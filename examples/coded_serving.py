"""Coded serving engine in ~40 lines: LeNet-5 behind a CodedServer.

Starts a continuous-batching server over one resident coded pipeline on
n=8 simulated workers (one of them a straggler), fires a burst of
concurrent requests from client threads, and prints each request's
queue-wait / execute / end-to-end latency.  The straggler never shows up
in the latencies — the coded cluster decodes from the fastest delta
workers, and late arrivals join the next layer boundary instead of
waiting for the batch ahead.

  PYTHONPATH=src python examples/coded_serving.py [--requests 12]
"""
import argparse
import threading

import jax
import numpy as np

from repro.models.cnn import init_cnn
from repro.runtime import StragglerModel
from repro.serving import CodedServer

N_WORKERS = 8


def main(requests: int = 12):
    rng = np.random.default_rng(0)
    params = init_cnn("lenet5", jax.random.PRNGKey(0))

    delays = np.zeros(N_WORKERS)
    delays[3] = 0.25  # one injected straggler (+250 ms per subtask)
    server = CodedServer.from_cnn(
        "lenet5", params, N_WORKERS, default_kab=(2, 4),
        straggler=StragglerModel(delays), mode="threads",
        bucket_sizes=(1, 2, 4),
    )
    server.warmup()  # pre-trace every (layer, bucket) program

    xs = rng.standard_normal((requests, 1, 32, 32)).astype(np.float32)
    handles = [None] * requests

    def client(i):  # each request arrives on its own client thread
        handles[i] = server.submit(xs[i])

    with server:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(requests)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for i, h in enumerate(handles):
            y = h.result(timeout=60.0)
            rec = next(r for r in server.metrics.records()
                       if r.request_id == h.request_id)
            print(
                f"request {h.request_id:2d}: queue {rec.queue_wait_s*1e3:6.1f} ms  "
                f"execute {rec.execute_s*1e3:6.1f} ms  "
                f"e2e {rec.e2e_s*1e3:6.1f} ms  "
                f"(batch {rec.batch_real}/{rec.bucket}, out {y.shape})"
            )
    stats = server.stats()
    print(f"\n{stats.summary_line()}")
    print(f"jit programs: {server.pipeline.worker_program_traces} traces "
          f"for buckets {server.pipeline.bucket_sizes} — bounded by bucket "
          f"count, despite the straggler on worker 3.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    main(**vars(ap.parse_args()))
