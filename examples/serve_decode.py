"""Batched serving example: prefill + greedy decode with a KV cache on any
of the 10 architectures (reduced configs on CPU).

  PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-1.6b
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()
    seq = serve(
        args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, smoke=True,
    )
    print("generated token ids (request 0):", seq[0].tolist())


if __name__ == "__main__":
    main()
