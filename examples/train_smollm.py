"""End-to-end training driver example: train a (reduced) SmolLM for a few
hundred steps with the full substrate — sharded train step, deterministic
resumable data, async checkpointing — and show the loss curve.

  PYTHONPATH=src python examples/train_smollm.py [--steps 300]
"""
import argparse
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-config", action="store_true",
                    help="use the real 135M config (slow on CPU)")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        losses = train(
            "smollm-135m",
            steps=args.steps,
            batch=args.batch,
            seq=args.seq,
            smoke=not args.full_config,
            ckpt_dir=ckpt,
            ckpt_every=100,
        )
    n = max(len(losses) // 10, 1)
    print("\nloss curve (decile means):")
    for i in range(0, len(losses), n):
        seg = losses[i : i + n]
        bar = "#" * int((seg[0] - min(losses)) * 40 / max(max(losses) - min(losses), 1e-6))
        print(f"  step {i:4d}  {sum(seg)/len(seg):.4f}  {bar}")
    print(f"\nfirst {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
