"""Coded CNN inference under stragglers (the paper's deployment story).

Runs AlexNet's ConvLs through the simulated master/worker cluster with
injected stragglers and a dead node, layer-wise optimal (k_A, k_B) from the
cost model, and reports the per-layer timing breakdown.

  PYTHONPATH=src python examples/coded_cnn_inference.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.cost import CostWeights, optimal_partition
from repro.core.fcdcc import FcdccPlan
from repro.models.cnn import CNN_SPECS, layer_geometry
from repro.runtime import FcdccCluster, StragglerModel

N_WORKERS = 12
Q = 16  # subtasks -> delta = Q/4 = 4, gamma = 8
W = CostWeights(comm=0.09, store=0.023, comp=0.0)

rng = np.random.default_rng(0)
hw0, layers = CNN_SPECS["alexnet"]
hw0 = 113  # reduced spatial size for the CPU demo

# 2 stragglers (+1s) and one dead worker; gamma covers all of them
delays = np.zeros(N_WORKERS)
delays[[1, 7]] = 1.0
delays[3] = np.inf
straggler = StragglerModel(delays)

hw = hw0
x = jnp.asarray(rng.standard_normal((3, hw, hw)), jnp.float32)
print(f"{N_WORKERS} workers, Q={Q} subtasks, 2 stragglers + 1 dead node\n")
for layer in layers:
    geo0 = layer_geometry(layer, hw)
    (k_a, k_b), cost, _ = optimal_partition(geo0, Q, W)
    if layer.out_ch % k_b:
        k_a, k_b = 2, Q // 2
    plan = FcdccPlan(n=N_WORKERS, k_a=k_a, k_b=k_b)
    geo = layer_geometry(layer, hw, k_a, k_b)
    k = jnp.asarray(
        rng.standard_normal((layer.out_ch, layer.in_ch, layer.kernel, layer.kernel))
        * (layer.in_ch * layer.kernel**2) ** -0.5,
        jnp.float32,
    )
    cluster = FcdccCluster(plan, straggler, mode="simulated")
    y, t = cluster.run_layer(geo, x, k)
    print(
        f"{layer.name:6s} (k_A,k_B)=({k_a:2d},{k_b:2d}) "
        f"encode {t.encode_s*1e3:6.1f} ms  compute {t.compute_s*1e3:6.1f} ms "
        f"decode {t.decode_s*1e3:6.1f} ms  used workers {t.used_workers}"
    )
    hw = geo.out_h // layer.pool if layer.pool > 1 else geo.out_h
    x = jnp.maximum(y, 0.0)[:, :hw, :hw] if layer.pool == 1 else jnp.max(
        jnp.maximum(y, 0.0)[:, : geo.out_h - geo.out_h % layer.pool,
                            : geo.out_w - geo.out_w % layer.pool]
        .reshape(layer.out_ch, geo.out_h // layer.pool, layer.pool,
                 geo.out_w // layer.pool, layer.pool)
        , axis=(2, 4),
    )
    hw = x.shape[1]
print("\ninference completed despite stragglers and a dead node.")
