"""Coded CNN inference under stragglers (the paper's deployment story).

Compiles AlexNet's ConvL stack into a ``CodedPipeline`` — layer-wise optimal
(k_A, k_B) from the cost model, every layer's filters encoded ONCE and
resident on the workers — then streams a batch of images through the
simulated master/worker cluster with injected stragglers and a dead node,
reporting the per-layer timing breakdown of the batched steady-state run.

  PYTHONPATH=src python examples/coded_cnn_inference.py [--batch 4]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import CostWeights
from repro.core.fcdcc import FcdccPlan
from repro.core.pipeline import CodedPipeline, plan_layers
from repro.models.cnn import CNN_SPECS, init_cnn
from repro.runtime import FcdccCluster, StragglerModel

N_WORKERS = 12
Q = 16  # subtasks -> delta = Q/4 = 4, gamma = 8
W = CostWeights(comm=0.09, store=0.023, comp=0.0)


def main(batch: int = 4):
    rng = np.random.default_rng(0)
    _, layers = CNN_SPECS["alexnet"]
    hw0 = 113  # reduced spatial size for the CPU demo

    # 2 stragglers (+1s) and one dead worker; gamma covers all of them
    delays = np.zeros(N_WORKERS)
    delays[[1, 7]] = 1.0
    delays[3] = np.inf
    straggler = StragglerModel(delays)

    params = init_cnn("alexnet", jax.random.PRNGKey(0))

    # compile once: per-layer cost-optimal (k_A, k_B), filters encoded once
    specs = plan_layers(layers, hw0, N_WORKERS, q=Q, weights=W)
    pipeline = CodedPipeline(specs, params)
    assert pipeline.filter_encode_calls == len(layers)  # encode-once contract

    cluster = FcdccCluster(FcdccPlan(n=N_WORKERS, k_a=2, k_b=Q // 2),
                           straggler, mode="simulated")
    cluster.load_pipeline(pipeline)

    x = jnp.asarray(rng.standard_normal((batch, 3, hw0, hw0)), jnp.float32)
    print(f"{N_WORKERS} workers, Q={Q} subtasks, batch={batch}, "
          f"2 stragglers + 1 dead node\n")
    y, timings = cluster.run_pipeline(x)
    for spec, t in zip(pipeline.specs, timings):
        print(
            f"{spec.name:6s} (k_A,k_B)=({spec.plan.k_a:2d},{spec.plan.k_b:2d}) "
            f"encode {t.encode_s*1e3:6.1f} ms  compute {t.compute_s*1e3:6.1f} ms "
            f"decode {t.decode_s*1e3:6.1f} ms  used workers {t.used_workers}"
        )
    print(f"\noutput {tuple(y.shape)}; batched inference completed despite "
          f"stragglers and a dead node.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    main(**vars(ap.parse_args()))
