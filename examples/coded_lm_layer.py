"""FCDCC on a transformer FFN layer (the LM-integration of the paper).

A dense layer is the 1x1-conv case of the paper's scheme: KCCP codes the
weight's output dim, degenerate APCP splits the token rows.  Here a SwiGLU
FFN block of the qwen3-4b (reduced) config runs with coded matmuls and
survives gamma stragglers.

  PYTHONPATH=src python examples/coded_lm_layer.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coded_linear import CodedLinear
from repro.core.fcdcc import FcdccPlan

plan = FcdccPlan(n=8, k_a=2, k_b=8)  # delta=4, tolerates gamma=4
T, D, F = 64, 256, 512

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
w_gate = jnp.asarray(rng.standard_normal((D, F)) / np.sqrt(D), jnp.float32)
w_up = jnp.asarray(rng.standard_normal((D, F)) / np.sqrt(D), jnp.float32)
w_down = jnp.asarray(rng.standard_normal((F, D)) / np.sqrt(F), jnp.float32)

up_layer = CodedLinear(plan, T, D, F)
down_layer = CodedLinear(plan, T, F, D)

survivors = [7, 5, 2, 0]  # any delta=4 of the 8 workers
g = up_layer.run_simulated(x, w_gate, survivors)
u = up_layer.run_simulated(x, w_up, survivors)
h = jax.nn.silu(g) * u  # nonlinearity on the master side of the code
y = down_layer.run_simulated(h, w_down, survivors)

ref = (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down
err = float(jnp.max(jnp.abs(y - ref)))
print(f"coded SwiGLU FFN: n={plan.n}, delta={plan.delta}, gamma={plan.gamma}")
print(f"max |err| vs uncoded = {err:.2e}")
assert err < 1e-3
print("LM layer survives", plan.gamma, "stragglers with exact reconstruction.")
