"""Quickstart: one FCDCC-coded convolution, end to end.

Shows the paper's full pipeline on a single layer: APCP/KCCP partitioning,
CRME encoding, per-worker coded subtasks, straggler-tolerant decode —
and checks the result against the plain convolution.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CodedConv2d, ConvGeometry, FcdccPlan

# 6 workers; input split in 2 (spatial), filters in 4 (channels);
# recovery threshold delta = 2*4/4 = 2 -> tolerates gamma = 4 stragglers.
plan = FcdccPlan(n=6, k_a=2, k_b=4)
geo = ConvGeometry(
    in_channels=3, out_channels=8, height=32, width=32,
    kernel_h=3, kernel_w=3, stride=1, padding=1, k_a=2, k_b=4,
)
layer = CodedConv2d(plan, geo)

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((3, 32, 32)), jnp.float32)
k = jnp.asarray(rng.standard_normal((8, 3, 3, 3)), jnp.float32)

print(f"n={plan.n} workers, delta={plan.delta}, tolerates gamma={plan.gamma}")

# master: encode (filters would be pre-distributed once in deployment)
xe = layer.encode_inputs(x)   # (n, 2, C, h_hat, W+2p)
ke = layer.encode_filters(k)  # (n, 2, N/k_b, C, 3, 3)

# workers: each computes its coded subtask
outs = jax.vmap(layer.worker_compute)(xe, ke)

# master: decode from ANY delta workers — pretend 4 of 6 straggled
survivors = [5, 2]
y = layer.decode(survivors, outs[jnp.asarray(survivors)])

ref = jax.lax.conv_general_dilated(
    x[None], k, (1, 1), ((1, 1), (1, 1)),
    dimension_numbers=("NCHW", "OIHW", "NCHW"),
)[0]
print("output", y.shape, "max |err| =", float(jnp.max(jnp.abs(y - ref))))
assert float(jnp.max(jnp.abs(y - ref))) < 1e-3
print("coded result matches the plain convolution — straggler-proof.")
