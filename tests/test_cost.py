"""Cost model: eqs. (50)-(60), convexity, Table IV spot checks."""
import pytest

from repro.core.cost import CostWeights, continuous_optimum, cost_breakdown, optimal_partition
from repro.models.cnn import CNN_SPECS, layer_geometry

W = CostWeights(comm=0.09, store=0.023, comp=0.0)


def test_breakdown_formulas():
    geo = layer_geometry(CNN_SPECS["alexnet"][1][0], 227)  # conv1
    b = cost_breakdown(geo, 2, 8, W)
    assert b.v_comm_up == pytest.approx(4 * 3 * 227 * 227 / 2)
    assert b.v_store == pytest.approx(2 * 96 * 3 * 11 * 11 / 8)
    assert b.total == b.c_comm + b.c_comp + b.c_store


def test_convexity_in_k_a():
    """U(k_a) along k_a*k_b = Q is convex: single local minimum."""
    geo = layer_geometry(CNN_SPECS["alexnet"][1][1], 27)
    _, _, landscape = optimal_partition(geo, 64, W)
    pairs = sorted(landscape.items())  # sorted by k_a
    us = [u for _, u in pairs]
    # differences change sign at most once
    signs = [u2 > u1 for u1, u2 in zip(us, us[1:])]
    assert signs == sorted(signs)


def test_early_layers_prefer_spatial_partitioning():
    """Paper Table IV: conv1 (large spatial, few channels) -> k_A = Q."""
    hw, layers = CNN_SPECS["alexnet"]
    geo = layer_geometry(layers[0], hw)
    (ka, kb), _, _ = optimal_partition(geo, 32, W)
    assert (ka, kb) == (32, 1)


def test_deep_layers_prefer_channel_partitioning():
    """Paper Table IV: VGG conv5 (small spatial, many channels) -> large k_B."""
    geo = layer_geometry(CNN_SPECS["vgg16"][1][-1], 14)
    (ka, kb), _, _ = optimal_partition(geo, 32, W)
    assert kb >= 8


def test_continuous_vs_discrete_agree_in_order():
    geo = layer_geometry(CNN_SPECS["alexnet"][1][2], 13)
    kc = continuous_optimum(geo, 32, W)
    (ka, _), _, _ = optimal_partition(geo, 32, W)
    assert 0.25 <= ka / max(kc, 1e-9) <= 4.0
