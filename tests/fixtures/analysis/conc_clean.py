"""Clean concurrency fixture — the lint must report nothing here."""

import threading


class Tidy:
    def __init__(self):
        self._lock = threading.Lock()
        self.items = []  # guarded-by: self._lock
        self._thread = None  # guarded-by: control-thread
        self.cv = threading.Condition(threading.RLock())
        self.ready = False  # guarded-by: self.cv

    def push(self, v):
        with self._lock:
            self.items.append(v)

    def signal(self):
        with self.cv:
            self.ready = True
            self.cv.notify_all()

    def await_ready(self):
        with self.cv:
            while not self.ready:
                self.cv.wait(0.05)

    def start(self):
        self._thread = threading.Thread(target=lambda: None, daemon=True)
        self._thread.start()

    def stop(self):
        if self._thread is not None:
            self._thread.join(timeout=1.0)
            self._thread = None
