"""Seeded concurrency violations — every lint rule must fire on this file.

NOT importable production code: this module exists only as input for
``repro.analysis.concurrency`` in ``tests/test_analysis.py``.  Each class
isolates one rule so the tests can assert rule -> location precisely.
"""

import threading

_G_LOCK = threading.Lock()
_G_STATE = {}  # guarded-by: _G_LOCK


def bad_global_write():
    global _G_STATE
    _G_STATE = {"reset": True}  # CONC-GUARD: no lock held


class GuardViolation:
    def __init__(self):
        self._lock = threading.Lock()
        self.items = []  # guarded-by: self._lock
        self.count = 0  # guarded-by: self._lock

    def ok(self):
        with self._lock:
            self.items.append(1)
            self.count += 1

    def bad(self):
        self.items.append(2)  # CONC-GUARD
        self.count = 5  # CONC-GUARD

    def suppressed(self):
        self.count = 9  # analysis: allow(CONC-GUARD)


class UnknownGuard:
    def __init__(self):
        self.value = 0  # guarded-by: self._no_such_lock  # CONC-GUARD-UNKNOWN


class SelfDeadlock:
    def __init__(self):
        self._lock = threading.Lock()

    def relock(self):
        with self._lock:
            with self._lock:  # CONC-SELF-DEADLOCK
                pass

    def _acquires(self):
        with self._lock:
            pass

    def relock_via_call(self):
        with self._lock:
            self._acquires()  # CONC-SELF-DEADLOCK (interprocedural)


class ReentrantOk:
    def __init__(self):
        self._lock = threading.RLock()

    def relock(self):
        with self._lock:
            with self._lock:  # fine: reentrant
                pass


class OrderCycle:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def ab(self):
        with self._a:
            with self._b:
                pass

    def ba(self):
        with self._b:
            with self._a:  # CONC-ORDER: cycle _a -> _b -> _a
                pass


class WaitWithoutLoop:
    def __init__(self):
        self.cv = threading.Condition(threading.RLock())
        self.evt = threading.Event()
        self.ready = False

    def bad_wait(self):
        with self.cv:
            self.cv.wait()  # CONC-WAIT-LOOP

    def good_wait(self):
        with self.cv:
            while not self.ready:
                self.cv.wait(0.1)

    def event_wait_is_fine(self):
        self.evt.wait(1.0)  # level-triggered: exempt


class LeakedThreads:
    def start(self):  # CONC-THREAD-LIFECYCLE: no join/shutdown anywhere
        t = threading.Thread(target=lambda: None, daemon=True)
        t.start()


class InterprocHeld:
    """Private helper mutating under the caller's lock: must NOT flag."""

    def __init__(self):
        self._lock = threading.Lock()
        self.state = {}  # guarded-by: self._lock

    def _apply(self, k, v):
        self.state[k] = v  # every caller holds the lock

    def put(self, k, v):
        with self._lock:
            self._apply(k, v)
