"""The continuous-batching coded LM server (``serving/lm_engine.py``).

Covers: token-stream continuous batching with late admission per decode
step (greedy outputs match the uncoded reference decoder for every
request, whatever admission order interleaved them); single-token
requests completing at admission; straggler-tolerant serving; request
packing; lifecycle guards; and CNN + LM co-serving on ONE shared coded
worker pool (the same cluster runs ConvL rounds and decoder GEMM rounds
concurrently).
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smollm_135m
from repro.core.decoder_pipeline import build_lm_decoder_pipeline
from repro.core.pipeline import build_cnn_pipeline
from repro.models import transformer as lm
from repro.models.cnn import init_cnn, input_hw
from repro.runtime import FcdccCluster, StragglerModel
from repro.serving import CodedLMServer, pack_request, unpack_request

N = 4
MAX_LEN = 32
MAX_PROMPT = 8
PROMPTS = [[5, 9, 2], [7, 1], [3, 3, 4, 8, 2], [11], [6, 2, 9, 1]]
GENS = [6, 4, 3, 1, 5]


@pytest.fixture(scope="module")
def smoke():
    bundle = smollm_135m.smoke()
    params = bundle.init(jax.random.PRNGKey(0), jnp.float32)
    return bundle.cfg, params


@pytest.fixture(scope="module")
def refs(smoke):
    cfg, params = smoke
    return [_ref_generate(cfg, params, p, g) for p, g in zip(PROMPTS, GENS)]


def _ref_generate(cfg, params, prompt, gen):
    """Uncoded greedy reference: batched prefill + decode_step loop."""
    toks = jnp.asarray([prompt])
    cache = lm.init_cache(cfg, 1, MAX_LEN, jnp.float32)
    logits, cache = lm.prefill(params, cfg, cache, toks)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    pos = len(prompt)
    for _ in range(gen - 1):
        logits, cache = lm.decode_step(
            params, cfg, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


def _pipe(smoke, **kw):
    cfg, params = smoke
    kw.setdefault("bucket_sizes", (1, 2, 4))
    kw.setdefault("max_len", MAX_LEN)
    return build_lm_decoder_pipeline(cfg, params, N, k_b=4, **kw)


def test_pack_unpack_roundtrip():
    row = pack_request([4, 5, 6], 7, MAX_PROMPT)
    prompt, gen = unpack_request(row)
    assert prompt.tolist() == [4, 5, 6] and gen == 7
    with pytest.raises(ValueError, match="exceeds"):
        pack_request(list(range(MAX_PROMPT + 1)), 1, MAX_PROMPT)
    with pytest.raises(ValueError, match="at least one"):
        pack_request([], 1, MAX_PROMPT)
    with pytest.raises(ValueError, match="max_new_tokens"):
        pack_request([1], 0, MAX_PROMPT)


def test_continuous_batching_matches_reference(smoke, refs):
    """Mixed prompt/generation lengths served concurrently, plus a request
    submitted mid-flight (admitted at a decode-step boundary), all match
    the uncoded reference decoder exactly."""
    cfg, params = smoke
    srv = CodedLMServer(_pipe(smoke), max_prompt=MAX_PROMPT,
                        poll_interval_s=0.002)
    with srv:
        handles = [srv.submit(p, g) for p, g in zip(PROMPTS, GENS)]
        time.sleep(0.05)  # engine mid-stream: this one admits late
        late = srv.submit([2, 4, 6], 4)
        results = [h.result(timeout=120) for h in handles]
        late_result = late.result(timeout=120)
    for got, want in zip(results, refs):
        assert list(got) == want
    assert list(late_result) == _ref_generate(cfg, params, [2, 4, 6], 4)
    assert srv.requests_served == len(PROMPTS) + 1
    assert srv.tokens_generated >= sum(GENS) + 4
    assert srv.tokens_per_second() > 0


def test_single_token_request(smoke, refs):
    """gen=1 resolves from the prefill logits alone — no decode round."""
    srv = CodedLMServer(_pipe(smoke), max_prompt=MAX_PROMPT)
    with srv:
        out = srv.generate(PROMPTS[3], 1)
    assert list(out) == refs[3]


def test_straggler_serving(smoke, refs):
    """1 of n straggling every round: served tokens are unchanged."""
    st = StragglerModel(np.array([0.0, 0.0, 0.02, 0.0]))  # worker 2 straggles
    srv = CodedLMServer(_pipe(smoke), st, max_prompt=MAX_PROMPT)
    with srv:
        handles = [srv.submit(p, g) for p, g in zip(PROMPTS, GENS)]
        results = [h.result(timeout=120) for h in handles]
    for got, want in zip(results, refs):
        assert list(got) == want


def test_direct_execution_forced_subset(smoke, refs):
    """execution='direct' with a forced survivor subset: no cluster spun
    up, same tokens."""
    srv = CodedLMServer(_pipe(smoke), execution="direct",
                        worker_ids=(1, 3), max_prompt=MAX_PROMPT)
    assert srv.cluster is None
    with srv:
        out = srv.generate(PROMPTS[0], GENS[0])
    assert list(out) == refs[0]


def test_lifecycle_guards(smoke):
    srv = CodedLMServer(_pipe(smoke), max_prompt=MAX_PROMPT)
    with pytest.raises(RuntimeError, match="not running"):
        srv.submit([1, 2], 2)
    with srv:
        with pytest.raises(ValueError, match="exceeds"):
            srv.submit(list(range(MAX_PROMPT + 1)), 2)
    # idempotent shutdown
    srv.shutdown()


def test_cnn_lm_co_serving_one_pool(smoke, refs):
    """One FcdccCluster serves a CNN's ConvL rounds and the LM's decoder
    GEMM rounds concurrently: the LM engine thread streams decode steps
    while the main thread pushes CNN inferences through the same worker
    pool, and both outputs are unchanged from solo runs."""
    cfg, params = smoke
    cnn_params = init_cnn("lenet5", jax.random.PRNGKey(1))
    cnn_pipe = build_cnn_pipeline(
        "lenet5", cnn_params, N, default_kab=(1, 2),
        input_hw=input_hw("lenet5", smoke=True), bucket_sizes=(1, 2),
    )
    lm_pipe = _pipe(smoke)
    cluster = FcdccCluster(cnn_pipe.specs[0].plan, None, mode="simulated",
                           backend="lax", interpret=True)
    try:
        cluster.load_pipeline(cnn_pipe, "cnn")
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(2,) + cnn_pipe.input_shape),
                        jnp.float32)
        y_solo, _ = cluster.run_pipeline(x, model="cnn")
        srv = CodedLMServer(lm_pipe, cluster=cluster, model="lm",
                            max_prompt=MAX_PROMPT)
        cnn_out, cnn_err = [], []

        def cnn_client():
            try:
                for _ in range(4):
                    y, _ = cluster.run_pipeline(x, model="cnn")
                    cnn_out.append(np.asarray(y))
            except Exception as err:  # surfaces in the main thread below
                cnn_err.append(err)

        with srv:
            t = threading.Thread(target=cnn_client)
            t.start()
            handles = [srv.submit(p, g) for p, g in zip(PROMPTS, GENS)]
            results = [h.result(timeout=120) for h in handles]
            t.join(timeout=120)
        assert not t.is_alive() and not cnn_err, f"CNN client failed: {cnn_err}"
        for got, want in zip(results, refs):
            assert list(got) == want
        for y in cnn_out:
            np.testing.assert_array_equal(y, np.asarray(y_solo))
    finally:
        cluster.shutdown()


def test_shutdown_drain_finishes_requests(smoke, refs):
    """shutdown(drain=True) completes queued work before stopping."""
    srv = CodedLMServer(_pipe(smoke), max_prompt=MAX_PROMPT)
    srv.start()
    h = srv.submit(PROMPTS[0], GENS[0])
    srv.shutdown(drain=True)
    assert list(h.result(timeout=1)) == refs[0]
