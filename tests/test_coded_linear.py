"""CodedLinear: FCDCC on dense layers (the LM-integration path)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coded_linear import CodedLinear
from repro.core.fcdcc import FcdccPlan

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n,k_a,k_b,ids", [
    (6, 2, 4, None),
    (6, 2, 4, [5, 4]),
    (8, 4, 8, [7, 5, 3, 1, 0, 2, 4, 6]),
    (4, 1, 8, [3, 0, 1, 2]),
    (4, 8, 1, [1, 2, 0, 3]),
])
def test_coded_linear_matches_matmul(n, k_a, k_b, ids):
    plan = FcdccPlan(n=n, k_a=k_a, k_b=k_b)
    t, d_in, d_out = 8 * max(k_a, 1), 32, 8 * max(k_b, 1)
    layer = CodedLinear(plan, t, d_in, d_out)
    x = jnp.asarray(RNG.standard_normal((t, d_in)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((d_in, d_out)), jnp.float32)
    if ids is not None:
        ids = ids[: plan.delta]
    y = layer.run_simulated(x, w, ids)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=2e-3, atol=2e-3)


def test_coded_ffn_block():
    """A coded SwiGLU FFN: nonlinearity on the master side of the coded
    boundary, both matmuls coded (the deployment pattern for LM layers)."""
    plan = FcdccPlan(n=5, k_a=2, k_b=2)
    t, d, f = 16, 24, 32
    up = CodedLinear(plan, t, d, f)
    down = CodedLinear(plan, t, f, d)
    x = jnp.asarray(RNG.standard_normal((t, d)), jnp.float32)
    w1 = jnp.asarray(RNG.standard_normal((d, f)), jnp.float32)
    w2 = jnp.asarray(RNG.standard_normal((f, d)), jnp.float32)
    h = up.run_simulated(x, w1, [4])
    h = jnp.tanh(h)  # master-side nonlinearity
    y = down.run_simulated(h, w2, [2])
    ref = jnp.tanh(x @ w1) @ w2
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-3, atol=2e-3)
