"""Suite-wide guards.

Per-test watchdog: the serving engine runs scheduler/worker threads, and a
wedged thread (deadlocked queue condition, never-signalled request event)
would otherwise hang the whole fast suite.  pytest-timeout isn't in the
image, so this uses SIGALRM directly — the alarm interrupts the blocked
main thread and fails just that test; with ``-x`` (the tier-1/ci.sh
invocation) the run then stops fail-fast.  Tune via REPRO_TEST_TIMEOUT
(seconds, 0 disables).
"""
import os
import signal

import pytest

TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT", "300"))


@pytest.fixture(autouse=True)
def _test_watchdog(request):
    if TIMEOUT_S <= 0 or not hasattr(signal, "SIGALRM"):
        yield
        return

    def on_timeout(signum, frame):
        raise TimeoutError(
            f"test exceeded REPRO_TEST_TIMEOUT={TIMEOUT_S}s "
            f"(hung thread in {request.node.nodeid}?)"
        )

    old = signal.signal(signal.SIGALRM, on_timeout)
    signal.alarm(TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
