"""Simulated cluster: straggler avoidance, failures, elastic recovery."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fcdcc import FcdccPlan
from repro.core.partition import ConvGeometry, np_reference_conv
from repro.runtime import ClusterDegraded, FcdccCluster, StragglerModel, run_layer_elastic

RNG = np.random.default_rng(0)
PLAN = FcdccPlan(n=6, k_a=2, k_b=4)
GEO = ConvGeometry(3, 8, 12, 12, 3, 3, 1, 1, 2, 4)
X = jnp.asarray(RNG.standard_normal((3, 12, 12)), jnp.float32)
K = jnp.asarray(RNG.standard_normal((8, 3, 3, 3)), jnp.float32)
REF = np_reference_conv(np.asarray(X), np.asarray(K), 1, 1)


def test_simulated_avoids_stragglers():
    cl = FcdccCluster(PLAN, StragglerModel.fixed(6, 2, 5.0), mode="simulated")
    y, t = cl.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
    assert t.compute_s < 1.0  # delta-th fastest, not the 5s stragglers
    assert all(t.worker_compute_s[i] < 1.0 for i in t.used_workers)


def test_threads_mode_returns_before_stragglers():
    cl = FcdccCluster(PLAN, StragglerModel.fixed(6, 2, 0.5), mode="threads")
    y, t = cl.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
    assert t.compute_s < 0.4


def test_dead_workers_within_gamma():
    d = np.zeros(6)
    d[[0, 1, 2, 3]] = np.inf  # 4 dead, gamma = 6 - 2 = 4
    cl = FcdccCluster(PLAN, StragglerModel(d), mode="simulated")
    y, _ = cl.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)


def test_degraded_raises_then_elastic_recovers():
    d = np.zeros(6)
    d[:5] = np.inf  # one survivor < delta=2
    with pytest.raises(ClusterDegraded):
        FcdccCluster(PLAN, StragglerModel(d), mode="simulated").run_layer(GEO, X, K)
    y, _, plan2 = run_layer_elastic(PLAN, GEO, X, K, StragglerModel(d), mode="simulated")
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
    assert plan2.delta <= 1  # shrank to a grid the survivor can cover


def test_elastic_recovery_threads_mode():
    """Same elastic path but over real worker threads: dead workers raise
    inside the persistent per-worker pool and the master re-plans."""
    d = np.zeros(6)
    d[:5] = np.inf
    with pytest.raises(ClusterDegraded):
        FcdccCluster(PLAN, StragglerModel(d), mode="threads").run_layer(GEO, X, K)
    y, timing, plan2 = run_layer_elastic(
        PLAN, GEO, X, K, StragglerModel(d), mode="threads"
    )
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
    assert plan2.delta <= 1
    assert timing.used_workers == [5]  # only the survivor contributed


def test_fused_worker_matches_loop():
    a = FcdccCluster(PLAN, StragglerModel.none(6), mode="simulated")
    y1, _ = a.run_layer(GEO, X, K)
    layer_loop = FcdccCluster(PLAN, StragglerModel.none(6), mode="simulated")
    y2, _ = layer_loop.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_dead_and_discarded_worker_times():
    """Dead workers report inf, workers discarded before finishing report
    nan — neither is mistakable for a fast node's 0.0 (the seed bug)."""
    d = np.zeros(6)
    d[0] = np.inf            # dead
    cl = FcdccCluster(PLAN, StragglerModel(d), mode="simulated")
    y, t = cl.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
    assert t.worker_compute_s[0] == float("inf")
    assert all(np.isfinite(t.worker_compute_s[i]) for i in t.used_workers)
    # finished_worker_s is the aggregation-safe view (no inf/nan)
    assert all(np.isfinite(v) for v in t.finished_worker_s)
    assert len(t.finished_worker_s) == 5

    # threads mode: a slow straggler is discarded before finishing -> nan
    d2 = np.zeros(6)
    d2[1] = np.inf           # dead
    d2[2] = 1.0              # straggler, still sleeping at collect
    cl2 = FcdccCluster(PLAN, StragglerModel(d2), mode="threads")
    y2, t2 = cl2.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y2), REF, atol=1e-3)
    assert t2.worker_compute_s[1] == float("inf")
    assert np.isnan(t2.worker_compute_s[2])
    assert all(np.isfinite(v) for v in t2.finished_worker_s)
    cl2.shutdown()


def test_elastic_retries_release_worker_pools(monkeypatch):
    """Every per-attempt cluster of run_layer_elastic must release its n
    single-thread executors (the seed leaked them per retry)."""
    import repro.runtime.cluster as rc

    created = []
    orig_cluster = rc.FcdccCluster

    class Recording(orig_cluster):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            created.append(self)

    monkeypatch.setattr(rc, "FcdccCluster", Recording)
    d = np.zeros(6)
    d[:5] = np.inf
    y, _, plan2 = rc.run_layer_elastic(
        PLAN, GEO, X, K, StragglerModel(d), mode="threads"
    )
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
    assert len(created) >= 2          # at least one degraded attempt + retry
    assert all(c._pools is None for c in created)  # all pools shut down


def test_cluster_pallas_backend_run_layer():
    """The cluster's per-worker dispatch path lowers through the fused
    pallas worker kernel and decodes identically to lax."""
    cl = FcdccCluster(PLAN, StragglerModel.none(6), mode="simulated",
                      backend="pallas")
    y, _ = cl.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
