"""Simulated cluster: straggler avoidance, failures, elastic recovery."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fcdcc import FcdccPlan
from repro.core.partition import ConvGeometry, np_reference_conv
from repro.runtime import ClusterDegraded, FcdccCluster, StragglerModel, run_layer_elastic

RNG = np.random.default_rng(0)
PLAN = FcdccPlan(n=6, k_a=2, k_b=4)
GEO = ConvGeometry(3, 8, 12, 12, 3, 3, 1, 1, 2, 4)
X = jnp.asarray(RNG.standard_normal((3, 12, 12)), jnp.float32)
K = jnp.asarray(RNG.standard_normal((8, 3, 3, 3)), jnp.float32)
REF = np_reference_conv(np.asarray(X), np.asarray(K), 1, 1)


def test_simulated_avoids_stragglers():
    cl = FcdccCluster(PLAN, StragglerModel.fixed(6, 2, 5.0), mode="simulated")
    y, t = cl.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
    assert t.compute_s < 1.0  # delta-th fastest, not the 5s stragglers
    assert all(t.worker_compute_s[i] < 1.0 for i in t.used_workers)


def test_threads_mode_returns_before_stragglers():
    cl = FcdccCluster(PLAN, StragglerModel.fixed(6, 2, 0.5), mode="threads")
    y, t = cl.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
    assert t.compute_s < 0.4


def test_dead_workers_within_gamma():
    d = np.zeros(6)
    d[[0, 1, 2, 3]] = np.inf  # 4 dead, gamma = 6 - 2 = 4
    cl = FcdccCluster(PLAN, StragglerModel(d), mode="simulated")
    y, _ = cl.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)


def test_degraded_raises_then_elastic_recovers():
    d = np.zeros(6)
    d[:5] = np.inf  # one survivor < delta=2
    with pytest.raises(ClusterDegraded):
        FcdccCluster(PLAN, StragglerModel(d), mode="simulated").run_layer(GEO, X, K)
    y, _, plan2 = run_layer_elastic(PLAN, GEO, X, K, StragglerModel(d), mode="simulated")
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
    assert plan2.delta <= 1  # shrank to a grid the survivor can cover


def test_elastic_recovery_threads_mode():
    """Same elastic path but over real worker threads: dead workers raise
    inside the persistent per-worker pool and the master re-plans."""
    d = np.zeros(6)
    d[:5] = np.inf
    with pytest.raises(ClusterDegraded):
        FcdccCluster(PLAN, StragglerModel(d), mode="threads").run_layer(GEO, X, K)
    y, timing, plan2 = run_layer_elastic(
        PLAN, GEO, X, K, StragglerModel(d), mode="threads"
    )
    np.testing.assert_allclose(np.asarray(y), REF, atol=1e-3)
    assert plan2.delta <= 1
    assert timing.used_workers == [5]  # only the survivor contributed


def test_fused_worker_matches_loop():
    a = FcdccCluster(PLAN, StragglerModel.none(6), mode="simulated")
    y1, _ = a.run_layer(GEO, X, K)
    layer_loop = FcdccCluster(PLAN, StragglerModel.none(6), mode="simulated")
    y2, _ = layer_loop.run_layer(GEO, X, K)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
