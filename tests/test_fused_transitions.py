"""Partition-resident layer transitions (``fuse_transitions=True``).

Covers: the partition-space helpers (channel rejoin, per-partition
relu/pool with halo exchange, APCP re-slicing) against the merged
reference — bit-exact, since everything is relu/max/slicing; fused
pipeline vs round-trip parity across all CNN_SPECS archs x {lax,
pallas-interpret}; odd/even pool boundaries and degenerate ``k_a=1`` /
``k_b=1`` grids; the bounded-trace contract under ``fuse_transitions``;
the cluster carrying partition-space state across layer rounds under
stragglers; and serving end-to-end with fused transitions under the
dead-worker straggler model, including partition-state coalescing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodedConv2d, CodedPipeline, ConvGeometry, FcdccPlan
from repro.core.partition import (
    apcp_partition,
    gather_partition_rows,
    merge_output,
    partition_apcp_slices,
    partition_channel_merge,
    partition_relu_pool,
    partition_transition,
)
from repro.core.pipeline import plan_layers, relu_pool
from repro.models.cnn import CNN_SPECS, ConvL, init_cnn
from repro.runtime import FcdccCluster, StragglerModel
from repro.serving import CodedServer

RNG = np.random.default_rng(0)


# -- partition-space helpers ----------------------------------------------
# (geo of layer i, pool, geo of layer i+1): odd out_h with even pool
# (floor-crop), pool windows straddling partition boundaries (hb % pool
# != 0), a pool window spanning >2 partitions (hb=1, pool=3), degenerate
# k_a=1 / k_b=1 axes, stride-2 + padded next layers, and a last partition
# made entirely of adaptive zero-pad rows (out_h=5 on k_a=4 -> hb=2).
TRANSITION_CASES = [
    (ConvGeometry(1, 6, 32, 32, 5, 5, 1, 0, 2, 2), 1,
     ConvGeometry(6, 16, 28, 28, 5, 5, 1, 0, 2, 2)),
    (ConvGeometry(1, 6, 32, 32, 5, 5, 1, 0, 4, 2), 2,
     ConvGeometry(6, 16, 14, 14, 5, 5, 1, 2, 2, 2)),
    (ConvGeometry(3, 8, 13, 13, 3, 3, 1, 0, 4, 2), 2,
     ConvGeometry(8, 8, 5, 5, 3, 3, 2, 1, 2, 1)),
    (ConvGeometry(2, 4, 9, 9, 3, 3, 1, 0, 8, 1), 3,
     ConvGeometry(4, 4, 2, 2, 1, 1, 1, 0, 2, 2)),
    (ConvGeometry(2, 8, 12, 12, 3, 3, 1, 1, 1, 8), 2,
     ConvGeometry(8, 8, 6, 6, 3, 3, 1, 1, 4, 1)),
    (ConvGeometry(2, 4, 7, 7, 3, 3, 1, 0, 4, 2), 1,
     ConvGeometry(4, 4, 5, 5, 3, 3, 1, 1, 4, 1)),
]


@pytest.mark.parametrize("geo,pool,geo_next", TRANSITION_CASES)
@pytest.mark.parametrize("batched", [True, False])
def test_partition_transition_matches_merged_reference(geo, pool, geo_next,
                                                       batched):
    """partition_transition == apcp_partition(relu_pool(merge_output(.)))
    bit-exactly (relu/max/slicing only, no float arithmetic reordered),
    and the two-stage relu_pool + apcp_slices decomposition agrees."""
    q = geo.k_a * geo.k_b
    block = (geo.out_c_block, geo.out_h_block, geo.out_w)
    shape = (q, 3) + block if batched else (q,) + block
    blocks = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    ref = apcp_partition(relu_pool(merge_output(blocks, geo), pool), geo_next)
    got = partition_transition(blocks, geo, pool, geo_next, relu=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # the documented two-stage decomposition is the same map
    spatial = jax.nn.relu(partition_channel_merge(blocks, geo))
    pooled, bounds = partition_relu_pool(
        [spatial[a] for a in range(geo.k_a)], geo, pool, relu=False)
    assert sum(hi - lo for lo, hi in bounds) == geo.out_h // pool
    two = partition_apcp_slices(pooled, geo_next)
    np.testing.assert_array_equal(np.asarray(two), np.asarray(ref))


def test_gather_partition_rows_halo_exchange():
    """The halo primitive: any [r0, r1) window of the virtual row stack,
    including windows spanning several ragged partitions."""
    parts = [jnp.arange(6).reshape(1, 3, 2) * (i + 1) for i in range(3)]
    virtual = np.concatenate([np.asarray(p) for p in parts], axis=-2)
    for r0, r1 in [(0, 2), (2, 5), (1, 9), (4, 4), (8, 9)]:
        got = np.asarray(gather_partition_rows(parts, r0, r1))
        np.testing.assert_array_equal(got, virtual[..., r0:r1, :])
    with pytest.raises(AssertionError, match="exceed"):
        gather_partition_rows(parts, 5, 10)


def test_decode_to_partitions_and_encode_from_partitions():
    """The fcdcc entry points: decode-to-grid + merge == decode, and
    encoding pre-sliced parts == encode_inputs on the assembled tensor."""
    plan = FcdccPlan(n=6, k_a=2, k_b=4)
    geo = ConvGeometry(3, 8, 12, 10, 3, 3, 1, 1, 2, 4)
    layer = CodedConv2d(plan, geo)
    x = jnp.asarray(RNG.standard_normal((2, 3, 12, 10)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((8, 3, 3, 3)), jnp.float32)
    xe, ke = layer.encode_inputs(x), layer.encode_filters(k)
    ids = [5, 1]
    outs = jax.vmap(layer.worker_compute)(xe[jnp.asarray(ids)],
                                          ke[jnp.asarray(ids)])
    blocks = layer.decode_to_partitions(ids, outs)
    np.testing.assert_allclose(
        np.asarray(merge_output(blocks, geo)),
        np.asarray(layer.decode(ids, outs)), atol=0)
    parts = apcp_partition(x, geo)
    np.testing.assert_allclose(
        np.asarray(layer.encode_from_partitions(parts)),
        np.asarray(layer.encode_inputs(x)), atol=0)


# -- fused pipeline vs round trip -----------------------------------------
STACK = [
    ConvL("t1", 2, 8, 3, stride=1, padding=1, pool=2),
    ConvL("t2", 8, 8, 3, padding=1),
    ConvL("t3", 8, 8, 3, padding=1, pool=2),
]


def _stack_params(layers, seed=0):
    rng = np.random.default_rng(seed)
    return {
        l.name: jnp.asarray(
            rng.standard_normal((l.out_ch, l.in_ch, l.kernel, l.kernel))
            * (l.in_ch * l.kernel**2) ** -0.5,
            jnp.float32,
        )
        for l in layers
    }


@pytest.mark.parametrize("arch,hw,backend", [
    ("lenet5", 20, "lax"),
    ("lenet5", 20, "pallas"),
    pytest.param("alexnet", 51, "lax", marks=pytest.mark.slow),
    pytest.param("alexnet", 51, "pallas", marks=pytest.mark.slow),
    pytest.param("vgg16", 32, "lax", marks=pytest.mark.slow),
    pytest.param("vgg16", 32, "pallas", marks=pytest.mark.slow),
])
def test_fused_pipeline_matches_roundtrip(arch, hw, backend):
    """The acceptance contract: fuse_transitions=True is allclose (fp32)
    with the round-trip path on every CNN_SPECS arch, on both backends,
    with worker + transition traces bounded by (geometries + transitions)
    x buckets."""
    params = init_cnn(arch, jax.random.PRNGKey(0))
    specs = plan_layers(CNN_SPECS[arch][1], hw, 6, default_kab=(2, 4))
    c0 = CNN_SPECS[arch][1][0].in_ch
    x = jnp.asarray(RNG.standard_normal((2, c0, hw, hw)), jnp.float32)
    ref = np.asarray(CodedPipeline(specs, params).run(x))
    fused = CodedPipeline(specs, params, backend=backend, bucket_sizes=(2,),
                          fuse_transitions=True)
    y = np.asarray(fused.run(x))
    assert y.shape == ref.shape
    tol = 5e-3 if backend == "pallas" else 1e-4
    np.testing.assert_allclose(y, ref, rtol=tol, atol=tol)
    # the serving fast path threads partition-space state the same way
    yp = np.asarray(fused.run_prepared(x, worker_ids=[5, 1, 3, 0]))
    np.testing.assert_allclose(yp, ref, rtol=tol, atol=tol)
    traces = fused.worker_program_traces + fused.transition_program_traces
    assert traces <= fused.program_trace_bound
    # repeated transition geometries (e.g. VGG conv blocks) share programs
    assert 1 <= fused.num_transitions <= len(specs) - 1
    assert len(fused._transitions) == fused.num_transitions
    assert fused.filter_encode_calls == len(specs)  # encode-once held


def test_fused_degenerate_grids_and_survivor_invariance():
    """k_a=1 (channel-only) and k_b=1 (spatial-only) layers mixed in one
    fused stack; every survivor subset decodes to the same output."""
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 4,
                        per_layer_kab={"t1": (1, 8), "t2": (8, 1)},
                        default_kab=(2, 2))
    x = jnp.asarray(RNG.standard_normal((3, 2, 16, 16)), jnp.float32)
    ref = np.asarray(CodedPipeline(specs, params).run(x))
    fused = CodedPipeline(specs, params, fuse_transitions=True)
    np.testing.assert_allclose(np.asarray(fused.run(x)), ref,
                               rtol=1e-4, atol=1e-4)
    for ids in ([3, 2, 1, 0], [1, 3, 0, 2]):
        np.testing.assert_allclose(
            np.asarray(fused.run_prepared(x, worker_ids=ids)), ref,
            rtol=1e-4, atol=1e-4)


def test_fused_bounded_traces_across_buckets():
    """Serving many distinct request-batch sizes leaves worker + transition
    traces bounded by (geometries + transitions) x buckets."""
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    fused = CodedPipeline(specs, params, bucket_sizes=(1, 2, 4),
                          fuse_transitions=True)
    for b in (1, 2, 3, 4, 3, 2, 1):
        x = jnp.asarray(RNG.standard_normal((b, 2, 16, 16)), jnp.float32)
        padded, real = fused.pad_to_bucket(x)
        fused.run(padded)
    traces = fused.worker_program_traces + fused.transition_program_traces
    assert traces <= fused.program_trace_bound
    assert fused.transition_program_traces <= \
        fused.num_transitions * len(fused.bucket_sizes)


def test_pad_to_bucket_partition_axis():
    """Mid-stack coded-share state pads on its batch axis (2) — zero
    shares, identical to encoding a zero activation."""
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    pipe = CodedPipeline(specs, params, bucket_sizes=(4,))
    xe = jnp.asarray(RNG.standard_normal((6, 2, 3, 2, 9, 18)), jnp.float32)
    padded, real = pipe.pad_to_bucket(xe, axis=2)
    assert padded.shape == (6, 2, 4, 2, 9, 18) and real == 3
    np.testing.assert_array_equal(np.asarray(padded[:, :, 3]), 0.0)
    np.testing.assert_array_equal(np.asarray(padded[:, :, :3]),
                                  np.asarray(xe))


# -- cluster: partition-space state across layer rounds --------------------
def test_cluster_fused_run_pipeline_under_stragglers():
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    ref = CodedPipeline(specs, params)
    fused = CodedPipeline(specs, params, fuse_transitions=True)
    delays = np.zeros(6)
    delays[1] = 5.0          # straggler
    delays[4] = np.inf       # dead worker
    cluster = FcdccCluster(FcdccPlan(n=6, k_a=2, k_b=4),
                           StragglerModel(delays), mode="simulated")
    cluster.load_pipeline(fused)
    x = jnp.asarray(RNG.standard_normal((2, 2, 16, 16)), jnp.float32)
    y, timings = cluster.run_pipeline(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.run(x)),
                               rtol=1e-4, atol=1e-4)
    assert len(timings) == len(STACK)
    for t in timings:
        assert 1 not in t.used_workers and 4 not in t.used_workers
    # mid-stack rounds never ran a separate encode: the transition fused it
    assert [t.encode_s == 0.0 for t in timings] == [False, True, True]


def test_cluster_fused_threads_mode_partition_state():
    """Threads mode: the coded-share state produced by round i feeds round
    i+1's per-worker dispatch, and the fastest-delta subset may differ per
    round."""
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    ref = CodedPipeline(specs, params)
    fused = CodedPipeline(specs, params, fuse_transitions=True)
    with FcdccCluster(FcdccPlan(n=6, k_a=2, k_b=4), StragglerModel.none(6),
                      mode="threads") as cluster:
        cluster.load_pipeline(fused)
        x = jnp.asarray(RNG.standard_normal((2, 2, 16, 16)), jnp.float32)
        y, _ = cluster.run_pipeline(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.run(x)),
                               rtol=1e-4, atol=1e-4)


# -- serving: fused end-to-end ---------------------------------------------
def _images(count, hw=16, c=2):
    return [jnp.asarray(RNG.standard_normal((c, hw, hw)), jnp.float32)
            for _ in range(count)]


@pytest.mark.parametrize("execution", ["cluster", "direct"])
def test_serving_fused_dead_worker(execution):
    """End-to-end serving over fused transitions under the dead-worker
    straggler model: results match the round-trip pipeline, traces stay
    bounded."""
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    ref = CodedPipeline(specs, params)
    fused = CodedPipeline(specs, params, bucket_sizes=(1, 2, 4),
                          fuse_transitions=True)
    delays = np.zeros(6)
    delays[2] = np.inf  # dead worker
    server = CodedServer(fused, StragglerModel(delays), mode="simulated",
                         execution=execution)
    server.warmup()
    xs = _images(5)
    with server:
        outs = [h.result(timeout=60.0) for h in server.submit_many(xs)]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref.run(x)),
                                   rtol=1e-4, atol=1e-4)
    traces = fused.worker_program_traces + fused.transition_program_traces
    assert traces <= fused.program_trace_bound


def test_serving_fused_coalesces_partition_state():
    """Two fragment batches admitted separately at layer 0 coalesce while
    mid-stack batches carry partition-space state — merged results still
    match per-request references."""
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    ref = CodedPipeline(specs, params)
    fused = CodedPipeline(specs, params, bucket_sizes=(1, 2, 4),
                          fuse_transitions=True)
    server = CodedServer(fused, StragglerModel.none(6), mode="simulated")
    xs = _images(2)
    sched = server.scheduler["default"]
    handles = []
    for x in xs:
        handles.append(sched.queue.submit(jnp.asarray(x, fused.input_dtype)))
        assert sched.admit() is not None
    assert [b.real for b in sched.inflight] == [1, 1]
    with server:
        outs = [h.result(timeout=60.0) for h in handles]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref.run(x)),
                                   rtol=1e-4, atol=1e-4)
    assert server.stats().coalesced == 1


def test_scheduler_coalesce_on_partition_axis():
    """Unit-level: equal-depth batches whose state is coded shares (batch
    axis 2) merge by slicing/concatenating that axis and re-padding with
    zero shares."""
    from repro.serving.scheduler import ScheduledBatch, Scheduler

    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    pipe = CodedPipeline(specs, params, bucket_sizes=(1, 2, 4))
    sched = Scheduler(pipe.pad_to_bucket, max_batch=4, max_inflight=4)

    def share_batch(reqs, real):
        x = jnp.asarray(RNG.standard_normal((6, 2, real, 2, 9, 18)),
                        jnp.float32)
        return ScheduledBatch(requests=list(reqs), x=x, bucket=real,
                              layer_idx=1, batch_axis=2)

    b1, b2 = share_batch(["r0"], 1), share_batch(["r1", "r2"], 2)
    x1, x2 = np.asarray(b1.x), np.asarray(b2.x)
    sched.inflight.extend([b1, b2])
    assert sched.coalesce() == 1
    (merged,) = sched.inflight
    assert merged.batch_axis == 2
    assert merged.bucket == 4 and merged.real == 3  # 3 -> bucket 4
    assert merged.requests == ["r0", "r1", "r2"]
    got = np.asarray(merged.x)
    np.testing.assert_array_equal(got[:, :, 0], x1[:, :, 0])
    np.testing.assert_array_equal(got[:, :, 1:3], x2[:, :, :2])
    np.testing.assert_array_equal(got[:, :, 3], 0.0)