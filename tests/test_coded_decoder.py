"""The coded LM decoder pipeline (``core/decoder_pipeline.py``).

Covers: once-only weight encoding; coded-vs-uncoded transformer decode
fp32 parity across forced survivor subsets x {lax, pallas}; bit-exact
replication-vs-uncoded equality (the fp32 bit-exactness claim: identical
worker/glue programs, decode by an exact one/identity); straggler and
dead-worker decode through the threaded cluster and the device pool;
batched-prefill-vs-step-loop parity; and the bounded-trace contract over
the decode-step program space.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smollm_135m
from repro.core.decoder_pipeline import (
    CodedDecoderPipeline,
    UncodedPlan,
    build_lm_decoder_pipeline,
)
from repro.models import transformer as lm
from repro.runtime import ClusterDegraded, FcdccCluster, StragglerModel

N = 4
MAX_LEN = 32
PROMPT = [5, 9, 2, 7, 1]
PROMPT2 = [7, 1, 4, 2, 6]
ATOL = 3e-4


@pytest.fixture(scope="module")
def smoke():
    bundle = smollm_135m.smoke()
    params = bundle.init(jax.random.PRNGKey(0), jnp.float32)
    return bundle.cfg, params


def _pipe(smoke, *, backend="lax", k_b=4, n=N, plan=None, buckets=(2, 4)):
    cfg, params = smoke
    return build_lm_decoder_pipeline(
        cfg, params, n, k_b=None if plan else k_b, plan=plan,
        backend=backend, bucket_sizes=buckets, max_len=MAX_LEN,
    )


def _prefilled(pipe, cfg, params, prompts):
    """Slot cache + first decode inputs from one batched prefill."""
    toks = jnp.asarray(prompts)
    logits, ks, vs = pipe.prefill_prompt(toks)
    cache = pipe.init_slot_cache(max(N, toks.shape[0]))
    for l in range(cfg.layers):
        cache[l]["k"] = pipe.slot_write(cache[l]["k"], ks[l], 0)
        cache[l]["v"] = pipe.slot_write(cache[l]["v"], vs[l], 0)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    pos = jnp.full((toks.shape[0],), toks.shape[1], jnp.int32)
    return cache, nxt, pos


def _ref_step(cfg, params, prompts):
    """Reference logits for the first post-prompt decode step."""
    toks = jnp.asarray(prompts)
    cache = lm.init_cache(cfg, toks.shape[0], MAX_LEN, jnp.float32)
    logits, cache = lm.prefill(params, cfg, cache, toks)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    ref, _ = lm.decode_step(params, cfg, cache, nxt[:, None],
                            jnp.int32(toks.shape[1]))
    return ref[:, 0]


def _subsets(n, delta):
    import itertools

    return list(itertools.combinations(range(n), delta))


def test_weights_encoded_once(smoke):
    cfg, params = smoke
    pipe = _pipe(smoke)
    assert pipe.weight_encode_calls == 4 * cfg.layers
    prompts = [PROMPT, PROMPT]
    cache, nxt, pos = _prefilled(pipe, cfg, params, prompts)
    for _ in range(3):
        _, nxt_, cache = pipe.run_decode_step_direct(nxt, cache, pos)
        nxt = nxt_[: len(prompts)]
        pos = pos + 1
    # serving N steps re-encodes nothing: weights are resident
    assert pipe.weight_encode_calls == 4 * cfg.layers


@pytest.mark.parametrize("backend", ["lax", "pallas"])
def test_decode_parity_forced_subsets(smoke, backend):
    """Coded decode == uncoded decoder output for EVERY survivor subset."""
    cfg, params = smoke
    pipe = _pipe(smoke, backend=backend)
    prompts = [PROMPT, [3, 3, 4, 8, 2]]
    ref = _ref_step(cfg, params, prompts)
    cache, nxt, pos = _prefilled(pipe, cfg, params, prompts)
    delta = pipe.specs[0].plan.delta
    for ids in _subsets(N, delta):
        logits, toks, _ = pipe.run_decode_step_direct(
            nxt, cache, pos, worker_ids=ids
        )
        b = len(prompts)
        np.testing.assert_allclose(np.asarray(logits[:b]), np.asarray(ref),
                                   atol=ATOL, rtol=0)
        assert jnp.array_equal(
            toks[:b], jnp.argmax(ref, axis=-1).astype(jnp.int32)
        ), f"greedy token mismatch for subset {ids} ({backend})"


@pytest.mark.parametrize("backend", ["lax", "pallas"])
def test_replication_bit_exact_vs_uncoded(smoke, backend):
    """k_b=1 replication decodes by multiplying with an exact 1.0, the
    uncoded plan by the identity — same worker program, same glue, so the
    fp32 outputs are bit-identical for every forced survivor."""
    cfg, params = smoke
    rep = _pipe(smoke, backend=backend, k_b=1, n=3)
    unc = _pipe(smoke, backend=backend, plan=UncodedPlan(N))
    prompts = [PROMPT, PROMPT2]
    cache_r, nxt, pos = _prefilled(rep, cfg, params, prompts)
    cache_u, _, _ = _prefilled(unc, cfg, params, prompts)
    lu, tu, _ = unc.run_decode_step_direct(nxt, cache_u, pos)
    for wid in range(3):
        lr, tr, _ = rep.run_decode_step_direct(
            nxt, cache_r, pos, worker_ids=(wid,)
        )
        assert jnp.array_equal(lr, lu), f"survivor {wid} not bit-equal"
        assert jnp.array_equal(tr, tu)


def test_uncoded_plan_needs_all_workers(smoke):
    unc = _pipe(smoke, plan=UncodedPlan(N))
    with pytest.raises(ValueError, match="needs delta"):
        unc.run_decode_step_direct(
            jnp.zeros(2, jnp.int32), unc.init_slot_cache(N),
            jnp.zeros(2, jnp.int32), worker_ids=(0, 1, 2),
        )


def test_prefill_matches_step_loop(smoke):
    """One jitted batched prefill == stepping the decoder over the prompt."""
    cfg, params = smoke
    toks = jnp.asarray([PROMPT, [3, 3, 4, 8, 2]])
    b, p = toks.shape
    cache = lm.init_cache(cfg, b, MAX_LEN, jnp.float32)
    logits_pf, cache_pf = lm.prefill(params, cfg, cache, toks)
    cache_st = lm.init_cache(cfg, b, MAX_LEN, jnp.float32)
    steps = []
    for t in range(p):
        lg, cache_st = lm.decode_step(params, cfg, cache_st, toks[:, t:t + 1],
                                      jnp.int32(t))
        steps.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(logits_pf),
                               np.stack([np.asarray(s) for s in steps], 1),
                               atol=ATOL, rtol=0)
    np.testing.assert_allclose(
        np.asarray(cache_pf["dense"]["k"][:, :, :p]),
        np.asarray(cache_st["dense"]["k"][:, :, :p]), atol=ATOL, rtol=0)


@pytest.mark.parametrize("backend", ["lax", "pallas"])
def test_cluster_straggler_skipped(smoke, backend):
    """1 of n straggling: every round decodes from the fastest delta, the
    straggler's results are never waited on, outputs match reference."""
    cfg, params = smoke
    pipe = _pipe(smoke, backend=backend)
    st = StragglerModel(np.array([0.0, 0.0, 0.05, 0.0]))  # worker 2 straggles
    cluster = FcdccCluster(pipe.specs[0].plan, st, mode="simulated",
                           backend=backend, interpret=True)
    try:
        cluster.load_pipeline(pipe, "lm")
        prompts = [PROMPT, PROMPT2]
        ref = _ref_step(cfg, params, prompts)
        cache, nxt, pos = _prefilled(pipe, cfg, params, prompts)
        timings = []
        logits, toks, _ = pipe.run_decode_step_cluster(
            cluster, nxt, cache, pos, model="lm", timings=timings
        )
        np.testing.assert_allclose(np.asarray(logits[:2]), np.asarray(ref),
                                   atol=ATOL, rtol=0)
        assert len(timings) == 4 * cfg.layers
        assert all(2 not in t.used_workers for t in timings)
    finally:
        cluster.shutdown()


def test_cluster_dead_worker(smoke):
    """delay=inf worker: coded rounds decode from the survivors; the
    uncoded plan (delta=n) degrades instead."""
    cfg, params = smoke
    st = StragglerModel(np.array([0.0, float("inf"), 0.0, 0.0]))  # worker 1 dead
    pipe = _pipe(smoke)
    cluster = FcdccCluster(pipe.specs[0].plan, st, mode="simulated",
                           backend="lax", interpret=True)
    try:
        cluster.load_pipeline(pipe, "lm")
        prompts = [PROMPT]
        ref = _ref_step(cfg, params, prompts)
        cache, nxt, pos = _prefilled(pipe, cfg, params, prompts)
        logits, _, _ = pipe.run_decode_step_cluster(
            cluster, nxt, cache, pos, model="lm"
        )
        np.testing.assert_allclose(np.asarray(logits[:1]), np.asarray(ref),
                                   atol=ATOL, rtol=0)
        unc = _pipe(smoke, plan=UncodedPlan(N))
        cluster.load_pipeline(unc, "lm-uncoded")
        cache_u, nxt_u, pos_u = _prefilled(unc, cfg, params, prompts)
        with pytest.raises(ClusterDegraded):
            unc.run_decode_step_cluster(
                cluster, nxt_u, cache_u, pos_u, model="lm-uncoded"
            )
    finally:
        cluster.shutdown()


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="device pool needs a multi-device host (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)",
)
@pytest.mark.parametrize("backend", ["lax"])
def test_device_pool_decode(smoke, backend):
    """Thread-vs-device pool bit-parity on a forced fastest-delta subset,
    plus straggling-device decode correctness."""
    cfg, params = smoke
    prompts = [PROMPT, PROMPT2]
    # finite delays on workers delta..n-1 force both pools to keep exactly
    # the undelayed subset -> decodes must be bit-identical
    pipe = _pipe(smoke, backend=backend)
    delta = pipe.specs[0].plan.delta
    delays = [0.0] * N
    for w in range(delta, N):
        delays[w] = 0.25
    st = StragglerModel(np.asarray(delays))
    outs = {}
    for pool in ("threads", "device"):
        p = _pipe(smoke, backend=backend)
        cluster = FcdccCluster(p.specs[0].plan, st, mode="threads",
                               backend=backend, interpret=True, pool=pool)
        try:
            cluster.load_pipeline(p, "lm")
            cache, nxt, pos = _prefilled(p, cfg, params, prompts)
            timings = []
            logits, toks, _ = p.run_decode_step_cluster(
                cluster, nxt, cache, pos, model="lm", timings=timings
            )
            assert all(t.used_workers == list(range(delta)) for t in timings)
            outs[pool] = (np.asarray(logits), np.asarray(toks))
        finally:
            cluster.shutdown()
    np.testing.assert_array_equal(outs["threads"][0], outs["device"][0])
    np.testing.assert_array_equal(outs["threads"][1], outs["device"][1])


def test_trace_bound_over_program_space(smoke):
    """Distinct worker trace signatures stay bounded by geometry x bucket
    per mode — timing-dependent survivor subsets and the decode inverse
    are runtime values, never trace keys."""
    pipe = _pipe(smoke, buckets=(1, 2, 4))
    assert pipe.num_geometries == 4  # qkv / wo / gateup / down
    assert pipe.program_trace_bound == 4 * 3
    per_mode = {}
    for cell in pipe.program_space():
        if cell.kind != "worker":
            continue
        per_mode.setdefault(cell.mode, set()).add(cell.trace_signature)
    assert set(per_mode) == {"direct", "cluster"}
    for mode, sigs in per_mode.items():
        assert len(sigs) <= pipe.program_trace_bound, (
            f"{mode}: {len(sigs)} worker signatures > bound "
            f"{pipe.program_trace_bound}"
        )


def test_decode_inverse_is_runtime_arg(smoke):
    """Same jitted decoder object serves every survivor subset: only the
    (Q, Q) inverse argument changes."""
    pipe = _pipe(smoke)
    assert pipe.decoder_fn(0) is pipe.decoder_fn(7)
    dms = [pipe.decode_matrix(0, ids) for ids in _subsets(N, 2)]
    assert len({dm.tobytes() for dm in dms}) > 1  # genuinely different
