"""Substrate: optimizer, schedules, compression, data, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import DataConfig, SyntheticTokens
from repro.optim import (
    AdamWConfig,
    apply_updates,
    compress_tree,
    compressed_bytes,
    cosine_with_warmup,
    global_norm,
    init_state,
)


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    state = init_state(params)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = apply_updates(params, g, state, AdamWConfig(clip_norm=1.0))
    assert float(m["grad_norm"]) > 1.0  # reported pre-clip


def test_schedule_shapes():
    s = cosine_with_warmup(jnp.int32(0), warmup=10, total=100)
    assert float(s) == 0.0
    s = cosine_with_warmup(jnp.int32(10), warmup=10, total=100)
    assert float(s) == pytest.approx(1.0)
    assert float(cosine_with_warmup(jnp.int32(100), warmup=10, total=100)) == pytest.approx(0.1)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 99), scheme=st.sampled_from(["int8", "topk"]))
def test_compression_error_feedback(seed, scheme):
    """Error feedback: accumulated (decompressed + residual) == raw sum."""
    rng = np.random.default_rng(seed)
    total_raw = np.zeros((32,), np.float32)
    total_dec = np.zeros((32,), np.float32)
    res = None
    for step in range(6):
        g = {"w": jnp.asarray(rng.standard_normal(32), jnp.float32)}
        total_raw += np.asarray(g["w"])
        dec, res = compress_tree(g, res, scheme, topk_frac=0.25)
        total_dec += np.asarray(dec["w"], np.float32)
    drift = np.abs(total_dec + np.asarray(res["w"]) - total_raw).max()
    assert drift < 1e-3  # residual carries exactly what compression dropped


def test_compressed_bytes_accounting():
    g = {"w": jnp.zeros((1000,))}
    assert compressed_bytes(g, "int8") == 1000
    assert compressed_bytes(g, "topk", 0.01) == 80


def test_data_deterministic_and_shard_disjoint():
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=8, seed=7)
    a = SyntheticTokens(cfg).batch(3)
    b = SyntheticTokens(cfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # different steps/shards differ
    c = SyntheticTokens(cfg).batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    s0 = SyntheticTokens(cfg, num_shards=2, shard=0).batch(3)
    s1 = SyntheticTokens(cfg, num_shards=2, shard=1).batch(3)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, tree)
        save(d, 2, jax.tree.map(lambda x: x * 2, tree))
        assert latest_step(d) == 2
        back = restore(d, 2, jax.tree.map(np.zeros_like, tree))
        np.testing.assert_allclose(np.asarray(back["a"]), np.asarray(tree["a"]) * 2)
        assert back["b"]["c"].dtype == np.int32

        ac = AsyncCheckpointer(d, keep=2)
        for s in (3, 4, 5):
            ac.submit(s, tree)
        ac.wait()
        assert latest_step(d) == 5
        steps = sorted(
            int(x.split("-")[1]) for x in os.listdir(d) if x.startswith("step-")
        )
        assert len(steps) <= 2  # gc keeps last 2


def test_checkpoint_elastic_reshard():
    """Restore under a (trivially) different sharding via device_put."""
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, tree)
        sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        back = restore(d, 1, tree, shardings={"w": sh})
        np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
