"""Round pipelining: multi-batch in-flight serving rounds.

The engine keeps up to ``pipeline_depth`` dispatched-but-uncollected
worker rounds in flight (dispatch batch B before collecting batch A) via
the cluster's dispatch/collect split (``dispatch_pipeline_layer`` /
``round_ready`` / ``collect_pipeline_layer``).

Covers: the split's non-blocking ``ready``/``collect(block=False)`` seam;
bit-identical fp32 parity between depth 1 and depths 2/4 for forced
fastest-delta survivor subsets across {lax, pallas} x {fused, unfused};
queue-wait ending at first *dispatch* (not admission); the window
actually reaching depth 2 on late admission with an earlier round in
flight; coalescing skipping mid-round batches; mid-flight cancellation
(shutdown without drain, unregister with rounds in flight); and the
shared-condition ``wait_many`` / HTTP 504 timeout path.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodedPipeline
from repro.core.pipeline import plan_layers
from repro.models.cnn import ConvL
from repro.runtime import FcdccCluster, StragglerModel
from repro.serving import CodedServer, Scheduler, ServingFrontend

RNG = np.random.default_rng(7)
N = 6

STACK = [
    ConvL("s1", 2, 8, 3, stride=1, padding=1, pool=2),
    ConvL("s2", 8, 8, 3, padding=1),
]

STACK_B = [
    ConvL("s1", 3, 8, 3, stride=1, padding=1, pool=2),
    ConvL("s2", 8, 4, 3, padding=1),
]


def _params(layers, seed=0):
    rng = np.random.default_rng(seed)
    return {
        l.name: jnp.asarray(
            rng.standard_normal((l.out_ch, l.in_ch, l.kernel, l.kernel))
            * (l.in_ch * l.kernel**2) ** -0.5,
            jnp.float32,
        )
        for l in layers
    }


def _pipeline(bucket_sizes=(2,), n=N, hw=12, backend="lax", fused=False,
              layers=STACK, seed=0):
    params = _params(layers, seed=seed)
    specs = plan_layers(layers, hw, n, default_kab=(2, 4))
    return CodedPipeline(specs, params, bucket_sizes=bucket_sizes,
                         backend=backend, fuse_transitions=fused)


def _images(count, ch=2, hw=12):
    return [jnp.asarray(RNG.standard_normal((ch, hw, hw)), jnp.float32)
            for _ in range(count)]


def _forced_survivors(pipe, n=N, delay=0.1):
    """Finite delays on workers delta..n-1: every round of every depth
    keeps exactly the undelayed subset, so decodes are bit-identical."""
    dm = max(spec.plan.delta for spec in pipe.specs)
    delays = np.zeros(n)
    delays[dm:] = delay
    return StragglerModel(delays), dm


# -- the dispatch/collect split (cluster seam) -----------------------------
def test_dispatch_collect_split_nonblocking_ready():
    """``dispatch_pipeline_layer`` returns a pending round whose readiness
    is observable without blocking, and ``collect(block=False)`` returns
    None while fewer than delta shards are in."""
    pipe = _pipeline()
    delays = np.full(N, 0.3)  # every worker sleeps: nothing ready at first
    cluster = FcdccCluster(pipe.specs[0].plan, StragglerModel(delays),
                           mode="threads")
    try:
        cluster.load_pipeline(pipe)
        x = jnp.asarray(RNG.standard_normal((2, 2, 12, 12)), jnp.float32)
        rnd = cluster.dispatch_pipeline_layer(0, x)
        assert not cluster.round_ready(rnd)
        assert cluster.collect(rnd.pending, rnd.spec.plan.delta,
                               block=False) is None
        deadline = time.perf_counter() + 30.0
        while not cluster.round_ready(rnd):
            assert time.perf_counter() < deadline
            time.sleep(0.01)
        y, timing = cluster.collect_pipeline_layer(rnd)
        ref, _ = cluster.run_pipeline_layer(0, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        assert len(timing.used_workers) == rnd.spec.plan.delta
    finally:
        cluster.shutdown()


def test_split_equals_run_pipeline_layer_bitwise():
    """collect(dispatch(...)) is bit-identical to run_pipeline_layer under
    a forced survivor subset (same shards, same fp32 reduction order)."""
    pipe = _pipeline()
    straggler, _ = _forced_survivors(pipe)
    cluster = FcdccCluster(pipe.specs[0].plan, straggler, mode="threads")
    try:
        cluster.load_pipeline(pipe)
        x = jnp.asarray(RNG.standard_normal((2, 2, 12, 12)), jnp.float32)
        y1, _ = cluster.collect_pipeline_layer(
            cluster.dispatch_pipeline_layer(0, x))
        y2, _ = cluster.run_pipeline_layer(0, x)
        assert np.array_equal(np.asarray(y1), np.asarray(y2))
    finally:
        cluster.shutdown()


# -- depth parity ----------------------------------------------------------
@pytest.mark.parametrize("backend", ["lax", "pallas"])
@pytest.mark.parametrize("fused", [False, True])
def test_depth_parity_forced_survivors(backend, fused):
    """The tentpole's correctness contract: with a forced fastest-delta
    subset, serving at pipeline_depth 2 and 4 is bit-identical fp32 to
    depth 1 — pipelining reorders scheduling, never math — and depth 1
    matches the undistributed pipeline within fp32 tolerance."""
    xs = _images(4)
    outs = {}
    for depth in (1, 2, 4):
        pipe = _pipeline(backend=backend, fused=fused)
        straggler, _ = _forced_survivors(pipe, delay=0.05)
        server = CodedServer(pipe, straggler, mode="threads",
                             pipeline_depth=depth)
        with server:
            outs[depth] = [np.asarray(h.result(timeout=120.0))
                           for h in server.submit_many(xs)]
    for depth in (2, 4):
        for a, b in zip(outs[1], outs[depth]):
            assert np.array_equal(a, b), (
                f"depth {depth} not bit-identical to depth 1 "
                f"({backend}, fused={fused})")
    ref_pipe = _pipeline(backend=backend, fused=fused)
    for x, y in zip(xs, outs[1]):
        np.testing.assert_allclose(
            y, np.asarray(ref_pipe.run(x[None]))[0], rtol=1e-4, atol=1e-4)


# -- queue-wait phase boundary --------------------------------------------
def test_queue_wait_ends_at_first_dispatch():
    """Admitted-but-undispatched time counts as QUEUE wait, not execute:
    with a serial window (depth 1) and a slow critical-path worker, the
    second request is admitted immediately but dispatched only after the
    first batch's two rounds finish — its queue wait must cover that span
    (the seed stamped start_t at admission, reporting ~0)."""
    pipe = _pipeline(bucket_sizes=(1,))
    dm = max(spec.plan.delta for spec in pipe.specs)
    delays = np.zeros(N)
    delays[dm - 1] = 0.08  # the delta-th shard: every round waits 0.08s
    server = CodedServer(pipe, StragglerModel(delays), mode="threads",
                         bucket_sizes=(1,), max_inflight=2, pipeline_depth=1)
    xs = _images(2)
    with server:
        handles = server.submit_many(xs)
        for h in handles:
            h.result(timeout=60.0)
    recs = {r.request_id: r for r in server.metrics.records()}
    first = recs[handles[0].request_id]
    second = recs[handles[1].request_id]
    # both were admitted at the same boundary; only the first dispatched
    assert first.queue_wait_s < 0.06, first.queue_wait_s
    assert second.queue_wait_s > 0.10, second.queue_wait_s
    # the first batch really did spend its two rounds executing
    assert first.execute_s > 0.12, first.execute_s


# -- the window fills ------------------------------------------------------
def test_late_admission_dispatches_while_round_in_flight():
    """A request arriving while an earlier batch's round is mid-flight is
    dispatched into the free window slot (depth 2) instead of waiting for
    the collect — the engine's observed window depth must reach 2."""
    pipe = _pipeline(bucket_sizes=(1,))
    delays = np.full(N, 0.15)  # slow rounds: the window visibly fills
    server = CodedServer(pipe, StragglerModel(delays), mode="threads",
                         bucket_sizes=(1,), pipeline_depth=2)
    xs = _images(2)
    with server:
        h1 = server.submit(xs[0])
        time.sleep(0.05)  # round 1 of batch 1 is in flight
        h2 = server.submit(xs[1])
        y1 = np.asarray(h1.result(timeout=60.0))
        y2 = np.asarray(h2.result(timeout=60.0))
        depth_seen = server.overlap_stats().max_depth
    assert depth_seen == 2, depth_seen
    ref = _pipeline(bucket_sizes=(1,))
    np.testing.assert_allclose(y1, np.asarray(ref.run(xs[0][None]))[0],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y2, np.asarray(ref.run(xs[1][None]))[0],
                               rtol=1e-4, atol=1e-4)


def test_overlap_stats_phases_recorded():
    """Every collected round leaves one phase tuple; the busy span closes
    when the window drains; serial_s is the sum of the four phases."""
    pipe = _pipeline(bucket_sizes=(1,))
    server = CodedServer(pipe, StragglerModel.none(N), mode="simulated",
                         bucket_sizes=(1,), pipeline_depth=2)
    xs = _images(3)
    with server:
        for h in server.submit_many(xs):
            h.result(timeout=60.0)
        stats = server.overlap_stats()
    assert stats.rounds == len(xs) * len(pipe.specs)
    assert stats.busy_wall_s > 0
    assert stats.serial_s == pytest.approx(
        stats.dispatch_s + stats.worker_s + stats.collect_s
        + stats.transition_s)
    assert np.isfinite(stats.overlap_efficiency)


# -- coalescing vs in-flight rounds ---------------------------------------
def test_coalesce_skips_dispatched_batches():
    """A batch whose round is in flight has stale ``x`` — it must never be
    merged; once its collect lands (dispatched=False) it merges again."""
    pipe = _pipeline(bucket_sizes=(1, 2, 4))
    sched = Scheduler(pipe.pad_to_bucket, max_batch=4, max_inflight=4)
    for x in _images(2):
        sched.queue.submit(x)
    a = sched.admit(limit=1)
    b = sched.admit(limit=1)
    assert a is not None and b is not None and len(sched.inflight) == 2
    a.dispatched = True
    assert sched.coalesce() == 0
    assert len(sched.inflight) == 2
    a.dispatched = False
    assert sched.coalesce() == 1
    assert len(sched.inflight) == 1 and sched.inflight[0].real == 2


# -- mid-flight cancellation ----------------------------------------------
def test_shutdown_no_drain_abandons_inflight_rounds():
    """shutdown(drain=False) with rounds in flight sheds the window
    immediately: requests fail with RuntimeError and the engine joins
    without waiting for the slow workers."""
    pipe = _pipeline(bucket_sizes=(1,))
    delays = np.full(N, 0.5)
    server = CodedServer(pipe, StragglerModel(delays), mode="threads",
                         bucket_sizes=(1,), pipeline_depth=2)
    server.start()
    handles = server.submit_many(_images(2))
    time.sleep(0.1)  # rounds dispatched, none collectable yet
    t0 = time.perf_counter()
    server.shutdown(drain=False, timeout=30.0)
    assert server._thread is None  # engine joined, not wedged
    for h in handles:
        with pytest.raises(RuntimeError, match="shut down"):
            h.result(timeout=5.0)
    assert time.perf_counter() - t0 < 10.0


def test_unregister_no_drain_with_round_in_flight():
    """unregister_model(drain=False) while the model has a round mid-
    flight: its requests are cancelled, and the engine finishes the
    orphaned collect through the PendingRound's captured pipeline — the
    other model keeps serving correctly afterwards."""
    pipe_a = _pipeline(bucket_sizes=(1,))
    pipe_b = _pipeline(bucket_sizes=(1,), layers=STACK_B, seed=3)
    delays = np.full(N, 0.3)
    server = CodedServer(straggler=StragglerModel(delays), mode="threads",
                         bucket_sizes=(1,), pipeline_depth=2)
    server.register_model("a", pipe_a)
    server.register_model("b", pipe_b)
    server.start()
    try:
        ha = server.submit(_images(1)[0], "a")
        time.sleep(0.1)  # a's first round is in flight
        server.unregister_model("a", drain=False)
        with pytest.raises(RuntimeError, match="unregistered"):
            ha.result(timeout=10.0)
        xb = _images(1, ch=3)[0]
        yb = np.asarray(server.submit(xb, "b").result(timeout=60.0))
        ref = _pipeline(bucket_sizes=(1,), layers=STACK_B, seed=3)
        np.testing.assert_allclose(yb, np.asarray(ref.run(xb[None]))[0],
                                   rtol=1e-4, atol=1e-4)
    finally:
        server.shutdown(timeout=60.0)


def test_unregister_drain_waits_for_inflight_round():
    """unregister_model(drain=True) with a round in flight serves the
    request to completion before tearing the model down."""
    pipe = _pipeline(bucket_sizes=(1,))
    delays = np.full(N, 0.2)
    server = CodedServer(straggler=StragglerModel(delays), mode="threads",
                         bucket_sizes=(1,), pipeline_depth=2)
    server.register_model("a", pipe)
    server.start()
    try:
        h = server.submit(_images(1)[0], "a")
        time.sleep(0.05)
        server.unregister_model("a", drain=True, timeout=60.0)
        assert h.done()
        y = np.asarray(h.result(timeout=1.0))
        assert np.all(np.isfinite(y))
    finally:
        server.shutdown(timeout=60.0)


# -- wait_many + HTTP timeout ---------------------------------------------
def test_wait_many_shared_condition():
    pipe = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(N), mode="simulated")
    with server:
        handles = server.submit_many(_images(3))
        assert server.wait_many(handles, timeout=60.0)
        assert all(h.done() for h in handles)
        # empty list: trivially done, no wait
        assert server.wait_many([], timeout=0.01)


def test_wait_many_times_out_on_wedged_engine():
    pipe = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(N), mode="simulated")
    gate = threading.Event()
    orig = server.cluster.dispatch_pipeline_layer

    def wedged(idx, x, model=None):
        gate.wait(30.0)
        return orig(idx, x, model)

    server.cluster.dispatch_pipeline_layer = wedged
    server.start()
    try:
        h = server.submit(_images(1)[0])
        t0 = time.perf_counter()
        assert not server.wait_many([h], timeout=0.3)
        assert 0.25 < time.perf_counter() - t0 < 5.0
    finally:
        gate.set()
        server.shutdown(timeout=60.0)


def test_http_504_when_result_times_out():
    """A request the engine cannot finish within ``result_timeout_s``
    answers 504 (the handler slot is released; the request itself is not
    cancelled)."""
    pipe = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(N), mode="simulated")
    gate = threading.Event()
    orig = server.cluster.dispatch_pipeline_layer

    def wedged(idx, x, model=None):
        gate.wait(30.0)
        return orig(idx, x, model)

    server.cluster.dispatch_pipeline_layer = wedged
    frontend = ServingFrontend(server, port=0, result_timeout_s=0.5)
    with frontend:
        body = json.dumps(
            {"input": np.zeros((2, 12, 12)).tolist()}).encode()
        req = urllib.request.Request(
            f"{frontend.url}/v1/infer", data=body,
            headers={"Content-Type": "application/json"})
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(req, timeout=30.0)
        assert exc.value.code == 504
        gate.set()  # un-wedge so the frontend's managed drain completes


def test_http_bounded_handler_pool():
    """handler_pool=1 serializes connections through ONE pooled thread
    (the stock mixin spawned one thread per connection); requests still
    all answer, and pool_size < 1 is rejected."""
    pipe = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(N), mode="simulated")
    with pytest.raises(ValueError, match="pool_size"):
        ServingFrontend(CodedServer(_pipeline(), mode="simulated"),
                        port=0, handler_pool=0)
    frontend = ServingFrontend(server, port=0, handler_pool=1)
    with frontend:
        x = np.asarray(_images(1)[0]).tolist()
        for _ in range(3):
            body = json.dumps({"input": x}).encode()
            req = urllib.request.Request(
                f"{frontend.url}/v1/infer", data=body,
                headers={"Content-Type": "application/json"})
            with urllib.request.urlopen(req, timeout=60.0) as resp:
                assert resp.status == 200
                payload = json.loads(resp.read())
        assert payload["shape"] == list(
            np.asarray(server.models["default"].pipeline.run(
                jnp.asarray(x, jnp.float32)[None]))[0].shape)
        # /v1/stats surfaces the per-phase overlap block per README
        with urllib.request.urlopen(f"{frontend.url}/v1/stats",
                                    timeout=30.0) as resp:
            stats = json.loads(resp.read())
        ov = stats["aggregate"]["overlap"]
        assert ov["rounds"] == 3 * len(pipe.specs)
        assert ov["overlap_efficiency"] is None or ov["overlap_efficiency"] > 0
        assert "overlap" in stats["per_model"]["default"]


# -- construction validation ----------------------------------------------
def test_pipeline_depth_validation():
    for bad in (0, -1, 1.5, "2"):
        with pytest.raises(ValueError, match="pipeline_depth"):
            CodedServer(_pipeline(), mode="simulated", pipeline_depth=bad)
    # depth 1 is the classic serial loop and still serves correctly
    pipe = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(N), mode="simulated",
                         pipeline_depth=1)
    x = _images(1)[0]
    with server:
        y = np.asarray(server.submit(x).result(timeout=60.0))
    ref = _pipeline()
    np.testing.assert_allclose(y, np.asarray(ref.run(x[None]))[0],
                               rtol=1e-4, atol=1e-4)
