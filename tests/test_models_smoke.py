"""Per-arch reduced-config smoke tests: forward/train-step/decode on CPU,
shape + NaN assertions (the FULL configs are exercised via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_bundle


def _batch_for(bundle, b=2, s=16, key=0):
    k = jax.random.PRNGKey(key)
    batch = {
        "tokens": jax.random.randint(k, (b, s), 0, bundle.cfg.vocab),
        "labels": jax.random.randint(k, (b, s), 0, bundle.cfg.vocab),
    }
    if bundle.family == "encdec":
        batch["frames"] = jax.random.normal(
            k, (b, bundle.cfg.enc_len, bundle.cfg.d_model), jnp.float32
        )
    if bundle.family == "vlm":
        batch["prefix"] = jax.random.normal(k, (b, 8, bundle.cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    bundle = get_bundle(arch, smoke=True)
    params = bundle.init(jax.random.PRNGKey(0), jnp.float32)
    batch = _batch_for(bundle)

    # forward/prefill
    logits = bundle.prefill_fn(params, batch)
    exp_s = 16 + (8 if bundle.family == "vlm" else 0)
    assert logits.shape == (2, exp_s, bundle.cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    # one train step (loss + grads finite)
    loss, grads = jax.value_and_grad(bundle.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    assert not any(bool(jnp.isnan(g).any()) for g in jax.tree.leaves(grads))

    # one decode step against a fresh cache
    cache = bundle.make_cache(2, 32, jnp.float32)
    l1, cache2 = bundle.decode_fn(
        params, cache, {"tokens": batch["tokens"][:, :1], "pos": jnp.int32(0)}
    )
    assert l1.shape == (2, 1, bundle.cfg.vocab)
    assert not bool(jnp.isnan(l1).any())


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "whisper-medium"])
def test_decode_matches_forward(arch):
    """Step-by-step decode reproduces the teacher-forced forward logits."""
    bundle = get_bundle(arch, smoke=True)
    params = bundle.init(jax.random.PRNGKey(0), jnp.float32)
    batch = _batch_for(bundle, s=10)
    ref = bundle.prefill_fn(params, batch)

    cache = bundle.make_cache(2, 16, jnp.float32)
    if bundle.family == "encdec":
        from repro.models import whisper

        enc = whisper.encode(params, bundle.cfg, batch["frames"])
        cache = whisper.precompute_cross_kv(params, bundle.cfg, enc, cache)
    outs = []
    for t in range(10):
        lg, cache = bundle.decode_fn(
            params, cache, {"tokens": batch["tokens"][:, t : t + 1], "pos": jnp.int32(t)}
        )
        outs.append(lg[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(ref), atol=2e-3
    )


def test_flash_paths_consistent():
    import dataclasses

    from repro.models.common import schema_init
    from repro.models.transformer import LMConfig, forward, lm_schema

    base = LMConfig(name="t", layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    head_dim=16, d_ff=128, vocab=97, flash_chunk=8)
    params = schema_init(lm_schema(base), jax.random.PRNGKey(1), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, 97)
    tri = forward(params, base, toks)
    rect = forward(params, dataclasses.replace(base, flash_block_skip=False), toks)
    direct = forward(params, dataclasses.replace(base, flash_chunk=10**9), toks)
    np.testing.assert_allclose(np.asarray(tri), np.asarray(rect), atol=1e-4)
    np.testing.assert_allclose(np.asarray(tri), np.asarray(direct), atol=2e-3)


def test_moe_matches_dense_reference():
    from repro.models.common import schema_init
    from repro.models.moe import MoEConfig, moe_ffn, moe_schema

    cfg = MoEConfig(n_routed=8, top_k=2, d_model=32, d_ff_expert=16,
                    n_shared=1, capacity_factor=4.0, dispatch_groups=4)
    w = schema_init(moe_schema(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    y = moe_ffn(w, x, cfg)
    logits = x @ w["router"]
    probs = jax.nn.softmax(logits, -1)
    gw, ge = jax.lax.top_k(probs, 2)
    gw = gw / gw.sum(-1, keepdims=True)
    allout = jnp.stack(
        [
            (jax.nn.silu(x @ w["w_gate"][i]) * (x @ w["w_up"][i])) @ w["w_down"][i]
            for i in range(8)
        ],
        1,
    )
    y_ref = (allout[jnp.arange(64)[:, None], ge] * gw[..., None]).sum(1)
    s = w["shared"]
    y_ref = y_ref + (jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])) @ s["w_down"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 overflow tokens are dropped, not corrupted."""
    from repro.models.common import schema_init
    from repro.models.moe import MoEConfig, moe_ffn, moe_schema

    cfg = MoEConfig(n_routed=4, top_k=1, d_model=16, d_ff_expert=8,
                    capacity_factor=0.25)
    w = schema_init(moe_schema(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16), jnp.float32)
    y = moe_ffn(w, x, cfg)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
    # at least one token must have been dropped (zero output row)
    assert bool(jnp.any(jnp.all(y == 0.0, axis=-1)))
