"""Static-analysis layer: every rule has a seeded violation (positive)
and the repo itself stays clean under ``--strict`` (negative).

The contract-rule positives run on tiny synthetic jitted programs (cheap
to trace); one real pipeline config covers the repo-clean direction so
the whole file stays fast — the full 12-config matrix is the CI gate's
job (``python -m repro.analysis --strict`` in scripts/ci.sh), not the
unit suite's.
"""

import json
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import concurrency, contracts
from repro.analysis.findings import Report, Severity

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
VIOLATIONS = os.path.join(FIXTURES, "conc_violations.py")
CLEAN = os.path.join(FIXTURES, "conc_clean.py")


# -- concurrency lint: seeded violations ------------------------------------

@pytest.fixture(scope="module")
def seeded():
    return concurrency.run(paths=[VIOLATIONS])


def _rules(report: Report, rule: str):
    return [f for f in report.findings if f.rule == rule]


def test_conc_guard_fires_on_unlocked_mutations(seeded):
    found = _rules(seeded, "CONC-GUARD")
    msgs = " | ".join(f.message for f in found)
    assert "GuardViolation.bad" in msgs
    assert "bad_global_write" in msgs
    # two field mutations in bad() plus the module-global write
    assert len(found) == 3


def test_conc_guard_respects_lock_and_interproc_entry(seeded):
    msgs = " | ".join(f.message for f in _rules(seeded, "CONC-GUARD"))
    assert "GuardViolation.ok" not in msgs
    # _apply mutates state but every call site holds the lock
    assert "InterprocHeld" not in msgs


def test_conc_guard_suppression(seeded):
    assert not any(
        "suppressed" in f.message for f in _rules(seeded, "CONC-GUARD")
    )


def test_conc_guard_unknown(seeded):
    found = _rules(seeded, "CONC-GUARD-UNKNOWN")
    assert len(found) == 1
    assert "_no_such_lock" in found[0].message


def test_conc_self_deadlock_lexical_and_interproc(seeded):
    found = _rules(seeded, "CONC-SELF-DEADLOCK")
    msgs = " | ".join(f.message for f in found)
    assert "SelfDeadlock" in msgs
    assert "_acquires" in msgs  # the held-across-call variant
    assert "ReentrantOk" not in msgs


def test_conc_order_cycle(seeded):
    found = _rules(seeded, "CONC-ORDER")
    assert found, "lock-order cycle _a/_b not detected"
    assert any("OrderCycle._a" in f.message and "OrderCycle._b" in f.message
               for f in found)


def test_conc_wait_loop(seeded):
    found = _rules(seeded, "CONC-WAIT-LOOP")
    assert len(found) == 1  # bad_wait only; good_wait + Event.wait pass
    assert "WaitWithoutLoop.cv" in found[0].message


def test_conc_thread_lifecycle(seeded):
    found = _rules(seeded, "CONC-THREAD-LIFECYCLE")
    assert len(found) == 1
    assert "LeakedThreads" in found[0].message


def test_conc_clean_fixture_is_clean():
    report = concurrency.run(paths=[CLEAN])
    assert report.findings == []


def test_repo_concurrency_strict_clean():
    """The serving/runtime stack itself must pass the lint in strict mode."""
    report = concurrency.run(root=os.path.join(os.path.dirname(__file__), ".."))
    assert not report.failed(strict=True), report.render_text(show_info=True)
    # the annotations are live, not decorative: guards bound and checked
    assert report.stats["guarded_fields_checked"] >= 20
    assert report.stats["locks_discovered"] >= 8


# -- contract rules: synthetic seeded violations ----------------------------

def _cell(fn, args, *, allowed=(), donate=()):
    return types.SimpleNamespace(
        fn=fn, args=tuple(args), cell_id="synthetic",
        allowed_const_shapes=tuple(allowed), donate_argnums=tuple(donate),
    )


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_baked_const_positive():
    baked = jnp.asarray(np.ones((8, 8), np.float32))
    cell = _cell(jax.jit(lambda x: x @ baked), [_f32(4, 8)])
    found = contracts.check_jaxpr_contracts(cell)
    assert any(f.rule == "JIT-BAKED-CONST" and f.severity == Severity.ERROR
               for f in found)


def test_baked_const_allowed_shape_and_small_consts_pass():
    baked = jnp.asarray(np.ones((8, 8), np.float32))
    cell = _cell(jax.jit(lambda x: x @ baked), [_f32(4, 8)],
                 allowed=[(8, 8)])
    assert not contracts.check_jaxpr_contracts(cell)
    eps = jnp.asarray(np.float32(1e-6))
    cell = _cell(jax.jit(lambda x: x + eps), [_f32(4, 8)])
    assert not contracts.check_jaxpr_contracts(cell)


def test_f64_positive():
    from jax.experimental import enable_x64

    with enable_x64():
        cell = _cell(
            lambda x: x.astype(jnp.float64).sum(),
            [jax.ShapeDtypeStruct((4,), jnp.float32)],
        )
        found = contracts.check_jaxpr_contracts(cell)
    assert any(f.rule == "JIT-F64" and f.severity == Severity.ERROR
               for f in found)


def test_weak_type_positive():
    cell = _cell(lambda x: jnp.asarray(2.0), [_f32(2)])
    found = contracts.check_jaxpr_contracts(cell)
    assert any(f.rule == "JIT-WEAK-TYPE" and f.severity == Severity.WARNING
               for f in found)


def test_host_callback_positive():
    def fn(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct(x.shape, x.dtype), x
        )

    found = contracts.check_jaxpr_contracts(_cell(fn, [_f32(3)]))
    assert any(f.rule == "JIT-HOST-CALLBACK" and f.severity == Severity.ERROR
               for f in found)


def test_donation_missing_positive():
    # the cell CLAIMS argument 0 is donated, but the jitted fn never was
    cell = _cell(jax.jit(lambda x: x + 1.0), [_f32(8, 8)], donate=[0])
    found = contracts.check_donation(cell)
    assert any(f.rule == "JIT-DONATION" and f.severity == Severity.ERROR
               for f in found)


def test_donation_wired_through_passes():
    cell = _cell(jax.jit(lambda x: x + 1.0, donate_argnums=(0,)),
                 [_f32(8, 8)], donate=[0])
    found = contracts.check_donation(cell)
    assert not [f for f in found if f.severity == Severity.ERROR]


def test_donation_no_matching_output_is_info():
    cell = _cell(jax.jit(lambda x: x.sum(), donate_argnums=(0,)),
                 [_f32(8, 8)], donate=[0])
    found = contracts.check_donation(cell)
    assert [f for f in found if f.severity == Severity.INFO]
    assert not [f for f in found if f.severity == Severity.ERROR]


# -- trace bound + repo-clean on one real config ----------------------------

@pytest.fixture(scope="module")
def lenet_cfg():
    return contracts.ContractConfig("lenet5", "lax", fused=True)


@pytest.fixture(scope="module")
def lenet_pipe_cells(lenet_cfg):
    pipe = contracts.build_pipeline(lenet_cfg)
    return pipe, list(pipe.program_space())


def test_trace_bound_holds_on_real_pipeline(lenet_pipe_cells):
    pipe, cells = lenet_pipe_cells
    report = contracts.check_trace_bound(pipe, cells, "lenet5")
    assert not report.findings, report.render_text()
    # exhaustive enumeration actually exercised the bound, not vacuous
    assert report.stats["lenet5/direct/traces"] > 0
    assert report.stats["lenet5/cluster/traces"] > 0


def test_trace_bound_positive(lenet_pipe_cells):
    import dataclasses

    pipe, cells = lenet_pipe_cells
    # mint bound+1 impostor signatures in one mode: must trip the proof
    workers = [c for c in cells if c.kind == "worker"]
    extra = [
        dataclasses.replace(workers[0], cache_key=("impostor", i))
        for i in range(pipe.program_trace_bound + 1)
    ]
    report = contracts.check_trace_bound(pipe, list(cells) + extra, "seeded")
    assert any(f.rule == "TRACE-BOUND" and f.severity == Severity.ERROR
               for f in report.findings)


def test_repo_contracts_clean_one_config(lenet_cfg):
    """One real config end-to-end: no errors, no warnings (info allowed —
    CPU donation geometry notes)."""
    report = contracts.analyze_config(lenet_cfg)
    hard = [f for f in report.findings
            if f.severity in (Severity.ERROR, Severity.WARNING)]
    assert not hard, "\n".join(f.render() for f in hard)
    assert report.stats["lenet5/lax/fused/programs_checked"] > 0


# -- CLI --------------------------------------------------------------------

def test_cli_json_and_exit_code(tmp_path, capsys):
    from repro.analysis.__main__ import main

    out = tmp_path / "findings.json"
    code = main(["--only", "concurrency", "--strict", "--format", "json",
                 "--json-out", str(out)])
    assert code == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["counts"]["error"] == 0
    assert json.loads(out.read_text())["counts"] == payload["counts"]


def test_cli_strict_fails_on_findings(monkeypatch, capsys):
    from repro.analysis import __main__ as cli

    monkeypatch.setattr(
        concurrency, "DEFAULT_SCOPE", (VIOLATIONS,), raising=True
    )
    code = cli.main(["--only", "concurrency", "--strict"])
    capsys.readouterr()
    assert code == 1
