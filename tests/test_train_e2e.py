"""End-to-end training driver: loss decreases, checkpoint/restart works,
microbatching is numerically consistent with full-batch grads."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.train import train


def test_train_loss_decreases():
    losses = train("smollm-135m", steps=40, batch=8, seq=64, smoke=True)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.02


def test_checkpoint_restart_continues():
    with tempfile.TemporaryDirectory() as d:
        l1 = train("smollm-135m", steps=20, batch=4, seq=32, smoke=True,
                   ckpt_dir=d, ckpt_every=10)
        # restart: should resume from step 20 and continue to 30
        l2 = train("smollm-135m", steps=30, batch=4, seq=32, smoke=True,
                   ckpt_dir=d, ckpt_every=10)
        assert len(l2) == 10  # only steps 20..30 executed


def test_microbatch_grads_match_full_batch():
    from repro.configs import get_bundle
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import make_host_mesh
    from repro.optim import init_state

    bundle = get_bundle("smollm-135m", smoke=True)
    mesh = make_host_mesh()
    with jax.set_mesh(mesh):
        params = bundle.init(jax.random.PRNGKey(0), jnp.float32)
        opt = init_state(params)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 256),
        }
        f1, _, _ = steps_mod.build_train_step(
            bundle, mesh, steps_mod.TrainConfig(microbatches=1, fsdp=False)
        )
        f4, _, _ = steps_mod.build_train_step(
            bundle, mesh, steps_mod.TrainConfig(microbatches=4, fsdp=False)
        )
        p1, _, m1 = f1(params, opt, batch)
        p4, _, m4 = f4(params, opt, batch)
    # losses are means over microbatches == full-batch mean
    assert np.isclose(float(m1["loss"]), float(m4["loss"]), atol=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_grad_compression_train_step_runs():
    losses = train("smollm-135m", steps=5, batch=4, seq=32, smoke=True,
                   grad_compression="int8")
    assert all(np.isfinite(l) for l in losses)
