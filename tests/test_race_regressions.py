"""Regression tests for the race fixes that rode along with the
concurrency lint (each corresponds to a lock added/mutation wrapped in
serving/ or runtime/).

These are stress-style tests: before the fixes they could fail (or fail
intermittently under load); after, the asserted invariants are
deterministic — identity of lazily-created singletons, absence of
resurrected accounting keys, absence of exceptions racing create vs
shutdown.
"""

import threading

import numpy as np
import pytest

from repro.runtime.cluster import FcdccCluster
from repro.runtime.devicepool import StragglerModel, ThreadWorkerPool
from repro.serving.scheduler import MultiScheduler, Scheduler


def _pad_identity(x):
    return x, int(x.shape[0])


def _hammer(threads):
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not any(t.is_alive() for t in threads)


def test_cluster_pool_created_once_under_contention():
    """FcdccCluster._pool_impl: the lazy pool build is now locked — every
    thread must observe the SAME pool object (previously two threads could
    each build a pool; one leaked with its executors)."""
    from repro.core.fcdcc import FcdccPlan

    cluster = FcdccCluster(FcdccPlan(n=4, k_a=2, k_b=2),
                           StragglerModel.none(4), mode="simulated")
    got = []
    barrier = threading.Barrier(8)

    def grab():
        barrier.wait(timeout=10.0)
        got.append(cluster._pool_impl())

    _hammer([threading.Thread(target=grab) for _ in range(8)])
    assert len({id(p) for p in got}) == 1
    cluster.shutdown()


def test_thread_pool_create_vs_shutdown_race():
    """ThreadWorkerPool: racing _ensure_pools against shutdown must never
    raise, and the final shutdown must leave no executor behind."""
    pool = ThreadWorkerPool(4, StragglerModel.none(4), mode="threads")
    errors = []

    def churn():
        try:
            for _ in range(50):
                pool._ensure_pools()
                pool.shutdown()
        except Exception as e:  # pragma: no cover - the regression
            errors.append(e)

    _hammer([threading.Thread(target=churn) for _ in range(4)])
    pool.shutdown()
    assert not errors
    assert pool._pools is None


def test_multischeduler_served_rounds_not_resurrected():
    """MultiScheduler.next_batch accounting: the served_rounds increment
    now happens under the condition, so a concurrent remove_model can
    never resurrect the removed model's counter."""
    ms = MultiScheduler()
    x = np.zeros((3, 8, 8), np.float32)
    stop = threading.Event()

    def engine():
        while not stop.is_set():
            ms.admit()
            picked = ms.next_batch()
            if picked is not None:
                name, batch = picked
                ms.retire(name, batch)

    t = threading.Thread(target=engine, daemon=True)
    t.start()
    try:
        for round_i in range(30):
            name = f"m{round_i}"
            sched = ms.add_model(name, _pad_identity, max_batch=4)
            for _ in range(3):
                sched.submit(x)
            sched.cancel_all(RuntimeError("test teardown"))
            ms.remove_model(name)
            assert name not in ms.served_rounds, (
                f"removed model {name!r} resurrected in served_rounds"
            )
    finally:
        stop.set()
        t.join(timeout=10.0)
    assert not t.is_alive()


def test_scheduler_close_fence_concurrent_idempotent():
    """Scheduler.close/fence now write under the lock; concurrent callers
    stay idempotent and a submit after close always refuses."""
    sched = Scheduler(_pad_identity, max_batch=4, name="m")
    barrier = threading.Barrier(6)

    def closer():
        barrier.wait(timeout=10.0)
        sched.close()
        sched.fence()

    _hammer([threading.Thread(target=closer) for _ in range(6)])
    assert sched.closed and sched.fenced
    with pytest.raises(RuntimeError):
        sched.submit(np.zeros((3, 8, 8), np.float32))


def test_device_pool_program_identity_under_contention():
    """DeviceWorkerPool.program: concurrent get-or-create for the same
    (key, device) must return one jit object (per-device trace accounting
    depends on it)."""
    from repro.runtime.devicepool import DeviceWorkerPool

    pool = DeviceWorkerPool(2, StragglerModel.none(2))
    got = []
    barrier = threading.Barrier(8)

    def grab(i):
        barrier.wait(timeout=10.0)
        got.append(pool.program(("k",), lambda a: a + 1, i % 2))

    _hammer([threading.Thread(target=grab, args=(i,)) for i in range(8)])
    per_dev = {}
    for i, fn in enumerate(got):
        per_dev.setdefault(pool.devices[i % 2], set()).add(id(fn))
    assert all(len(s) == 1 for s in per_dev.values())
    pool.shutdown()
