"""CRME code construction: structure, invertibility, conditioning."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.baselines import make_poly_codes, poly_recovery_matrix, real_points
from repro.core.crme import (
    condition_number,
    make_axis_codes,
    next_odd,
    recovery_matrix,
    rotation_matrix,
)


def test_next_odd():
    assert next_odd(4) == 5 and next_odd(5) == 5 and next_odd(18) == 19


def test_rotation_matrix_orthogonal():
    r = rotation_matrix(0.7)
    assert np.allclose(r @ r.T, np.eye(2), atol=1e-12)
    assert np.isclose(np.linalg.det(r), 1.0)


def test_rotation_power_structure():
    theta = 2 * np.pi / 7
    a, _ = make_axis_codes(4, 2, 6, 7)
    # block (a_idx, j) must equal R^(j*a_idx)
    for ai in range(2):
        for j in range(6):
            blk = a.matrix[2 * ai : 2 * ai + 2, 2 * j : 2 * j + 2]
            assert np.allclose(blk, np.linalg.matrix_power(rotation_matrix(theta), j * ai))


@pytest.mark.parametrize("k_a,k_b,n", [
    (2, 2, 2), (2, 4, 4), (4, 4, 6), (2, 32, 20), (8, 4, 10), (1, 8, 5),
    (8, 1, 5), (1, 1, 3), (4, 8, 8), (6, 4, 8),
])
def test_recovery_invertible_all_subsets(k_a, k_b, n):
    """Any delta-subset of workers must give a full-rank recovery matrix."""
    import itertools

    a, b = make_axis_codes(k_a, k_b, n)
    delta = (k_a * k_b) // (a.ell * b.ell)
    rng = np.random.default_rng(0)
    subsets = list(itertools.combinations(range(n), delta))
    if len(subsets) > 30:
        subsets = [tuple(sorted(rng.choice(n, delta, replace=False))) for _ in range(30)]
    for sub in subsets:
        e = recovery_matrix(a, b, sub)
        assert np.linalg.matrix_rank(e) == k_a * k_b, (sub, np.linalg.cond(e))


def test_crme_conditioning_beats_real_vandermonde():
    """The paper's Fig. 4: CRME condition number is orders of magnitude
    below real-Vandermonde at (40, 32)."""
    n, delta = 40, 32
    a, b = make_axis_codes(2, 2 * delta, n)
    workers = list(range(delta))
    c_crme = condition_number(recovery_matrix(a, b, workers))
    pa, pb = make_poly_codes(2, delta // 2, n, real_points(n))
    c_poly = condition_number(poly_recovery_matrix(pa, pb, workers))
    assert c_crme < 1e8
    assert c_poly / c_crme > 1e6


@settings(max_examples=25, deadline=None)
@given(
    k_a=st.sampled_from([1, 2, 4, 6]),
    k_b=st.sampled_from([1, 2, 4, 8]),
    extra=st.integers(0, 4),
    seed=st.integers(0, 999),
)
def test_recovery_invertible_property(k_a, k_b, extra, seed):
    ell = (1 if k_a == 1 else 2) * (1 if k_b == 1 else 2)
    delta = (k_a * k_b) // ell
    n = delta + extra
    a, b = make_axis_codes(k_a, k_b, n)
    rng = np.random.default_rng(seed)
    sub = sorted(rng.choice(n, delta, replace=False).tolist())
    e = recovery_matrix(a, b, sub)
    assert np.linalg.matrix_rank(e) == k_a * k_b


def test_delta_exceeds_n_rejected():
    with pytest.raises(ValueError):
        make_axis_codes(8, 8, 4)  # delta=16 > n=4


def test_odd_k_rejected():
    with pytest.raises(ValueError):
        make_axis_codes(3, 2, 4)
