"""APCP/KCCP partition geometry properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.partition import (
    ConvGeometry,
    apcp_partition,
    kccp_partition,
    merge_output,
)


@settings(max_examples=40, deadline=None)
@given(
    h=st.integers(6, 40),
    w=st.integers(4, 20),
    k_a=st.sampled_from([1, 2, 4, 8]),
    kh=st.sampled_from([1, 3, 5]),
    s=st.sampled_from([1, 2]),
    p=st.sampled_from([0, 1, 2]),
)
def test_apcp_geometry(h, w, k_a, kh, s, p):
    """Eq. (24)/(25): slice height/stride produce exactly H'/k_a rows each."""
    if h + 2 * p < kh:
        return
    geo = ConvGeometry(2, 4, h, w, kh, kh if kh <= w + 2 * p else 1, s, p, k_a, 1)
    if geo.kernel_w > geo.padded_w:
        return
    x = jnp.arange(2 * h * w, dtype=jnp.float32).reshape(2, h, w)
    parts = apcp_partition(x, geo)
    assert parts.shape == (k_a, 2, geo.h_hat, geo.padded_w)
    # each slice convolves to exactly out_h_block rows
    assert (geo.h_hat - geo.kernel_h) // geo.stride + 1 == geo.out_h_block
    # slices tile the output: starts step by s_hat = out_h_block * stride
    assert geo.s_hat == geo.out_h_block * geo.stride


def test_apcp_slices_match_original_rows():
    geo = ConvGeometry(1, 1, 10, 10, 3, 3, 1, 0, 2, 1)
    x = jnp.arange(100, dtype=jnp.float32).reshape(1, 10, 10)
    parts = apcp_partition(x, geo)
    np.testing.assert_array_equal(np.asarray(parts[0][0]), np.asarray(x[0, : geo.h_hat]))
    np.testing.assert_array_equal(
        np.asarray(parts[1][0, : 10 - geo.s_hat]), np.asarray(x[0, geo.s_hat :])
    )


def test_kccp_partition_and_padding():
    geo = ConvGeometry(3, 10, 8, 8, 3, 3, 1, 1, 1, 4)  # N=10 pads to 12
    k = jnp.arange(10 * 3 * 9, dtype=jnp.float32).reshape(10, 3, 3, 3)
    parts = kccp_partition(k, geo)
    assert parts.shape == (4, 3, 3, 3, 3)
    np.testing.assert_array_equal(np.asarray(parts[0]), np.asarray(k[:3]))
    assert float(jnp.sum(parts[3, 2:])) == 0.0  # zero padding


def test_merge_roundtrip():
    geo = ConvGeometry(1, 6, 12, 5, 3, 3, 1, 1, 3, 2)
    y = jnp.arange(
        geo.out_c_padded * geo.out_h_padded * geo.out_w, dtype=jnp.float32
    ).reshape(geo.out_c_padded, geo.out_h_padded, geo.out_w)
    # split into blocks the same way workers produce them, then merge
    blocks = []
    for a in range(geo.k_a):
        for b in range(geo.k_b):
            blocks.append(
                y[
                    b * geo.out_c_block : (b + 1) * geo.out_c_block,
                    a * geo.out_h_block : (a + 1) * geo.out_h_block,
                ]
            )
    merged = merge_output(jnp.stack(blocks), geo)
    np.testing.assert_array_equal(
        np.asarray(merged), np.asarray(y[: geo.out_channels, : geo.out_h])
    )
