"""Numerical-stability claims (paper Fig. 3/4) at test scale."""
import numpy as np

from repro.core.baselines import (
    chebyshev_points,
    make_poly_codes,
    poly_recovery_matrix,
    real_points,
)
from repro.core.crme import condition_number, make_axis_codes, recovery_matrix


def _worst_cond(n, delta, maker, trials=20, seed=0):
    rng = np.random.default_rng(seed)
    worst = 0.0
    for _ in range(trials):
        sub = sorted(rng.choice(n, delta, replace=False).tolist())
        worst = max(worst, maker(sub))
    return worst


def test_condition_number_ordering():
    """CRME < Chebyshev < real-Vandermonde, paper Fig. 4 ordering."""
    n, delta = 20, 16
    a, b = make_axis_codes(2, 2 * delta, n)
    crme = _worst_cond(n, delta, lambda s: condition_number(recovery_matrix(a, b, s)))
    pa, pb = make_poly_codes(2, delta // 2, n, real_points(n))
    vand = _worst_cond(n, delta, lambda s: np.linalg.cond(poly_recovery_matrix(pa, pb, s)))
    ca, cb = make_poly_codes(2, delta // 2, n, chebyshev_points(n))
    cheb = _worst_cond(n, delta, lambda s: np.linalg.cond(poly_recovery_matrix(ca, cb, s)))
    assert crme < cheb < vand


def test_crme_mse_tiny_in_float64():
    """Paper Table III: MSE ~1e-27 scale decode error in f64."""
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import CodedConv2d, ConvGeometry, FcdccPlan

    rng = np.random.default_rng(0)
    plan = FcdccPlan(n=20, k_a=2, k_b=32)
    geo = ConvGeometry(8, 64, 24, 24, 3, 3, 1, 1, 2, 32)
    layer = CodedConv2d(plan, geo)
    x = jnp.asarray(rng.standard_normal((8, 24, 24)))
    k = jnp.asarray(rng.standard_normal((64, 8, 3, 3)))
    y = layer.run_simulated(x, k, list(range(4, 20)))
    ref = jax.lax.conv_general_dilated(
        x[None], k, (1, 1), ((1, 1), (1, 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    mse = float(jnp.mean((y - ref) ** 2))
    assert mse < 1e-20, mse
    jax.config.update("jax_enable_x64", False)
