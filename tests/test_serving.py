"""The coded serving engine: CodedServer + scheduler + metrics + frontend.

Covers: served results match the pipeline's own output; bucketed batch
assembly keeps the jit program count bounded by the *bucket* count while
request batch sizes vary; continuous admission at layer boundaries;
``run_prepared`` equivalence with ``run``; the cluster's ``submit``/
``collect`` split (persistent per-worker pool, worker_times snapshot);
straggler resilience end-to-end through the server; metrics math; and the
multi-model engine — shared-pool isolation, namespaced filter caches,
fair-share scheduling, equal-depth coalescing, and the HTTP front-end
round trip.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodedPipeline, FcdccPlan
from repro.core.pipeline import plan_layers
from repro.models.cnn import ConvL
from repro.runtime import ClusterDegraded, FcdccCluster, StragglerModel
from repro.serving import (
    CodedServer,
    MetricsCollector,
    RequestRecord,
    ServingFrontend,
    percentile,
)

RNG = np.random.default_rng(0)

STACK = [
    ConvL("s1", 2, 8, 3, stride=1, padding=1, pool=2),
    ConvL("s2", 8, 8, 3, padding=1),
]

# a second model: SAME layer names as STACK, different channels — the
# shared-cluster namespacing must keep the two models' filters apart
STACK_B = [
    ConvL("s1", 3, 8, 3, stride=1, padding=1, pool=2),
    ConvL("s2", 8, 4, 3, padding=1),
]


def _params(layers, seed=0):
    rng = np.random.default_rng(seed)
    return {
        l.name: jnp.asarray(
            rng.standard_normal((l.out_ch, l.in_ch, l.kernel, l.kernel))
            * (l.in_ch * l.kernel**2) ** -0.5,
            jnp.float32,
        )
        for l in layers
    }


def _pipeline(bucket_sizes=(1, 2, 4), n=6, hw=12):
    params = _params(STACK)
    specs = plan_layers(STACK, hw, n, default_kab=(2, 4))
    return CodedPipeline(specs, params, bucket_sizes=bucket_sizes), params


def _images(count, hw=12):
    return [jnp.asarray(RNG.standard_normal((2, hw, hw)), jnp.float32)
            for _ in range(count)]


# -- bucketing ------------------------------------------------------------
def test_bucketize_and_pad():
    pipe, _ = _pipeline(bucket_sizes=(1, 2, 4))
    assert pipe.bucket_sizes == (1, 2, 4)
    assert pipe.max_batch == 4
    assert [pipe.bucketize(b) for b in (1, 2, 3, 4)] == [1, 2, 4, 4]
    with pytest.raises(ValueError, match="exceeds"):
        pipe.bucketize(5)
    x = jnp.ones((3, 2, 12, 12))
    padded, real = pipe.pad_to_bucket(x)
    assert padded.shape[0] == 4 and real == 3
    np.testing.assert_array_equal(np.asarray(padded[3]), 0.0)
    # exact bucket size: no copy, no padding
    x2 = jnp.ones((2, 2, 12, 12))
    padded2, real2 = pipe.pad_to_bucket(x2)
    assert padded2 is x2 and real2 == 2


def test_bounded_jit_programs_bucket_count_not_batch_size_count():
    """The acceptance-criteria contract: after serving many distinct
    request-batch sizes, the number of jitted program traces is bounded by
    (layer geometries) x (buckets), NOT by the number of batch sizes."""
    pipe, _ = _pipeline(bucket_sizes=(1, 2, 4))
    n_geos = len({(s.program_key, s.geo) for s in pipe.specs})
    seen_sizes = set()
    for b in (1, 2, 3, 4, 3, 2, 1):  # 4 distinct sizes, only 3 buckets
        x = jnp.asarray(RNG.standard_normal((b, 2, 12, 12)), jnp.float32)
        padded, real = pipe.pad_to_bucket(x)
        pipe.run(padded)
        seen_sizes.add(b)
    assert len(seen_sizes) > len(pipe.bucket_sizes)
    assert pipe.worker_program_traces <= n_geos * len(pipe.bucket_sizes)


# -- run_prepared ---------------------------------------------------------
def test_run_prepared_matches_run():
    pipe, _ = _pipeline()
    x = jnp.asarray(RNG.standard_normal((2, 2, 12, 12)), jnp.float32)
    ref = np.asarray(pipe.run(x))
    # shared availability list, any order / superset of delta
    y1 = np.asarray(pipe.run_prepared(x, worker_ids=[5, 2, 4, 0]))
    np.testing.assert_allclose(y1, ref, rtol=1e-4, atol=1e-4)
    # explicit per-layer survivor subsets
    ids = [(1, 3), (5, 0)]
    y2 = np.asarray(pipe.run_prepared(x, pipe.prepare(ids)))
    np.testing.assert_allclose(y2, ref, rtol=1e-4, atol=1e-4)
    # one prepare plan reused across batches (the serving fast path)
    plan = pipe.prepare()
    for _ in range(2):
        np.testing.assert_allclose(
            np.asarray(pipe.run_prepared(x, plan)), ref, rtol=1e-4, atol=1e-4
        )
    with pytest.raises(ValueError, match="covers"):
        pipe.run_prepared(x, plan[:1])


# -- cluster submit/collect ----------------------------------------------
def test_submit_collect_split_and_persistent_pool():
    pipe, _ = _pipeline()
    cluster = FcdccCluster(pipe.specs[0].plan, StragglerModel.none(6),
                           mode="threads")
    cluster.load_pipeline(pipe)
    x = jnp.asarray(RNG.standard_normal((1, 2, 12, 12)), jnp.float32)
    y0, _ = cluster.run_pipeline(x)
    pools = cluster._pools
    assert pools is not None and len(pools) == 6
    y1, _ = cluster.run_pipeline(x)
    assert cluster._pools is pools  # same executors, not per-call ones
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)
    cluster.shutdown()
    assert cluster._pools is None
    y2, _ = cluster.run_pipeline(x)  # pools re-created lazily
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y0), atol=1e-4)
    cluster.shutdown()


def test_collect_snapshots_worker_times():
    """A straggler finishing after collect() must not mutate the returned
    timing list (the old _collect leaked its live list).  The discarded
    straggler's slot is nan — NOT 0.0, which would be indistinguishable
    from the fastest node."""
    delays = np.zeros(6)
    delays[0] = 0.3
    cluster = FcdccCluster(FcdccPlan(n=6, k_a=2, k_b=4),
                           StragglerModel(delays), mode="threads")
    pipe, _ = _pipeline()
    cluster.load_pipeline(pipe)
    x = jnp.asarray(RNG.standard_normal((1, 2, 12, 12)), jnp.float32)
    _, timing = cluster.run_pipeline_layer(0, x)
    assert np.isnan(timing.worker_compute_s[0])  # unfinished at collect
    time.sleep(0.5)  # straggler thread writes its time into the live list
    assert np.isnan(timing.worker_compute_s[0])  # snapshot unchanged
    assert 0 not in timing.used_workers
    cluster.shutdown()


# -- the server -----------------------------------------------------------
def test_server_serves_correct_results():
    pipe, _ = _pipeline()
    ref_pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    xs = _images(5)
    with server:
        handles = server.submit_many(xs)
        outs = [h.result(timeout=60.0) for h in handles]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-4, atol=1e-4
        )
    stats = server.stats()
    assert stats.completed == 5
    assert stats.e2e_p50_s > 0 and stats.images_per_s > 0
    assert stats.e2e_p99_s >= stats.e2e_p95_s >= stats.e2e_p50_s


def test_server_bounded_programs_after_warmup():
    pipe, _ = _pipeline(bucket_sizes=(1, 2, 4))
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    server.warmup()
    traces = pipe.worker_program_traces
    with server:
        for burst in (1, 3, 2, 4, 1):
            handles = server.submit_many(_images(burst))
            for h in handles:
                h.result(timeout=60.0)
    # every request-batch size mapped onto a warmed bucket: zero new traces
    assert pipe.worker_program_traces == traces


def test_server_casts_request_dtype():
    """A uint8/float16 request is cast to the pipeline dtype at submit —
    a stray client dtype must not re-trace every (layer, bucket) program."""
    pipe, _ = _pipeline(bucket_sizes=(1, 2))
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    server.warmup()
    traces = pipe.worker_program_traces
    with server:
        y8 = server.submit(np.zeros((2, 12, 12), np.uint8)).result(timeout=60.0)
        y16 = server.submit(
            np.ones((2, 12, 12), np.float16)).result(timeout=60.0)
    assert y8.shape == y16.shape
    assert pipe.worker_program_traces == traces


def test_server_under_stragglers_and_dead_worker():
    pipe, _ = _pipeline()
    ref_pipe, _ = _pipeline()
    delays = np.zeros(6)
    delays[1] = 5.0
    delays[4] = np.inf
    server = CodedServer(pipe, StragglerModel(delays), mode="simulated")
    xs = _images(3)
    with server:
        outs = [h.result(timeout=60.0) for h in server.submit_many(xs)]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-4, atol=1e-4
        )


def test_server_threads_mode_returns_before_straggler():
    pipe, _ = _pipeline()
    delays = np.zeros(6)
    delays[2] = 1.0
    server = CodedServer(pipe, StragglerModel(delays), mode="threads")
    server.warmup()
    t0 = time.perf_counter()
    with server:
        outs = [h.result(timeout=60.0) for h in server.submit_many(_images(2))]
    assert len(outs) == 2
    # fastest-delta collection: both layers finish well before the 1s sleep
    assert time.perf_counter() - t0 < 1.0


def test_server_direct_execution_matches_cluster():
    pipe, _ = _pipeline()
    ref_pipe, _ = _pipeline()
    delays = np.zeros(6)
    delays[0] = 2.0
    delays[3] = np.inf
    server = CodedServer(pipe, StragglerModel(delays), execution="direct")
    xs = _images(4)
    with server:
        outs = [h.result(timeout=60.0) for h in server.submit_many(xs)]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-4, atol=1e-4
        )


def test_server_late_arrivals_join_new_batch():
    """Requests arriving while a batch is mid-stack are admitted as a new
    batch at the next layer boundary, not appended to the running one."""
    pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated",
                         max_inflight=2)
    with server:
        first = server.submit_many(_images(2))
        time.sleep(0.01)  # let the first batch start
        second = server.submit_many(_images(2))
        for h in (*first, *second):
            h.result(timeout=60.0)
    recs = {r.request_id: r for r in server.metrics.records()}
    assert len(recs) == 4
    # the late pair rode a different batch start than the early pair
    starts = {round(recs[h.request_id].start_t, 6) for h in second}
    early_starts = {round(recs[h.request_id].start_t, 6) for h in first}
    assert starts.isdisjoint(early_starts)


def test_server_degraded_cluster_fails_requests_not_engine():
    pipe, _ = _pipeline()
    delays = np.full(6, np.inf)
    delays[0] = 0.0  # one survivor < delta=2
    server = CodedServer(pipe, StragglerModel(delays), mode="simulated")
    with server:
        h = server.submit(_images(1)[0])
        with pytest.raises(ClusterDegraded):
            h.result(timeout=60.0)
        # the engine survived the failed batch and still rejects bad shapes
        with pytest.raises(ValueError, match="request shape"):
            server.submit(jnp.zeros((3, 5, 5)))


def test_server_shutdown_without_drain_cancels():
    pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    server.start()
    handles = server.submit_many(_images(2))
    server.shutdown(drain=False)
    for h in handles:
        if not h.done():
            continue  # may have completed before the stop landed
        try:
            h.result(timeout=1.0)
        except RuntimeError:
            pass
    with pytest.raises(RuntimeError, match="not running"):
        server.submit(_images(1)[0])


def test_server_shutdown_timeout_keeps_thread_and_cancels():
    """A join timeout must leave ``_thread`` set (so a retry joins again
    instead of silently skipping) and fail outstanding requests fast."""
    pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    gate = threading.Event()
    orig = server.cluster.dispatch_pipeline_layer

    def wedged_layer(idx, x, model=None):
        gate.wait(30.0)  # engine blocks here until the test releases it
        return orig(idx, x, model)

    server.cluster.dispatch_pipeline_layer = wedged_layer
    server.start()
    h = server.submit(_images(1)[0])
    time.sleep(0.05)  # let the engine pick up the batch and block
    with pytest.raises(TimeoutError):
        server.shutdown(timeout=0.2)
    assert server._thread is not None  # a retry will re-join, not skip
    with pytest.raises(TimeoutError):  # request cancelled, caller not hung
        h.result(timeout=5.0)
    # the gate is closed: no new request may enqueue onto the wedged engine
    with pytest.raises(RuntimeError, match="not running"):
        server.submit(_images(1)[0])
    # the cancelled request must not be counted as served
    assert server.stats().completed == 0
    gate.set()  # un-wedge; the retry drains and joins cleanly
    server.shutdown(timeout=30.0)
    assert server._thread is None


def test_engine_admits_up_to_capacity_per_boundary():
    """With free inflight slots and a deep queue, the engine fills ALL
    slots at one layer boundary — the seed admitted one batch per
    iteration, filling capacity one layer-round late."""
    pipe, _ = _pipeline(bucket_sizes=(1,))
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated",
                         max_inflight=2)
    inflight_at_advance = []
    orig = server.cluster.dispatch_pipeline_layer

    def spy(idx, x, model=None):
        inflight_at_advance.append(len(server.scheduler["default"].inflight))
        return orig(idx, x, model)

    server.cluster.dispatch_pipeline_layer = spy
    # queue two single-image batches BEFORE the engine starts: the first
    # boundary sees both waiting with both slots free
    handles = [server.scheduler["default"].queue.submit(x)
               for x in _images(2)]
    with server:
        for h in handles:
            h.result(timeout=60.0)
    assert inflight_at_advance[0] == 2  # both admitted before any advance


def test_request_finish_first_writer_wins():
    """A shutdown-timeout cancel_all races the still-running engine; a
    result delivered first must survive the late cancellation (and a
    cancellation delivered first must survive a late result)."""
    from repro.serving.scheduler import Scheduler

    sched = Scheduler(lambda x: (x, x.shape[0]), max_batch=1)
    h1 = sched.submit(jnp.zeros((2, 12, 12)))
    h2 = sched.submit(jnp.zeros((2, 12, 12)))
    b1, b2 = sched.admit(), sched.admit()
    b1.requests[0].finish(result="done")     # engine completed b1 ...
    assert sched.cancel_all(TimeoutError("wedged")) == 2  # ... then cancel
    assert h1.result(timeout=1.0) == "done"  # result not clobbered
    with pytest.raises(TimeoutError):
        h2.result(timeout=1.0)
    b2.requests[0].finish(result="late")     # engine finishes b2 after all
    with pytest.raises(TimeoutError):        # cancellation not clobbered
        h2.result(timeout=1.0)
    assert not sched.has_work()


def test_server_pallas_backend_serves_matching_results():
    """End-to-end serving over the fused pallas worker kernel: the engine's
    bucketed batch programs run the custom MXU path and decode to the same
    outputs as the lax pipeline."""
    params = _params(STACK)
    specs = plan_layers(STACK, 12, 6, default_kab=(2, 4))
    pal = CodedPipeline(specs, params, backend="pallas", bucket_sizes=(1, 2))
    ref_pipe, _ = _pipeline(bucket_sizes=(1, 2))
    server = CodedServer(pal, StragglerModel.none(6), mode="simulated")
    assert server.cluster.backend == "pallas"
    xs = _images(3)
    with server:
        outs = [h.result(timeout=120.0) for h in server.submit_many(xs)]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-3, atol=1e-3
        )


def test_server_concurrent_clients():
    pipe, _ = _pipeline()
    ref_pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    xs = _images(6)
    outs = [None] * len(xs)
    errs = []

    def client(i):
        try:
            outs[i] = server.submit(xs[i]).result(timeout=60.0)
        except BaseException as e:  # surfaced below
            errs.append(e)

    with server:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(len(xs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60.0)
    assert not errs
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-4, atol=1e-4
        )


# -- multi-model serving ---------------------------------------------------
def _pipeline_b(bucket_sizes=(1, 2, 4), n=6, hw=12, kab=(4, 2)):
    params = _params(STACK_B, seed=3)
    specs = plan_layers(STACK_B, hw, n, default_kab=kab)
    return CodedPipeline(specs, params, bucket_sizes=bucket_sizes), params


def _images_b(count, hw=12):
    return [jnp.asarray(RNG.standard_normal((3, hw, hw)), jnp.float32)
            for _ in range(count)]


def _prequeue(server, model, xs):
    """Enqueue requests before ``start()`` (dtype pre-cast like submit)."""
    pipe = server.models[model].pipeline
    return [server.scheduler[model].queue.submit(
        jnp.asarray(x, pipe.input_dtype)) for x in xs]


def test_multimodel_bitexact_vs_single_model_servers():
    """The acceptance contract: two models with different (k_a, k_b) plans
    served concurrently from ONE shared worker pool produce bit-exact
    per-model outputs vs their own single-model servers, with the jit
    trace count bounded by geometries x buckets summed over models.

    Distinct finite delays make the simulated fastest-delta subset
    deterministic, so identical programs see identical inputs."""
    delays = np.arange(6, dtype=float)  # worker 0 fastest, strict order
    pipe_a, _ = _pipeline()
    pipe_b, _ = _pipeline_b()
    xs_a, xs_b = _images(4), _images_b(3)

    def serve_single(pipe, xs):
        server = CodedServer(pipe, StragglerModel(delays), mode="simulated")
        handles = _prequeue(server, "default", xs)
        with server:
            return [np.asarray(h.result(timeout=60.0)) for h in handles]

    ref_a = serve_single(pipe_a, xs_a)
    ref_b = serve_single(pipe_b, xs_b)

    shared = CodedServer(straggler=StragglerModel(delays), mode="simulated")
    shared.register_model("a", pipe_a)
    shared.register_model("b", pipe_b)
    ha = _prequeue(shared, "a", xs_a)
    hb = _prequeue(shared, "b", xs_b)
    with shared:
        out_a = [np.asarray(h.result(timeout=60.0)) for h in ha]
        out_b = [np.asarray(h.result(timeout=60.0)) for h in hb]
    for got, ref in zip(out_a + out_b, ref_a + ref_b):
        np.testing.assert_array_equal(got, ref)
    traces = sum(s.pipeline.worker_program_traces
                 for s in shared.models.values())
    bound = sum(s.pipeline.num_geometries * len(s.pipeline.bucket_sizes)
                for s in shared.models.values())
    assert traces <= bound
    # per-model metrics break out; the aggregate covers both
    per = shared.per_model_stats()
    assert per["a"].completed == 4 and per["b"].completed == 3
    assert shared.stats().completed == 7
    assert shared.stats("a").completed == 4


def test_multimodel_straggler_isolation_threads_mode():
    """Model A's straggler-heavy wall-clock rounds must not corrupt model
    B's results on the shared pool (threads mode, real sleeps)."""
    delays = np.zeros(6)
    delays[0] = 0.3
    delays[5] = np.inf  # and one dead worker
    pipe_a, _ = _pipeline()
    pipe_b, _ = _pipeline_b()
    ref_a, _ = _pipeline()
    ref_b, _ = _pipeline_b()
    server = CodedServer(straggler=StragglerModel(delays), mode="threads")
    server.register_model("a", pipe_a)
    server.register_model("b", pipe_b)
    server.warmup()
    xs_a, xs_b = _images(3), _images_b(3)
    with server:
        ha = server.submit_many(xs_a, "a")
        hb = server.submit_many(xs_b, "b")
        out_a = [h.result(timeout=60.0) for h in ha]
        out_b = [h.result(timeout=60.0) for h in hb]
    for x, y in zip(xs_a, out_a):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_a.run(x)), rtol=1e-4, atol=1e-4)
    for x, y in zip(xs_b, out_b):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_b.run(x)), rtol=1e-4, atol=1e-4)


def test_cluster_filter_cache_no_collision_across_pipelines():
    """Two pipelines with the SAME layer names but different plans stay
    resident on one cluster at once — namespaced entries, no clobbering,
    and each model decodes against its own filters."""
    pipe1, _ = _pipeline()                      # plan (2, 4)
    specs2 = plan_layers(STACK, 12, 6, default_kab=(4, 2))
    pipe2 = CodedPipeline(specs2, _params(STACK, seed=9))  # plan (4, 2)
    cluster = FcdccCluster(pipe1.specs[0].plan, StragglerModel.none(6),
                           mode="simulated")
    cluster.load_pipeline(pipe1, "m1")
    cluster.load_pipeline(pipe2, "m2")
    assert {"m1/s1", "m1/s2", "m2/s1", "m2/s2"} <= set(cluster._resident)
    x = jnp.asarray(RNG.standard_normal((2, 2, 12, 12)), jnp.float32)
    y1, _ = cluster.run_pipeline(x, model="m1")
    y2, _ = cluster.run_pipeline(x, model="m2")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(pipe1.run(x)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(pipe2.run(x)),
                               rtol=1e-4, atol=1e-4)
    # model selector is mandatory once ambiguous, and must exist
    with pytest.raises(ValueError, match="pass model="):
        cluster.run_pipeline(x)
    with pytest.raises(ValueError, match="unknown model"):
        cluster.run_pipeline(x, model="nope")
    # an explicitly passed pipeline is never ambiguous (default namespace)
    y3, _ = cluster.run_pipeline(x, pipe1)
    np.testing.assert_allclose(np.asarray(y3), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)
    # re-registering a name purges ALL of its old resident entries (a v2
    # with fewer layers must not leave v1 filters reachable)
    short = CodedPipeline(plan_layers(STACK[:1], 12, 6, default_kab=(2, 4)),
                          _params(STACK))
    cluster.load_pipeline(short, "m1")
    assert "m1/s1" in cluster._resident and "m1/s2" not in cluster._resident
    cluster.shutdown()


def test_fair_share_interleaves_models():
    """The starvation bound: with both models holding work, layer rounds
    alternate (least-served first) — at every prefix of the advance
    sequence the per-model round counts differ by at most 1."""
    pipe_a, _ = _pipeline(bucket_sizes=(1,))
    pipe_b, _ = _pipeline_b(bucket_sizes=(1,))
    server = CodedServer(mode="simulated")
    server.register_model("a", pipe_a)
    server.register_model("b", pipe_b)
    advanced = []
    orig = server.cluster.dispatch_pipeline_layer

    def spy(idx, x, model=None):
        advanced.append(model)
        return orig(idx, x, model)

    server.cluster.dispatch_pipeline_layer = spy
    ha = _prequeue(server, "a", _images(3))
    hb = _prequeue(server, "b", _images_b(3))
    with server:
        for h in ha + hb:
            h.result(timeout=60.0)
    # 3 requests x 2 layers each = 6 rounds per model, interleaved fairly
    assert advanced.count("a") == 6 and advanced.count("b") == 6
    for i in range(1, len(advanced) + 1):
        prefix = advanced[:i]
        assert abs(prefix.count("a") - prefix.count("b")) <= 1, prefix


def test_weighted_fair_share_round_ratio_and_starvation_bound():
    """register_model(..., weight=w): the rotating sweep grants up to w
    consecutive rounds per sweep position.  With weights (2, 1) and both
    models backlogged, rounds follow a,a,b,... and the starvation bound
    holds: a backlogged model never waits more than the sum of the OTHER
    models' weights between consecutive rounds of its own."""
    pipe_a, _ = _pipeline(bucket_sizes=(1,))
    pipe_b, _ = _pipeline_b(bucket_sizes=(1,))
    server = CodedServer(mode="simulated")
    server.register_model("a", pipe_a, weight=2)
    server.register_model("b", pipe_b, weight=1)
    advanced = []
    orig = server.cluster.dispatch_pipeline_layer

    def spy(idx, x, model=None):
        advanced.append(model)
        return orig(idx, x, model)

    server.cluster.dispatch_pipeline_layer = spy
    ha = _prequeue(server, "a", _images(4))
    hb = _prequeue(server, "b", _images_b(4))
    with server:
        for h in ha + hb:
            h.result(timeout=60.0)
    # 4 requests x 2 layers per model
    assert advanced.count("a") == 8 and advanced.count("b") == 8
    # while both are backlogged the prefix ratio honors the weights: in any
    # prefix of the contended phase, a's rounds stay within weight_a of
    # 2x b's rounds (a,a,b repeating)
    contended = advanced[: 3 * 4]  # both models have work for >= 4 sweeps
    for i in range(1, len(contended) + 1):
        na, nb = contended[:i].count("a"), contended[:i].count("b")
        assert abs(na - 2 * nb) <= 2, contended[:i]
    # starvation bound: gaps between consecutive 'b' rounds <= weight_a + 1
    b_rounds = [i for i, m in enumerate(contended) if m == "b"]
    assert all(j - i <= 3 for i, j in zip(b_rounds, b_rounds[1:]))


def test_weighted_fair_share_validation():
    server = CodedServer(mode="simulated")
    with pytest.raises(ValueError, match="weight"):
        server.register_model("a", _pipeline()[0], weight=0)
    with pytest.raises(ValueError, match="weight"):
        server.register_model("a", _pipeline()[0], weight=1.5)
    # the failed registrations left no partial state behind
    assert not server.models and server.cluster is None


def test_models_registry_single_source_of_truth():
    """The name -> pipeline registry lives only in the cluster;
    CodedServer.models holds per-model serving state whose ``pipeline`` is
    a live view of ``cluster.pipelines`` — the two can never disagree."""
    pipe_a, _ = _pipeline()
    pipe_b, _ = _pipeline_b()
    server = CodedServer(mode="simulated")
    server.register_model("a", pipe_a)
    server.register_model("b", pipe_b, weight=3)
    assert set(server.models) == set(server.cluster.pipelines) == {"a", "b"}
    assert server.models["a"].pipeline is server.cluster.pipelines["a"]
    assert server.models["b"].pipeline is pipe_b
    # the fair-share weight likewise has one home: the scheduler
    assert server.scheduler.weights["b"] == 3
    # a cluster-side replace is immediately visible through the view
    pipe_a2, _ = _pipeline()
    server.cluster.load_pipeline(pipe_a2, "a")
    assert server.models["a"].pipeline is pipe_a2


def test_fair_share_idle_model_builds_no_deficit():
    """A model that idled while another served must NOT bank a least-served
    deficit it can later spend monopolizing the engine: the sweep is
    positional, so once both have work the picks alternate immediately."""
    from repro.serving.scheduler import MultiScheduler

    multi = MultiScheduler()
    for name in ("a", "b"):
        multi.add_model(name, lambda x: (x, x.shape[0]), max_batch=1,
                        max_inflight=8)
    # phase 1: only 'a' has work — it serves 50 rounds unopposed
    multi.submit("a", jnp.zeros((2, 12, 12)))
    assert multi.admit() is not None
    for _ in range(50):
        name, _batch = multi.next_batch()
        assert name == "a"
    # phase 2: 'b' arrives — picks must alternate from the very next round
    multi.submit("b", jnp.zeros((3, 12, 12)))
    assert multi.admit() is not None
    picks = [multi.next_batch()[0] for _ in range(6)]
    assert picks == ["b", "a", "b", "a", "b", "a"]


def test_coalescing_merges_equal_depth_batches():
    """Two in-flight fragments of one model at the same layer boundary are
    merged into one bucketed batch (counted in stats) and still decode to
    exactly the per-request reference results."""
    pipe, _ = _pipeline(bucket_sizes=(1, 2, 4))
    ref_pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    xs = _images(2)
    sched = server.scheduler["default"]
    # force two fragment batches at layer 0: admit each request alone
    handles = []
    for x in xs:
        handles.append(sched.queue.submit(jnp.asarray(x, pipe.input_dtype)))
        assert sched.admit() is not None
    assert [b.real for b in sched.inflight] == [1, 1]
    with server:
        outs = [h.result(timeout=60.0) for h in handles]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-4, atol=1e-4)
    assert server.stats().completed == 2
    assert server.stats().coalesced == 1
    assert server.stats("default").coalesced == 1
    recs = sorted(server.metrics.records(), key=lambda r: r.request_id)
    assert [r.batch_real for r in recs] == [2, 2]  # both rode one batch


def test_coalesce_respects_max_batch():
    """Fragments whose combined real size exceeds the largest bucket stay
    separate (a merge must never overflow the jit program buckets)."""
    from repro.serving.scheduler import Scheduler

    pipe, _ = _pipeline(bucket_sizes=(1, 2))
    sched = Scheduler(pipe.pad_to_bucket, max_batch=2, max_inflight=4)
    for _ in range(3):
        sched.queue.submit(_images(1)[0])
        sched.admit(limit=1)
    assert len(sched.inflight) == 3
    assert sched.coalesce() == 1
    assert sorted(b.real for b in sched.inflight) == [1, 2]
    assert sched.coalesce() == 0  # nothing else fits


def test_register_model_validation():
    pipe_a, _ = _pipeline()
    server = CodedServer(pipe_a, StragglerModel.none(6), mode="simulated")
    with pytest.raises(ValueError, match="already registered"):
        server.register_model("default", _pipeline()[0])
    unbucketed = CodedPipeline(plan_layers(STACK, 12, 8, default_kab=(2, 4)),
                               _params(STACK))
    with pytest.raises(ValueError, match="n=8"):
        server.register_model("bigger", unbucketed)
    # a failed registration must not have re-bucketed the caller's pipeline
    assert unbucketed.bucket_sizes is None
    pal = CodedPipeline(plan_layers(STACK_B, 12, 6, default_kab=(2, 4)),
                        _params(STACK_B), backend="pallas",
                        bucket_sizes=(1, 2))
    with pytest.raises(ValueError, match="backend"):
        server.register_model("pallas", pal)
    with pytest.raises(ValueError, match="unknown model"):
        server.submit(_images(1)[0], "nope")
    server.start()
    try:
        # live registration: a model added while the engine loop is running
        # serves without a restart (scheduler is published last, so the
        # loop never sees a half-registered model)
        server.register_model("late", _pipeline_b()[0])
        y = server.submit(_images_b(1)[0], "late").result(timeout=60.0)
        assert y.shape == _pipeline_b()[0].run(_images_b(1)[0]).shape
    finally:
        server.shutdown()
    # a server with no model registered refuses to start
    with pytest.raises(RuntimeError, match="no model"):
        CodedServer(mode="simulated").start()


def test_multimodel_submit_requires_model_name():
    server = CodedServer(mode="simulated")
    server.register_model("a", _pipeline()[0])
    server.register_model("b", _pipeline_b()[0])
    with server:
        with pytest.raises(ValueError, match="pass model="):
            server.submit(_images(1)[0])
        y = server.submit(_images(1)[0], "a").result(timeout=60.0)
    assert y is not None
    with pytest.raises(ValueError, match="use models"):
        server.pipeline  # single-model back-compat view is now ambiguous


# -- HTTP front-end --------------------------------------------------------
def _http(method, url, payload=None, timeout=30.0):
    data = None if payload is None else json.dumps(payload).encode()
    req = urllib.request.Request(url, data=data, method=method)
    if data is not None:
        req.add_header("Content-Type", "application/json")
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        return resp.status, json.loads(resp.read())


def test_http_frontend_roundtrip_and_drain():
    """POST /v1/infer for two models on an ephemeral port, stats/models
    introspection, error codes, then a graceful drain: no leaked engine
    thread, no leaked worker executors, socket closed."""
    pipe_a, _ = _pipeline()
    pipe_b, _ = _pipeline_b()
    ref_a, _ = _pipeline()
    server = CodedServer(mode="simulated")
    server.register_model("a", pipe_a)
    server.register_model("b", pipe_b)
    frontend = ServingFrontend(server, port=0)
    frontend.start()
    url = frontend.url
    try:
        status, models = _http("GET", f"{url}/v1/models")
        assert status == 200
        assert {m["name"] for m in models["models"]} == {"a", "b"}
        shapes = {m["name"]: tuple(m["input_shape"]) for m in models["models"]}
        assert shapes == {"a": (2, 12, 12), "b": (3, 12, 12)}

        x = np.asarray(_images(1)[0])
        status, out = _http("POST", f"{url}/v1/infer",
                            {"model": "a", "input": x.tolist()})
        assert status == 200 and out["model"] == "a"
        np.testing.assert_allclose(
            np.asarray(out["output"], np.float32), np.asarray(ref_a.run(x)),
            rtol=1e-4, atol=1e-4)
        xb = np.asarray(_images_b(1)[0])
        status, out_b = _http("POST", f"{url}/v1/infer",
                              {"model": "b", "input": xb.tolist()})
        assert status == 200 and out_b["shape"][0] == 4  # STACK_B out_ch

        status, stats = _http("GET", f"{url}/v1/stats")
        assert status == 200
        assert stats["aggregate"]["completed"] == 2
        assert stats["per_model"]["a"]["completed"] == 1
        assert stats["per_model"]["b"]["completed"] == 1

        with pytest.raises(urllib.error.HTTPError) as err:
            _http("POST", f"{url}/v1/infer",
                  {"model": "nope", "input": x.tolist()})
        assert err.value.code == 404
        with pytest.raises(urllib.error.HTTPError) as err:
            _http("POST", f"{url}/v1/infer",
                  {"model": "a", "input": [[1.0]]})
        assert err.value.code == 400
        # ambiguous model on a multi-model server is a client error ...
        with pytest.raises(urllib.error.HTTPError) as err:
            _http("POST", f"{url}/v1/infer", {"input": x.tolist()})
        assert err.value.code == 400
        # ... and so is a valid-JSON body that is not an object
        with pytest.raises(urllib.error.HTTPError) as err:
            _http("POST", f"{url}/v1/infer", 42)
        assert err.value.code == 400
        with pytest.raises(urllib.error.HTTPError) as err:
            _http("GET", f"{url}/v1/nothing")
        assert err.value.code == 404
    finally:
        frontend.shutdown()
    # graceful drain: engine thread joined, worker pools released, port dead
    assert server._thread is None
    assert server.cluster._pools is None
    assert not any(t.name == "coded-server-engine" and t.is_alive()
                   for t in threading.enumerate())
    with pytest.raises((urllib.error.URLError, ConnectionError, OSError)):
        _http("GET", f"{url}/v1/models", timeout=2.0)
    # idempotent
    frontend.shutdown()


def test_http_batched_infer_per_item_errors():
    """POST /v1/infer with "inputs": one HTTP round trip fans out every
    image to the engine (in-order results), and a bad item yields a
    per-item error without failing its siblings."""
    pipe_a, _ = _pipeline()
    ref_a, _ = _pipeline()
    server = CodedServer(pipe_a, mode="simulated", model="a")
    frontend = ServingFrontend(server, port=0)
    frontend.start()
    url = frontend.url
    try:
        xs = [np.asarray(x) for x in _images(3)]
        status, out = _http("POST", f"{url}/v1/infer",
                            {"model": "a", "inputs": [x.tolist() for x in xs]})
        assert status == 200 and out["model"] == "a" and out["count"] == 3
        assert len(out["results"]) == 3
        for x, item in zip(xs, out["results"]):
            assert "error" not in item
            np.testing.assert_allclose(
                np.asarray(item["output"], np.float32),
                np.asarray(ref_a.run(x)), rtol=1e-4, atol=1e-4)
        # in-order: request ids ascend with list position
        ids = [r["request_id"] for r in out["results"]]
        assert ids == sorted(ids)

        # middle item has the wrong shape: that item errors, siblings serve
        bad = [xs[0].tolist(), np.zeros((1, 2, 2)).tolist(), xs[2].tolist()]
        status, out = _http("POST", f"{url}/v1/infer",
                            {"model": "a", "inputs": bad})
        assert status == 200 and out["count"] == 3
        assert "error" not in out["results"][0]
        assert "request shape" in out["results"][1]["error"]
        assert "error" not in out["results"][2]

        # malformed batches are request-level 400s
        for body in ({"model": "a", "inputs": []},
                     {"model": "a", "inputs": 5},
                     {"model": "a", "input": xs[0].tolist(),
                      "inputs": [xs[0].tolist()]}):
            with pytest.raises(urllib.error.HTTPError) as err:
                _http("POST", f"{url}/v1/infer", body)
            assert err.value.code == 400
    finally:
        frontend.shutdown()


def test_http_infer_no_model_registered_is_503_not_crash():
    """An infer against an engine with zero models must answer 503 (both
    single and batched forms), not kill the handler with an IndexError."""
    server = CodedServer(mode="simulated")
    frontend = ServingFrontend(server, port=0, manage_server=False)
    frontend.start()
    try:
        x = np.zeros((2, 12, 12)).tolist()
        for body in ({"input": x}, {"inputs": [x]}):
            with pytest.raises(urllib.error.HTTPError) as err:
                _http("POST", f"{frontend.url}/v1/infer", body)
            assert err.value.code == 503
    finally:
        frontend.shutdown()


def test_http_batched_infer_requires_model_when_ambiguous():
    server = CodedServer(mode="simulated")
    server.register_model("a", _pipeline()[0])
    server.register_model("b", _pipeline_b()[0])
    frontend = ServingFrontend(server, port=0)
    frontend.start()
    try:
        x = np.asarray(_images(1)[0])
        with pytest.raises(urllib.error.HTTPError) as err:
            _http("POST", f"{frontend.url}/v1/infer",
                  {"inputs": [x.tolist()]})
        assert err.value.code == 400
        # with the model named, the batch serves
        status, out = _http("POST", f"{frontend.url}/v1/infer",
                            {"model": "a", "inputs": [x.tolist()]})
        assert status == 200 and out["count"] == 1
    finally:
        frontend.shutdown()


# -- metrics --------------------------------------------------------------
def test_percentile_and_stats_math():
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert np.isnan(percentile([], 50))
    mc = MetricsCollector()
    for i in range(4):
        mc.record(RequestRecord(
            request_id=i, arrival_t=float(i), start_t=i + 1.0,
            finish_t=i + 3.0, bucket=4, batch_real=2,
        ))
    s = mc.stats()
    assert s.completed == 4
    assert s.queue_wait_p50_s == pytest.approx(1.0)
    assert s.execute_p50_s == pytest.approx(2.0)
    assert s.e2e_p50_s == pytest.approx(3.0)
    assert s.wall_s == pytest.approx(6.0)  # arrival 0 -> finish 6
    assert s.images_per_s == pytest.approx(4 / 6.0)
    assert s.mean_batch_real == pytest.approx(2.0)
    mc.reset()
    assert mc.stats().completed == 0


# -- live (de)registration ------------------------------------------------
def test_unregister_model_drains_then_removes():
    """Two-phase removal on a live engine: close (no new submits) -> drain
    queued work -> fence -> remove.  In-flight requests of the removed
    model complete; the co-resident model keeps serving; every cluster
    namespace (pipelines, resident filters) is reclaimed."""
    server = CodedServer(mode="simulated")
    server.register_model("a", _pipeline()[0])
    server.register_model("b", _pipeline_b()[0])
    with server:
        last = server.submit(_images_b(1)[0], "b")
        server.unregister_model("b", drain=True, timeout=60.0)
        assert last.result(timeout=1.0) is not None  # drained, not dropped
        with pytest.raises(ValueError, match="unknown model"):
            server.submit(_images_b(1)[0], "b")
        y = server.submit(_images(1)[0], "a").result(timeout=60.0)
        assert y is not None
        assert "b" not in server.models
        assert "b" not in server.cluster.pipelines
        assert not any(k.startswith("b/") for k in server.cluster._resident)
        # re-registration under the freed name works on the live engine
        server.register_model("b", _pipeline_b()[0])
        assert server.submit(_images_b(1)[0], "b").result(timeout=60.0) \
            is not None


def test_unregister_model_no_drain_cancels_queued():
    server = CodedServer(mode="simulated")
    server.register_model("a", _pipeline()[0])
    server.register_model("b", _pipeline_b()[0])
    # engine not started: queued work cannot drain, so drain=False cancels
    h = server.scheduler["b"].submit(_images_b(1)[0])
    server.unregister_model("b", drain=False)
    with pytest.raises(RuntimeError, match="unregistered"):
        h.result(timeout=1.0)
    with pytest.raises(ValueError, match="unknown model"):
        server.submit(_images_b(1)[0], "b")
    with pytest.raises(ValueError, match="unknown model"):
        server.unregister_model("b")


def test_scheduler_fence_blocks_bucket_bindings():
    """A fenced scheduler must never consult pad_to_bucket again: admit
    refuses new batches and coalesce refuses merges *before* touching the
    bucket bindings (they may already be unloaded mid-removal)."""
    from repro.serving.scheduler import Scheduler

    pipe, _ = _pipeline(bucket_sizes=(1, 2))
    live = {"ok": True}

    def pad(x):
        assert live["ok"], "pad_to_bucket consulted after fence"
        return pipe.pad_to_bucket(x)

    sched = Scheduler(pad, max_batch=2, max_inflight=4)
    for _ in range(2):
        sched.queue.submit(_images(1)[0])
        sched.admit(limit=1)
    assert len(sched.inflight) == 2
    sched.close()
    with pytest.raises(RuntimeError, match="unregistered"):
        sched.submit(_images(1)[0])
    assert sched.has_work()  # queued/in-flight work survives close
    sched.fence()
    live["ok"] = False  # bindings gone: any pad call from here is a bug
    sched.queue.submit(_images(1)[0])  # raced in before close... simulate
    assert sched.admit() is None
    assert sched.coalesce() == 0


def test_multischeduler_remove_is_safe_mid_iteration():
    """The engine loop iterates a snapshot: removing a model between
    next_batch calls must neither KeyError nor starve the survivor."""
    from repro.serving.scheduler import MultiScheduler

    pipe, _ = _pipeline(bucket_sizes=(1, 2))
    multi = MultiScheduler()
    multi.add_model("a", pipe.pad_to_bucket, max_batch=2, max_inflight=4)
    multi.add_model("b", pipe.pad_to_bucket, max_batch=2, max_inflight=4)
    multi.submit("a", _images(1)[0])
    multi.submit("b", _images(1)[0])
    assert multi.admit() is not None
    assert multi.admit() is not None
    removed = multi.remove_model("b")
    assert removed.cancel_all(RuntimeError("gone")) >= 0
    picked = multi.next_batch()
    assert picked is not None and picked[0] == "a"
    with pytest.raises(KeyError):
        multi.remove_model("b")
    with pytest.raises(ValueError, match="already registered"):
        multi.add_model("a", pipe.pad_to_bucket, max_batch=2, max_inflight=4)
