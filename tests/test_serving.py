"""The coded serving engine: CodedServer + scheduler + metrics.

Covers: served results match the pipeline's own output; bucketed batch
assembly keeps the jit program count bounded by the *bucket* count while
request batch sizes vary; continuous admission at layer boundaries;
``run_prepared`` equivalence with ``run``; the cluster's ``submit``/
``collect`` split (persistent per-worker pool, worker_times snapshot);
straggler resilience end-to-end through the server; and metrics math.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodedPipeline, FcdccPlan
from repro.core.pipeline import plan_layers
from repro.models.cnn import ConvL
from repro.runtime import ClusterDegraded, FcdccCluster, StragglerModel
from repro.serving import CodedServer, MetricsCollector, RequestRecord, percentile

RNG = np.random.default_rng(0)

STACK = [
    ConvL("s1", 2, 8, 3, stride=1, padding=1, pool=2),
    ConvL("s2", 8, 8, 3, padding=1),
]


def _params(layers, seed=0):
    rng = np.random.default_rng(seed)
    return {
        l.name: jnp.asarray(
            rng.standard_normal((l.out_ch, l.in_ch, l.kernel, l.kernel))
            * (l.in_ch * l.kernel**2) ** -0.5,
            jnp.float32,
        )
        for l in layers
    }


def _pipeline(bucket_sizes=(1, 2, 4), n=6, hw=12):
    params = _params(STACK)
    specs = plan_layers(STACK, hw, n, default_kab=(2, 4))
    return CodedPipeline(specs, params, bucket_sizes=bucket_sizes), params


def _images(count, hw=12):
    return [jnp.asarray(RNG.standard_normal((2, hw, hw)), jnp.float32)
            for _ in range(count)]


# -- bucketing ------------------------------------------------------------
def test_bucketize_and_pad():
    pipe, _ = _pipeline(bucket_sizes=(1, 2, 4))
    assert pipe.bucket_sizes == (1, 2, 4)
    assert pipe.max_batch == 4
    assert [pipe.bucketize(b) for b in (1, 2, 3, 4)] == [1, 2, 4, 4]
    with pytest.raises(ValueError, match="exceeds"):
        pipe.bucketize(5)
    x = jnp.ones((3, 2, 12, 12))
    padded, real = pipe.pad_to_bucket(x)
    assert padded.shape[0] == 4 and real == 3
    np.testing.assert_array_equal(np.asarray(padded[3]), 0.0)
    # exact bucket size: no copy, no padding
    x2 = jnp.ones((2, 2, 12, 12))
    padded2, real2 = pipe.pad_to_bucket(x2)
    assert padded2 is x2 and real2 == 2


def test_bounded_jit_programs_bucket_count_not_batch_size_count():
    """The acceptance-criteria contract: after serving many distinct
    request-batch sizes, the number of jitted program traces is bounded by
    (layer geometries) x (buckets), NOT by the number of batch sizes."""
    pipe, _ = _pipeline(bucket_sizes=(1, 2, 4))
    n_geos = len({(s.program_key, s.geo) for s in pipe.specs})
    seen_sizes = set()
    for b in (1, 2, 3, 4, 3, 2, 1):  # 4 distinct sizes, only 3 buckets
        x = jnp.asarray(RNG.standard_normal((b, 2, 12, 12)), jnp.float32)
        padded, real = pipe.pad_to_bucket(x)
        pipe.run(padded)
        seen_sizes.add(b)
    assert len(seen_sizes) > len(pipe.bucket_sizes)
    assert pipe.worker_program_traces <= n_geos * len(pipe.bucket_sizes)


# -- run_prepared ---------------------------------------------------------
def test_run_prepared_matches_run():
    pipe, _ = _pipeline()
    x = jnp.asarray(RNG.standard_normal((2, 2, 12, 12)), jnp.float32)
    ref = np.asarray(pipe.run(x))
    # shared availability list, any order / superset of delta
    y1 = np.asarray(pipe.run_prepared(x, worker_ids=[5, 2, 4, 0]))
    np.testing.assert_allclose(y1, ref, rtol=1e-4, atol=1e-4)
    # explicit per-layer survivor subsets
    ids = [(1, 3), (5, 0)]
    y2 = np.asarray(pipe.run_prepared(x, pipe.prepare(ids)))
    np.testing.assert_allclose(y2, ref, rtol=1e-4, atol=1e-4)
    # one prepare plan reused across batches (the serving fast path)
    plan = pipe.prepare()
    for _ in range(2):
        np.testing.assert_allclose(
            np.asarray(pipe.run_prepared(x, plan)), ref, rtol=1e-4, atol=1e-4
        )
    with pytest.raises(ValueError, match="covers"):
        pipe.run_prepared(x, plan[:1])


# -- cluster submit/collect ----------------------------------------------
def test_submit_collect_split_and_persistent_pool():
    pipe, _ = _pipeline()
    cluster = FcdccCluster(pipe.specs[0].plan, StragglerModel.none(6),
                           mode="threads")
    cluster.load_pipeline(pipe)
    x = jnp.asarray(RNG.standard_normal((1, 2, 12, 12)), jnp.float32)
    y0, _ = cluster.run_pipeline(x)
    pools = cluster._pools
    assert pools is not None and len(pools) == 6
    y1, _ = cluster.run_pipeline(x)
    assert cluster._pools is pools  # same executors, not per-call ones
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)
    cluster.shutdown()
    assert cluster._pools is None
    y2, _ = cluster.run_pipeline(x)  # pools re-created lazily
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y0), atol=1e-4)
    cluster.shutdown()


def test_collect_snapshots_worker_times():
    """A straggler finishing after collect() must not mutate the returned
    timing list (the old _collect leaked its live list).  The discarded
    straggler's slot is nan — NOT 0.0, which would be indistinguishable
    from the fastest node."""
    delays = np.zeros(6)
    delays[0] = 0.3
    cluster = FcdccCluster(FcdccPlan(n=6, k_a=2, k_b=4),
                           StragglerModel(delays), mode="threads")
    pipe, _ = _pipeline()
    cluster.load_pipeline(pipe)
    x = jnp.asarray(RNG.standard_normal((1, 2, 12, 12)), jnp.float32)
    _, timing = cluster.run_pipeline_layer(0, x)
    assert np.isnan(timing.worker_compute_s[0])  # unfinished at collect
    time.sleep(0.5)  # straggler thread writes its time into the live list
    assert np.isnan(timing.worker_compute_s[0])  # snapshot unchanged
    assert 0 not in timing.used_workers
    cluster.shutdown()


# -- the server -----------------------------------------------------------
def test_server_serves_correct_results():
    pipe, _ = _pipeline()
    ref_pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    xs = _images(5)
    with server:
        handles = server.submit_many(xs)
        outs = [h.result(timeout=60.0) for h in handles]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-4, atol=1e-4
        )
    stats = server.stats()
    assert stats.completed == 5
    assert stats.e2e_p50_s > 0 and stats.images_per_s > 0
    assert stats.e2e_p99_s >= stats.e2e_p95_s >= stats.e2e_p50_s


def test_server_bounded_programs_after_warmup():
    pipe, _ = _pipeline(bucket_sizes=(1, 2, 4))
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    server.warmup()
    traces = pipe.worker_program_traces
    with server:
        for burst in (1, 3, 2, 4, 1):
            handles = server.submit_many(_images(burst))
            for h in handles:
                h.result(timeout=60.0)
    # every request-batch size mapped onto a warmed bucket: zero new traces
    assert pipe.worker_program_traces == traces


def test_server_casts_request_dtype():
    """A uint8/float16 request is cast to the pipeline dtype at submit —
    a stray client dtype must not re-trace every (layer, bucket) program."""
    pipe, _ = _pipeline(bucket_sizes=(1, 2))
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    server.warmup()
    traces = pipe.worker_program_traces
    with server:
        y8 = server.submit(np.zeros((2, 12, 12), np.uint8)).result(timeout=60.0)
        y16 = server.submit(
            np.ones((2, 12, 12), np.float16)).result(timeout=60.0)
    assert y8.shape == y16.shape
    assert pipe.worker_program_traces == traces


def test_server_under_stragglers_and_dead_worker():
    pipe, _ = _pipeline()
    ref_pipe, _ = _pipeline()
    delays = np.zeros(6)
    delays[1] = 5.0
    delays[4] = np.inf
    server = CodedServer(pipe, StragglerModel(delays), mode="simulated")
    xs = _images(3)
    with server:
        outs = [h.result(timeout=60.0) for h in server.submit_many(xs)]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-4, atol=1e-4
        )


def test_server_threads_mode_returns_before_straggler():
    pipe, _ = _pipeline()
    delays = np.zeros(6)
    delays[2] = 1.0
    server = CodedServer(pipe, StragglerModel(delays), mode="threads")
    server.warmup()
    t0 = time.perf_counter()
    with server:
        outs = [h.result(timeout=60.0) for h in server.submit_many(_images(2))]
    assert len(outs) == 2
    # fastest-delta collection: both layers finish well before the 1s sleep
    assert time.perf_counter() - t0 < 1.0


def test_server_direct_execution_matches_cluster():
    pipe, _ = _pipeline()
    ref_pipe, _ = _pipeline()
    delays = np.zeros(6)
    delays[0] = 2.0
    delays[3] = np.inf
    server = CodedServer(pipe, StragglerModel(delays), execution="direct")
    xs = _images(4)
    with server:
        outs = [h.result(timeout=60.0) for h in server.submit_many(xs)]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-4, atol=1e-4
        )


def test_server_late_arrivals_join_new_batch():
    """Requests arriving while a batch is mid-stack are admitted as a new
    batch at the next layer boundary, not appended to the running one."""
    pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated",
                         max_inflight=2)
    with server:
        first = server.submit_many(_images(2))
        time.sleep(0.01)  # let the first batch start
        second = server.submit_many(_images(2))
        for h in (*first, *second):
            h.result(timeout=60.0)
    recs = {r.request_id: r for r in server.metrics.records()}
    assert len(recs) == 4
    # the late pair rode a different batch start than the early pair
    starts = {round(recs[h.request_id].start_t, 6) for h in second}
    early_starts = {round(recs[h.request_id].start_t, 6) for h in first}
    assert starts.isdisjoint(early_starts)


def test_server_degraded_cluster_fails_requests_not_engine():
    pipe, _ = _pipeline()
    delays = np.full(6, np.inf)
    delays[0] = 0.0  # one survivor < delta=2
    server = CodedServer(pipe, StragglerModel(delays), mode="simulated")
    with server:
        h = server.submit(_images(1)[0])
        with pytest.raises(ClusterDegraded):
            h.result(timeout=60.0)
        # the engine survived the failed batch and still rejects bad shapes
        with pytest.raises(ValueError, match="request shape"):
            server.submit(jnp.zeros((3, 5, 5)))


def test_server_shutdown_without_drain_cancels():
    pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    server.start()
    handles = server.submit_many(_images(2))
    server.shutdown(drain=False)
    for h in handles:
        if not h.done():
            continue  # may have completed before the stop landed
        try:
            h.result(timeout=1.0)
        except RuntimeError:
            pass
    with pytest.raises(RuntimeError, match="not running"):
        server.submit(_images(1)[0])


def test_server_shutdown_timeout_keeps_thread_and_cancels():
    """A join timeout must leave ``_thread`` set (so a retry joins again
    instead of silently skipping) and fail outstanding requests fast."""
    pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    gate = threading.Event()
    orig = server.cluster.run_pipeline_layer

    def wedged_layer(idx, x):
        gate.wait(30.0)  # engine blocks here until the test releases it
        return orig(idx, x)

    server.cluster.run_pipeline_layer = wedged_layer
    server.start()
    h = server.submit(_images(1)[0])
    time.sleep(0.05)  # let the engine pick up the batch and block
    with pytest.raises(TimeoutError):
        server.shutdown(timeout=0.2)
    assert server._thread is not None  # a retry will re-join, not skip
    with pytest.raises(TimeoutError):  # request cancelled, caller not hung
        h.result(timeout=5.0)
    # the gate is closed: no new request may enqueue onto the wedged engine
    with pytest.raises(RuntimeError, match="not running"):
        server.submit(_images(1)[0])
    # the cancelled request must not be counted as served
    assert server.stats().completed == 0
    gate.set()  # un-wedge; the retry drains and joins cleanly
    server.shutdown(timeout=30.0)
    assert server._thread is None


def test_engine_admits_up_to_capacity_per_boundary():
    """With free inflight slots and a deep queue, the engine fills ALL
    slots at one layer boundary — the seed admitted one batch per
    iteration, filling capacity one layer-round late."""
    pipe, _ = _pipeline(bucket_sizes=(1,))
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated",
                         max_inflight=2)
    inflight_at_advance = []
    orig = server.cluster.run_pipeline_layer

    def spy(idx, x):
        inflight_at_advance.append(len(server.scheduler.inflight))
        return orig(idx, x)

    server.cluster.run_pipeline_layer = spy
    # queue two single-image batches BEFORE the engine starts: the first
    # boundary sees both waiting with both slots free
    handles = [server.scheduler.queue.submit(x) for x in _images(2)]
    with server:
        for h in handles:
            h.result(timeout=60.0)
    assert inflight_at_advance[0] == 2  # both admitted before any advance


def test_request_finish_first_writer_wins():
    """A shutdown-timeout cancel_all races the still-running engine; a
    result delivered first must survive the late cancellation (and a
    cancellation delivered first must survive a late result)."""
    from repro.serving.scheduler import Scheduler

    sched = Scheduler(lambda x: (x, x.shape[0]), max_batch=1)
    h1 = sched.submit(jnp.zeros((2, 12, 12)))
    h2 = sched.submit(jnp.zeros((2, 12, 12)))
    b1, b2 = sched.admit(), sched.admit()
    b1.requests[0].finish(result="done")     # engine completed b1 ...
    assert sched.cancel_all(TimeoutError("wedged")) == 2  # ... then cancel
    assert h1.result(timeout=1.0) == "done"  # result not clobbered
    with pytest.raises(TimeoutError):
        h2.result(timeout=1.0)
    b2.requests[0].finish(result="late")     # engine finishes b2 after all
    with pytest.raises(TimeoutError):        # cancellation not clobbered
        h2.result(timeout=1.0)
    assert not sched.has_work()


def test_server_pallas_backend_serves_matching_results():
    """End-to-end serving over the fused pallas worker kernel: the engine's
    bucketed batch programs run the custom MXU path and decode to the same
    outputs as the lax pipeline."""
    params = _params(STACK)
    specs = plan_layers(STACK, 12, 6, default_kab=(2, 4))
    pal = CodedPipeline(specs, params, backend="pallas", bucket_sizes=(1, 2))
    ref_pipe, _ = _pipeline(bucket_sizes=(1, 2))
    server = CodedServer(pal, StragglerModel.none(6), mode="simulated")
    assert server.cluster.backend == "pallas"
    xs = _images(3)
    with server:
        outs = [h.result(timeout=120.0) for h in server.submit_many(xs)]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-3, atol=1e-3
        )


def test_server_concurrent_clients():
    pipe, _ = _pipeline()
    ref_pipe, _ = _pipeline()
    server = CodedServer(pipe, StragglerModel.none(6), mode="simulated")
    xs = _images(6)
    outs = [None] * len(xs)
    errs = []

    def client(i):
        try:
            outs[i] = server.submit(xs[i]).result(timeout=60.0)
        except BaseException as e:  # surfaced below
            errs.append(e)

    with server:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(len(xs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60.0)
    assert not errs
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref_pipe.run(x)), rtol=1e-4, atol=1e-4
        )


# -- metrics --------------------------------------------------------------
def test_percentile_and_stats_math():
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert np.isnan(percentile([], 50))
    mc = MetricsCollector()
    for i in range(4):
        mc.record(RequestRecord(
            request_id=i, arrival_t=float(i), start_t=i + 1.0,
            finish_t=i + 3.0, bucket=4, batch_real=2,
        ))
    s = mc.stats()
    assert s.completed == 4
    assert s.queue_wait_p50_s == pytest.approx(1.0)
    assert s.execute_p50_s == pytest.approx(2.0)
    assert s.e2e_p50_s == pytest.approx(3.0)
    assert s.wall_s == pytest.approx(6.0)  # arrival 0 -> finish 6
    assert s.images_per_s == pytest.approx(4 / 6.0)
    assert s.mean_batch_real == pytest.approx(2.0)
    mc.reset()
    assert mc.stats().completed == 0
