"""The batched multi-layer coded inference engine (CodedPipeline).

Covers: batched CodedConv2d == batched lax conv; pipeline == naive
run_convls; output invariance over surviving-worker subsets
(any-delta-of-n); the encode-filters-exactly-once contract; worker-program
sharing across same-geometry layers; and the persistent cluster's
run_pipeline path under stragglers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodedConv2d, CodedPipeline, ConvGeometry, FcdccPlan
from repro.core.pipeline import plan_layers
from repro.models.cnn import CNN_SPECS, ConvL, init_cnn, run_convls
from repro.runtime import FcdccCluster, StragglerModel

RNG = np.random.default_rng(0)


def _batched_lax_conv(x, k, stride, padding):
    return jax.lax.conv_general_dilated(
        x, k, (stride, stride), ((padding, padding),) * 2,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


@pytest.mark.parametrize("n,k_a,k_b,ids", [
    (6, 2, 4, [5, 3]),
    (5, 2, 2, [4]),
    (4, 1, 8, [3, 1, 0, 2]),
    (4, 8, 1, [0, 3, 2, 1]),
])
def test_batched_coded_conv_matches_lax(n, k_a, k_b, ids):
    plan = FcdccPlan(n=n, k_a=k_a, k_b=k_b)
    geo = ConvGeometry(3, 8, 13, 11, 3, 3, 1, 1, k_a, k_b)
    layer = CodedConv2d(plan, geo)
    x = jnp.asarray(RNG.standard_normal((4, 3, 13, 11)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((8, 3, 3, 3)), jnp.float32)
    y = layer.run_simulated(x, k, ids)
    ref = _batched_lax_conv(x, k, 1, 1)
    assert y.shape == ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_batched_matches_per_image():
    plan = FcdccPlan(n=6, k_a=2, k_b=4)
    geo = ConvGeometry(2, 8, 12, 10, 3, 3, 2, 0, 2, 4)
    layer = CodedConv2d(plan, geo)
    x = jnp.asarray(RNG.standard_normal((3, 2, 12, 10)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((8, 2, 3, 3)), jnp.float32)
    yb = layer.run_simulated(x, k, [4, 1])
    for i in range(3):
        yi = layer.run_simulated(x[i], k, [4, 1])
        np.testing.assert_allclose(np.asarray(yb[i]), np.asarray(yi), atol=1e-5)


# a 3-layer stack exercising stride, padding, pooling, and a repeated
# geometry (l2/l3 share the worker-program signature)
STACK = [
    ConvL("l1", 2, 8, 3, stride=1, padding=1, pool=2),
    ConvL("l2", 8, 8, 3, padding=1),
    ConvL("l3", 8, 8, 3, padding=1),
]


def _stack_params(layers, seed=0):
    rng = np.random.default_rng(seed)
    return {
        l.name: jnp.asarray(
            rng.standard_normal((l.out_ch, l.in_ch, l.kernel, l.kernel))
            * (l.in_ch * l.kernel**2) ** -0.5,
            jnp.float32,
        )
        for l in layers
    }


def _naive_stack(layers, params, x):
    for l in layers:
        x = _batched_lax_conv(x, params[l.name], l.stride, l.padding)
        x = jax.nn.relu(x)
        if l.pool > 1:
            h, w = x.shape[-2:]
            h2, w2 = h - h % l.pool, w - w % l.pool
            x = jnp.max(
                x[..., :h2, :w2].reshape(
                    x.shape[:-2] + (h2 // l.pool, l.pool, w2 // l.pool, l.pool)
                ),
                axis=(-3, -1),
            )
    return x


def test_pipeline_matches_naive_and_survivor_invariance():
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    pipe = CodedPipeline(specs, params)
    x = jnp.asarray(RNG.standard_normal((3, 2, 16, 16)), jnp.float32)
    y = pipe.run(x)
    ref = _naive_stack(STACK, params, x)
    assert y.shape == ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-3, atol=2e-3)
    # any-delta-of-n: every survivor subset decodes to the same output
    y0 = np.asarray(y)
    for ids in ([5, 4, 3, 2, 1, 0], [2, 5, 0, 3], [4, 2]):
        ys = np.asarray(pipe.run(x, worker_ids=ids))
        np.testing.assert_allclose(ys, y0, rtol=1e-4, atol=1e-4)


def test_filters_encoded_exactly_once():
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    pipe = CodedPipeline(specs, params)
    assert pipe.filter_encode_calls == len(STACK)
    x = jnp.asarray(RNG.standard_normal((2, 2, 16, 16)), jnp.float32)
    pipe.run(x)
    pipe.run(x, worker_ids=[5, 3, 1, 0, 2, 4])
    pipe.run(x[0])  # single-image path
    assert pipe.filter_encode_calls == len(STACK)  # still once per layer


def test_worker_program_shared_across_same_geometry_layers():
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    pipe = CodedPipeline(specs, params)
    pipe.run(jnp.asarray(RNG.standard_normal((2, 2, 16, 16)), jnp.float32))
    # all three layers have stride 1 and the same (ell_a, ell_b): one program
    assert pipe.num_worker_programs == 1


def test_run_convls_wrapper_matches_pipeline():
    params = init_cnn("lenet5", jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.standard_normal((2, 1, 32, 32)), jnp.float32)
    naive = run_convls("lenet5", params, x)
    coded = run_convls("lenet5", params, x, plan=FcdccPlan(n=6, k_a=2, k_b=2))
    np.testing.assert_allclose(np.asarray(coded), np.asarray(naive),
                               rtol=2e-3, atol=2e-3)
    # single-image call keeps the seed's (C,H,W) contract
    one = run_convls("lenet5", params, x[0], plan=FcdccPlan(n=6, k_a=2, k_b=2))
    np.testing.assert_allclose(np.asarray(one), np.asarray(coded[0]), atol=1e-5)


def test_cluster_run_pipeline_under_stragglers():
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    pipe = CodedPipeline(specs, params)
    delays = np.zeros(6)
    delays[1] = 5.0          # straggler
    delays[4] = np.inf       # dead worker
    cluster = FcdccCluster(FcdccPlan(n=6, k_a=2, k_b=4),
                           StragglerModel(delays), mode="simulated")
    cluster.load_pipeline(pipe)
    x = jnp.asarray(RNG.standard_normal((2, 2, 16, 16)), jnp.float32)
    y, timings = cluster.run_pipeline(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(pipe.run(x)),
                               rtol=1e-4, atol=1e-4)
    assert len(timings) == len(STACK)
    for t in timings:
        assert 1 not in t.used_workers and 4 not in t.used_workers
        assert t.compute_s < 1.0
    # resident filters: the pipeline's encode-once contract survived the run
    assert pipe.filter_encode_calls == len(STACK)


def test_run_pipeline_immune_to_resident_name_collision():
    """A preload (or run_layer) under a name colliding with a pipeline layer
    must not swap foreign filters into the pipeline's decode — run_pipeline
    reads the pipeline's own coded filters, not the name-keyed store."""
    params = _stack_params(STACK)
    specs = plan_layers(STACK, 16, 6, default_kab=(2, 4))
    pipe = CodedPipeline(specs, params)
    cluster = FcdccCluster(FcdccPlan(n=6, k_a=2, k_b=4),
                           StragglerModel.none(6), mode="simulated")
    cluster.load_pipeline(pipe)
    x = jnp.asarray(RNG.standard_normal((2, 2, 16, 16)), jnp.float32)
    y0, _ = cluster.run_pipeline(x)
    foreign = _stack_params(STACK, seed=7)[specs[0].name]
    cluster.preload_filters(specs[0].name, specs[0].geo, foreign,
                            plan=specs[0].plan)
    y1, _ = cluster.run_pipeline(x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_cluster_run_layer_caches_filters_and_programs():
    plan = FcdccPlan(n=6, k_a=2, k_b=4)
    geo = ConvGeometry(3, 8, 12, 12, 3, 3, 1, 1, 2, 4)
    cluster = FcdccCluster(plan, StragglerModel.none(6), mode="simulated")
    x = jnp.asarray(RNG.standard_normal((3, 12, 12)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((8, 3, 3, 3)), jnp.float32)
    y1, _ = cluster.run_layer(geo, x, k, layer_name="conv")
    layer = cluster.coded_layer(geo)
    assert layer.filter_encode_calls == 1
    y2, _ = cluster.run_layer(geo, x, k, layer_name="conv")
    assert layer.filter_encode_calls == 1  # resident, not re-encoded
    # runs may pick different fastest-delta subsets; decode is exact up to
    # float32 roundoff of the (well-conditioned) recovery inverses
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    assert len(cluster._programs) == 1


def test_resident_filters_not_reused_across_plans():
    """Filters preloaded under one (k_a, k_b) code must never serve a
    run_layer under a different plan — wrong code matrices would decode to
    silently wrong output.  The resident entry is guarded by a filter-code
    key (plan + filter shape, NOT input resolution), so a plan change with
    no weights falls through to the need-k error, a resolution change keeps
    serving the same coded filters, and re-planning a layer replaces its
    entry instead of accumulating."""
    plan1 = FcdccPlan(n=12, k_a=2, k_b=4)
    plan2 = FcdccPlan(n=12, k_a=4, k_b=2)
    geo1 = ConvGeometry(3, 8, 12, 12, 3, 3, 1, 1, 2, 4)
    geo2 = ConvGeometry(3, 8, 12, 12, 3, 3, 1, 1, 4, 2)
    cluster = FcdccCluster(plan1, StragglerModel.none(12), mode="simulated")
    x = jnp.asarray(RNG.standard_normal((3, 12, 12)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((8, 3, 3, 3)), jnp.float32)
    cluster.preload_filters("conv", geo1, k, plan=plan1)
    with pytest.raises(ValueError, match="need k"):
        cluster.run_layer(geo2, x, None, layer_name="conv", plan=plan2)
    # coded filters are resolution-independent: a larger input under the
    # same code hits the resident store (its layer never encodes filters)
    geo1_hi = ConvGeometry(3, 8, 16, 16, 3, 3, 1, 1, 2, 4)
    x_hi = jnp.asarray(RNG.standard_normal((3, 16, 16)), jnp.float32)
    cluster.run_layer(geo1_hi, x_hi, None, layer_name="conv", plan=plan1)
    assert cluster.coded_layer(geo1_hi, plan1).filter_encode_calls == 0
    # the original plan still hits its resident filters (no re-encode) ...
    y1, _ = cluster.run_layer(geo1, x, None, layer_name="conv", plan=plan1)
    assert cluster.coded_layer(geo1, plan1).filter_encode_calls == 1
    # ... and passing k under the new plan encodes fresh, correct filters,
    # replacing the layer's resident entry (no unbounded growth)
    y2, _ = cluster.run_layer(geo2, x, k, layer_name="conv", plan=plan2)
    assert len(cluster._resident) == 1
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_relu_pool_odd_spatial_dims():
    """Odd H/W: the trailing row/column that doesn't fill a pool window is
    cropped (floor semantics), matching the naive reference."""
    from repro.core.pipeline import relu_pool

    x = jnp.asarray(RNG.standard_normal((2, 3, 5, 7)), jnp.float32)
    y = relu_pool(x, 2)
    assert y.shape == (2, 3, 2, 3)  # 5 -> 4 -> 2, 7 -> 6 -> 3
    r = np.maximum(np.asarray(x), 0.0)[..., :4, :6]
    ref = r.reshape(2, 3, 2, 2, 3, 2).max(axis=(-3, -1))
    np.testing.assert_allclose(np.asarray(y), ref, atol=0)
    # pool=1 is the identity after relu, odd dims untouched
    y1 = relu_pool(x, 1)
    assert y1.shape == x.shape
    np.testing.assert_allclose(np.asarray(y1), np.maximum(np.asarray(x), 0.0))
    # pool window larger than the axis: everything cropped away is an error
    # surface worth pinning — a 3x3 pool on H=5,W=7 keeps floor(5/3), floor(7/3)
    y3 = relu_pool(x, 3)
    assert y3.shape == (2, 3, 1, 2)


def test_auto_partition_planner_feasible():
    _, layers = CNN_SPECS["alexnet"]
    specs = plan_layers(layers, 113, 12, q=16)
    assert [s.name for s in specs] == [l.name for l in layers]
    for s in specs:
        assert s.plan.k_a * s.plan.k_b == 16
        assert s.plan.delta <= 12
    # spatial bookkeeping: each layer's input hw is the previous out_hw
    hw = 113
    for s, l in zip(specs, layers):
        assert s.geo.height == hw
        hw = s.out_hw


@pytest.mark.parametrize("arch,hw,kab", [
    ("lenet5", 20, (2, 4)),
    pytest.param("alexnet", 51, (2, 4), marks=pytest.mark.slow),
    pytest.param("vgg16", 32, (2, 4), marks=pytest.mark.slow),
])
def test_pipeline_pallas_matches_lax(arch, hw, kab):
    """backend="pallas" CodedPipeline.run / run_prepared == backend="lax"
    for every CNN_SPECS geometry, batched, with the jitted worker-program
    traces bounded by (distinct geometries) x (buckets) — the fused pallas
    worker keeps the serving engine's bounded-program contract."""
    params = init_cnn(arch, jax.random.PRNGKey(0))
    specs = plan_layers(CNN_SPECS[arch][1], hw, 6, default_kab=kab)
    c0 = CNN_SPECS[arch][1][0].in_ch
    x = jnp.asarray(RNG.standard_normal((2, c0, hw, hw)), jnp.float32)
    ref = np.asarray(CodedPipeline(specs, params).run(x))
    pal = CodedPipeline(specs, params, backend="pallas", bucket_sizes=(2,))
    y = np.asarray(pal.run(x))
    assert y.shape == ref.shape
    np.testing.assert_allclose(y, ref, rtol=5e-3, atol=5e-3)
    # the serving fast path lowers through the same fused pallas programs
    yp = np.asarray(pal.run_prepared(x, worker_ids=[5, 1, 3, 0]))
    np.testing.assert_allclose(yp, ref, rtol=5e-3, atol=5e-3)
    n_geos = len({(s.program_key, s.geo) for s in pal.specs})
    assert pal.worker_program_traces <= n_geos * len(pal.bucket_sizes)


@pytest.mark.slow
def test_vgg16_pipeline_batch():
    params = init_cnn("vgg16", jax.random.PRNGKey(1))
    x = jnp.asarray(RNG.standard_normal((2, 3, 56, 56)), jnp.float32)
    naive = run_convls("vgg16", params, x)
    pipe_specs = plan_layers(CNN_SPECS["vgg16"][1], 56, 6, default_kab=(2, 4))
    pipe = CodedPipeline(pipe_specs, params)
    y = pipe.run(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(naive),
                               rtol=5e-3, atol=5e-3)
    assert pipe.filter_encode_calls == 13
    assert pipe.num_worker_programs <= 3
