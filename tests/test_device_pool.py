"""The device-resident worker pool (``pool="device"``).

Each coded worker pinned to its own ``jax.Device``: coded filters resident
per device, per-device jitted programs, async dispatch, fastest-delta
reaped via per-array readiness.  These tests need a multi-device host —
on CPU boxes run them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (scripts/ci.sh
does); on a single-device host the whole module skips, keeping the tier-1
suite's behavior identical to the thread-pool-only seed.

Covers: threads-vs-device bit-parity (forced fastest-delta subsets) across
the CNN archs x {lax, pallas}; fastest-delta discard under a slowed
device; dead-device elastic re-plan; the per-device bounded-program
contract; resident filter placement; pool resolution rules; and serving
through the device pool.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FcdccPlan
from repro.core.fcdcc import ConvGeometry
from repro.core.pipeline import build_cnn_pipeline
from repro.models.cnn import CNN_SPECS, init_cnn, input_hw
from repro.runtime import (
    FcdccCluster,
    StragglerModel,
    run_layer_elastic,
)
from repro.runtime.devicepool import resolve_pool

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="device pool needs a multi-device host (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)",
)

RNG = np.random.default_rng(0)
N = 6


def _pipe(arch, backend="lax", n=N, kab=(2, 4)):
    params = init_cnn(arch, jax.random.PRNGKey(0))
    return build_cnn_pipeline(arch, params, n, default_kab=kab,
                              input_hw=input_hw(arch, smoke=True),
                              backend=backend)


def _in_shape(pipe, batch):
    geo = pipe.specs[0].geo
    return (batch, geo.in_channels, geo.height, geo.width)


def _forced_subset_straggler(pipe, n=N):
    """Finite delays on workers delta..n-1: both pools must keep exactly
    the undelayed subset, making their decodes bit-identical."""
    dm = max(spec.plan.delta for spec in pipe.specs)
    delays = np.zeros(n)
    delays[dm:] = 0.3
    return StragglerModel(delays), dm


def _run_pool(pipe, pool, x, straggler, arch):
    cluster = FcdccCluster(pipe.specs[0].plan, straggler=straggler,
                           mode="threads", backend=pipe.backend, pool=pool)
    try:
        cluster.load_pipeline(pipe, arch)
        y, timings = cluster.run_pipeline(x, model=arch)
        return np.asarray(y), timings, cluster
    finally:
        cluster.shutdown()


# -- bit-parity across pools ----------------------------------------------
@pytest.mark.parametrize("arch", sorted(CNN_SPECS))
@pytest.mark.parametrize("backend", ["lax", "pallas"])
def test_pools_bit_identical_forced_subset(arch, backend):
    """With the fastest-delta subset pinned, the device pool's gather +
    decode is bitwise the thread pool's: same shards, same fp32 GEMMs."""
    if backend == "pallas" and arch == "vgg16":
        pytest.skip("interpret-mode vgg16 is minutes-slow; lax covers the "
                    "pool seam, pallas parity is covered by the small archs")
    pipe_t, pipe_d = _pipe(arch, backend), _pipe(arch, backend)
    straggler, dm = _forced_subset_straggler(pipe_t)
    c0 = pipe_t.specs[0].geo.in_channels
    hw0 = input_hw(arch, smoke=True)
    x = np.asarray(RNG.standard_normal((1, c0, hw0, hw0)), np.float32)
    yt, tt, _ = _run_pool(pipe_t, "threads", x, straggler, arch)
    yd, td, _ = _run_pool(pipe_d, "device", x, straggler, arch)
    assert np.array_equal(yt, yd)
    delayed = set(range(dm, N))
    for t in tt + td:
        assert not (set(t.used_workers) & delayed), (
            f"{t.name}: decode consumed a delayed shard {t.used_workers}")


# -- fastest-delta discard ------------------------------------------------
def test_slowed_device_discarded():
    """A delayed device's shard must be excluded from the decode subset and
    its worker slot marked nan (discarded) — never silently gathered."""
    delays = np.zeros(N)
    delays[0] = 3.0
    pipe = _pipe("lenet5")
    cluster = FcdccCluster(pipe.specs[0].plan, StragglerModel(delays),
                           mode="threads", pool="device")
    try:
        cluster.load_pipeline(pipe)
        x = np.asarray(RNG.standard_normal(_in_shape(pipe, 1)), np.float32)
        y, timing = cluster.run_pipeline_layer(0, x)
        assert 0 not in timing.used_workers
        assert np.isnan(timing.worker_compute_s[0])
        assert len(timing.used_workers) == pipe.specs[0].plan.delta
        assert all(np.isfinite(timing.worker_compute_s[i])
                   for i in timing.used_workers)
    finally:
        cluster.shutdown()


def test_dead_device_elastic_replan():
    """inf-delay devices never dispatch; when fewer than delta survive the
    elastic driver shrinks the subtask grid and retries on the device
    pool."""
    plan = FcdccPlan(n=N, k_a=2, k_b=4)
    geo = ConvGeometry(in_channels=2, height=12, width=12, out_channels=8,
                       kernel_h=3, kernel_w=3, stride=1, padding=1)
    x = jnp.asarray(RNG.standard_normal((2, 12, 12)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((8, 2, 3, 3)), jnp.float32)
    ref = FcdccCluster(plan, None, mode="threads").run_layer(geo, x, k)[0]
    d = np.zeros(N)
    d[:5] = np.inf  # 5 dead of 6: delta=8's plan cannot survive
    y, _, plan2 = run_layer_elastic(
        plan, geo, x, k, StragglerModel(d), mode="threads", pool="device")
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-3)
    assert plan2.delta < plan.delta


# -- bounded programs per device ------------------------------------------
def test_bounded_programs_per_device():
    """After serving several buckets, every device's worker-program trace
    count stays <= (layer geometries) x (buckets) — compiles are per cell,
    never per round or per request."""
    pipe = _pipe("lenet5")
    buckets = (1, 2)
    cluster = FcdccCluster(pipe.specs[0].plan, None, mode="threads",
                           pool="device")
    try:
        cluster.load_pipeline(pipe)
        for b in buckets:
            x = np.asarray(RNG.standard_normal(_in_shape(pipe, b)), np.float32)
            for _ in range(3):  # repeats must not re-trace
                cluster.run_pipeline(x)
        traces = cluster._pool_impl().program_traces()
        assert len(traces) == min(N, len(jax.devices()))
        bound = len(pipe.specs) * len(buckets)
        assert all(c <= bound for c in traces.values()), (traces, bound)
    finally:
        cluster.shutdown()


# -- residency + placement ------------------------------------------------
def test_filters_resident_on_worker_devices():
    pipe = _pipe("lenet5")
    cluster = FcdccCluster(pipe.specs[0].plan, None, mode="threads",
                           pool="device")
    try:
        cluster.load_pipeline(pipe, "m")
        impl = cluster._pool_impl()
        devs = cluster.worker_devices
        assert devs is not None and len(devs) == N
        for spec in pipe.specs:
            _, shards = impl._filters[f"m/{spec.name}"]
            assert len(shards) == N
            for i, shard in enumerate(shards):
                assert shard.devices() == {devs[i]}
        # unload reclaims every per-device shard of the namespace
        cluster.unload_pipeline("m")
        assert not any(key.startswith("m/") for key in impl._filters)
    finally:
        cluster.shutdown()


def test_worker_devices_round_robin_when_fewer_devices():
    n_big = len(jax.devices()) + 3  # more workers than devices
    pipe = build_cnn_pipeline(
        "lenet5", init_cnn("lenet5", jax.random.PRNGKey(0)), n_big,
        default_kab=(2, 4), input_hw=12)
    cluster = FcdccCluster(pipe.specs[0].plan, None, mode="threads",
                           pool="device")
    try:
        cluster.load_pipeline(pipe)
        devs = cluster.worker_devices
        assert len(devs) == n_big
        assert devs[0] == devs[len(jax.devices())]  # wraps round-robin
        x = np.asarray(RNG.standard_normal(_in_shape(pipe, 1)), np.float32)
        y, _ = cluster.run_pipeline(x)
        ref, _ = FcdccCluster(pipe.specs[0].plan, None,
                              mode="threads").run_pipeline(x, pipe)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
    finally:
        cluster.shutdown()


# -- pool resolution ------------------------------------------------------
def test_resolve_pool_rules():
    assert resolve_pool(None, "threads") == "device"  # multi-device host
    assert resolve_pool(None, "threads", devices=jax.devices()[:2]) == "device"
    assert resolve_pool(None, "simulated") == "threads"
    assert resolve_pool("threads", "threads") == "threads"
    assert resolve_pool("device", "threads") == "device"
    with pytest.raises(ValueError, match="simulated"):
        resolve_pool("device", "simulated")
    with pytest.raises(ValueError, match="unknown pool"):
        resolve_pool("gpu", "threads")
    with pytest.raises(ValueError, match="simulated"):
        FcdccCluster(FcdccPlan(n=N, k_a=2, k_b=4), None, mode="simulated",
                     pool="device")


# -- serving through the device pool --------------------------------------
def test_serving_on_device_pool():
    from repro.serving import CodedServer

    pipe, ref = _pipe("lenet5"), _pipe("lenet5")
    server = CodedServer(pipe, StragglerModel.none(N), mode="threads",
                         pool="device")
    xs = [jnp.asarray(RNG.standard_normal(_in_shape(pipe, 1)[1:]),
                      jnp.float32) for _ in range(3)]
    with server:
        assert server.cluster.pool == "device"
        outs = [h.result(timeout=120.0) for h in server.submit_many(xs)]
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.run(x)), rtol=1e-4, atol=1e-4)


def test_fused_transitions_on_device_pool():
    """Partition-resident transitions on the device pool: the coded shares
    carried between rounds re-dispatch to the worker devices, and with a
    forced fastest-delta subset the end result is bitwise the thread
    pool's."""
    def mk():
        return build_cnn_pipeline(
            "lenet5", init_cnn("lenet5", jax.random.PRNGKey(0)), N,
            default_kab=(2, 4), input_hw=input_hw("lenet5", smoke=True),
            fuse_transitions=True)

    pipe_t, pipe_d = mk(), mk()
    straggler, dm = _forced_subset_straggler(pipe_t)
    x = np.asarray(RNG.standard_normal(_in_shape(pipe_t, 1)), np.float32)
    yt, tt, _ = _run_pool(pipe_t, "threads", x, straggler, "m")
    yd, td, _ = _run_pool(pipe_d, "device", x, straggler, "m")
    assert np.array_equal(yt, yd)
    delayed = set(range(dm, N))
    for t in tt + td:
        assert not (set(t.used_workers) & delayed)


# -- non-blocking readiness + adaptive collect backoff ---------------------
def test_device_pool_round_ready_nonblocking():
    """The dispatch/collect split on the device pool: ``round_ready`` is
    False while the delta-th shard's deferred dispatch has not landed,
    flips True without blocking, and ``collect(block=False)`` mirrors it."""
    pipe = _pipe("lenet5")
    dm = max(spec.plan.delta for spec in pipe.specs)
    delays = np.full(N, 0.4)  # every dispatch deferred: nothing ready early
    cluster = FcdccCluster(pipe.specs[0].plan, StragglerModel(delays),
                           mode="threads", pool="device")
    try:
        cluster.load_pipeline(pipe)
        x = np.asarray(RNG.standard_normal(_in_shape(pipe, 1)), np.float32)
        rnd = cluster.dispatch_pipeline_layer(0, x)
        assert not cluster.round_ready(rnd)
        assert cluster.collect(rnd.pending, dm, block=False) is None
        deadline = time.perf_counter() + 30.0
        while not cluster.round_ready(rnd):
            assert time.perf_counter() < deadline
            time.sleep(0.01)
        y, timing = cluster.collect_pipeline_layer(rnd)
        assert len(timing.used_workers) == dm
        refc = FcdccCluster(pipe.specs[0].plan, None, mode="threads")
        try:
            refc.load_pipeline(pipe)
            ref, _ = refc.run_pipeline_layer(0, x)
        finally:
            refc.shutdown()
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-4)
    finally:
        cluster.shutdown()


def test_device_pool_adaptive_poll_default_and_override():
    """``poll_interval_s=None`` (the default) collects with the adaptive
    5us..1ms backoff; an explicit value is kept verbatim as a fixed period
    (the test override).  Both produce identical results."""
    from repro.runtime.devicepool import DeviceWorkerPool

    assert DeviceWorkerPool._POLL_MIN == pytest.approx(5e-6)
    assert DeviceWorkerPool._POLL_MAX == pytest.approx(1e-3)
    outs = {}
    # forced fastest-delta subset: without it the reap race would pick
    # different (all-correct) shard subsets per run and bits would differ
    straggler, _ = _forced_subset_straggler(_pipe("lenet5"))
    x = np.asarray(RNG.standard_normal(_in_shape(_pipe("lenet5"), 1)),
                   np.float32)
    for label, pool_kwargs in (("adaptive", {}),
                               ("fixed", {"poll_interval_s": 5e-5})):
        pipe = _pipe("lenet5")
        impl = DeviceWorkerPool(N, straggler, **pool_kwargs)
        try:
            assert impl._poll_interval_s == pool_kwargs.get("poll_interval_s")
            cluster = FcdccCluster(pipe.specs[0].plan, None, mode="threads",
                                   pool="device")
            cluster._pool_obj = impl  # inject before the lazy default build
            cluster.load_pipeline(pipe)
            outs[label] = np.asarray(cluster.run_pipeline(x)[0])
        finally:
            cluster.shutdown()
    np.testing.assert_array_equal(outs["adaptive"], outs["fixed"])
