"""Soft dependency on hypothesis.

``from _hypothesis_compat import given, settings, st`` gives the real
hypothesis when installed; otherwise property tests are individually skipped
at run time while the rest of the module still collects and runs (the seed
errored out 5 whole modules at collection when hypothesis was missing).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(_fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass

            _skipped.__name__ = _fn.__name__
            _skipped.__doc__ = _fn.__doc__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        """Accepts any strategy construction; never materializes values."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
