"""Sharding resolution rules + HLO cost analyzer correctness."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import analyze_hlo
from repro.sharding import resolve_pspec

MESH = {"data": 16, "model": 16}
MESH3 = {"pod": 2, "data": 16, "model": 16}


def test_resolve_basic():
    assert resolve_pspec((256, 4096), (("pod", "data"), None), MESH) == P("data", None)
    assert resolve_pspec((256, 4096), (("pod", "data"), None), MESH3) == P(("pod", "data"), None)


def test_resolve_divisibility_fallback():
    # 9 heads don't divide model=16 -> replicate
    assert resolve_pspec((30, 9, 64), (None, "model", None), MESH) == P(None, None, None)
    # flattened 9*64=576 DOES divide -> shards
    assert resolve_pspec((30, 576), (None, "model"), MESH) == P(None, "model")
    # each mesh axis used at most once
    assert resolve_pspec((32, 32), ("model", "model"), MESH) == P("model", None)


def test_resolve_candidate_chain():
    # first candidate fails (8 % 16), single-axis retry also fails -> None
    assert resolve_pspec((8,), (("model",),), MESH) == P(None)


def test_hlo_scan_trip_counts():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    x = jnp.ones((64, 64))
    w = jnp.ones((8, 64, 64))
    cost = analyze_hlo(jax.jit(f).lower(x, w).compile().as_text())
    assert cost.dot_flops == 8 * 2 * 64**3


def test_hlo_nested_scan():
    def g(x, w):
        def outer(cc, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), None
            ci, _ = jax.lax.scan(inner, cc, None, length=4)
            return ci, None
        cc, _ = jax.lax.scan(outer, x, w)
        return cc

    x = jnp.ones((64, 64))
    w = jnp.ones((8, 64, 64))
    cost = analyze_hlo(jax.jit(g).lower(x, w).compile().as_text())
    assert cost.dot_flops == 8 * 4 * 2 * 64**3


def test_hlo_collective_accounting():
    """Synthetic HLO string: ring factors for each collective type."""
    hlo = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main (p: f32[128,8]) -> f32[128,8] {
  %p = f32[128,8]{1,0} parameter(0)
  %ar = f32[128,8]{1,0} all-reduce(%p), replica_groups=[1,4]<=[4], to_apply=%add
  %ag = f32[512,8]{1,0} all-gather(%ar), replica_groups=[1,4]<=[4], dimensions={0}
  ROOT %cp = f32[128,8]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    c = analyze_hlo(hlo, 4)
    sz = 128 * 8 * 4
    assert np.isclose(c.collectives["all-reduce"], 2 * sz * 3 / 4)
    assert np.isclose(c.collectives["all-gather"], 4 * sz * 3 / 4)
    assert np.isclose(c.collectives["collective-permute"], sz)


def test_dryrun_smoke_cell():
    """One tiny dry-run cell end-to-end in a subprocess (256 fake devices)."""
    import os
    import subprocess
    import sys

    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "train_4k", "--smoke-scale", "16", "--force"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        timeout=560,
    )
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
    assert "[ok" in out.stdout
