"""Autotune ledger: sweep-once caching, persistence, pipeline consultation."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import build_cnn_pipeline
from repro.kernels import autotune
from repro.models.cnn import init_cnn

RNG = np.random.default_rng(7)


@pytest.fixture
def ledger(tmp_path, monkeypatch):
    """An isolated, initially-empty ledger file for each test."""
    path = tmp_path / "autotune_cache.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_cache(memory_only=True)
    yield path
    autotune.clear_cache(memory_only=True)


def test_matmul_tune_roundtrip(ledger):
    win = autotune.tune_matmul(16, 16, 512, relu=True, repeat=1)
    assert win in [dict(c) for c in autotune.MATMUL_CANDIDATES]
    assert autotune.sweep_count() == 1
    # second call: cache hit, no re-sweep
    assert autotune.tune_matmul(16, 16, 512, relu=True, repeat=1) == win
    assert autotune.sweep_count() == 1
    # trace-time lookup sees the winner; other cells miss
    assert autotune.matmul_params(16, 16, 512, relu=True) == win
    assert autotune.matmul_params(16, 16, 512, relu=False) is None
    assert autotune.matmul_params(17, 16, 512, relu=True) is None


def test_worker_tune_roundtrip(ledger):
    xe, ke = (2, 1, 2, 12, 16), (2, 3, 2, 3, 3)
    win = autotune.tune_worker(xe, ke, 1, repeat=1)
    assert autotune.sweep_count() == 1
    assert autotune.tune_worker(xe, ke, 1, repeat=1) == win
    assert autotune.sweep_count() == 1
    assert autotune.worker_params(xe, ke, 1) == win
    # the winner runs and matches the untuned default bitwise
    from repro.kernels.conv2d.kernel import coded_worker_pallas

    x = jnp.asarray(RNG.standard_normal(xe), jnp.float32)
    k = jnp.asarray(RNG.standard_normal(ke), jnp.float32)
    assert np.array_equal(
        np.asarray(coded_worker_pallas(x, k, 1, **win)),
        np.asarray(coded_worker_pallas(x, k, 1)),
    )


def test_ledger_file_persistence(ledger):
    win = autotune.tune_matmul(8, 8, 256, repeat=1)
    assert ledger.exists()
    on_disk = json.loads(ledger.read_text())
    key = autotune.matmul_key(8, 8, 256)
    assert on_disk[key]["params"] == win
    assert len(on_disk[key]["swept"]) == len(autotune.MATMUL_CANDIDATES)
    # a fresh process (simulated: drop memory, reload file) sees the winner
    autotune.clear_cache(memory_only=True)
    assert autotune.matmul_params(8, 8, 256) == win
    assert autotune.sweep_count() == 0  # reload is not a sweep


def test_lookups_never_sweep(ledger):
    assert autotune.matmul_params(31, 41, 59) is None
    assert autotune.worker_params((1, 1, 1, 8, 8), (1, 1, 1, 3, 3), 1) is None
    assert autotune.sweep_count() == 0
    assert not ledger.exists()


def _small_pipe(**kw):
    params = init_cnn("lenet5", jax.random.PRNGKey(0))
    return build_cnn_pipeline("lenet5", params, 8, default_kab=(2, 4),
                              backend="pallas", **kw), params


def test_pipeline_autotune_consulted_and_bounded(ledger):
    """``autotune_kernels`` sweeps each cell once; the rebuilt tuned
    programs stay inside the bounded-program contract and match lax."""
    pipe, params = _small_pipe(fuse_transitions=True, bucket_sizes=(2,))
    tuned = pipe.autotune_kernels(repeat=1)
    swept = autotune.sweep_count()
    assert swept == len(tuned) > 0
    # idempotent: every cell is a cache hit the second time
    assert pipe.autotune_kernels(repeat=1) == tuned
    assert autotune.sweep_count() == swept
    x = jnp.asarray(RNG.standard_normal((2, 1, 32, 32)), jnp.float32)
    y = pipe.run(x)
    ref, _ = _small_pipe()
    ref = build_cnn_pipeline("lenet5", params, 8, default_kab=(2, 4),
                             backend="lax").run(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-3)
    assert (pipe.worker_program_traces + pipe.transition_program_traces
            <= pipe.program_trace_bound)


def test_pipeline_autotune_noop_on_lax_backend(ledger):
    pipe, _ = _small_pipe()
    pipe.backend = "lax"
    assert pipe.autotune_kernels() == {}
    assert autotune.sweep_count() == 0


def test_donate_transitions_default_and_override():
    """CPU auto-disables donation (XLA:CPU warns and copies); an explicit
    flag wins either way and the donating program still computes correctly
    when fed fresh buffers each call."""
    pipe, params = _small_pipe(fuse_transitions=True)
    assert pipe.donate_transitions == (jax.default_backend() != "cpu")
    don, _ = _small_pipe(fuse_transitions=True, donate_transitions=True)
    assert don.donate_transitions is True
    x = jnp.asarray(RNG.standard_normal((1, 1, 32, 32)), jnp.float32)
    ref = build_cnn_pipeline("lenet5", params, 8, default_kab=(2, 4),
                             backend="lax").run(x)
    np.testing.assert_allclose(np.asarray(don.run(x)), np.asarray(ref),
                               atol=1e-3)
