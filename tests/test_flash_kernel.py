"""Pallas flash-attention kernel vs jnp oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention, flash_attention_ref

RNG = np.random.default_rng(7)


def _mk(b, sq, sk, h, d, dtype=np.float32):
    q = jnp.asarray(RNG.standard_normal((b, sq, h, d)).astype(dtype))
    k = jnp.asarray(RNG.standard_normal((b, sk, h, d)).astype(dtype))
    v = jnp.asarray(RNG.standard_normal((b, sk, h, d)).astype(dtype))
    return q, k, v


@pytest.mark.parametrize("b,s,h,d,bq,bk", [
    (2, 256, 2, 64, 128, 128),
    (1, 128, 4, 32, 64, 64),
    (2, 200, 1, 64, 128, 128),  # non-multiple seq (padding path)
    (1, 384, 2, 128, 128, 64),  # asymmetric blocks
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_matches_ref(b, s, h, d, bq, bk, causal):
    q, k, v = _mk(b, s, s, h, d)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    bh = b * h
    qf = q.transpose(0, 2, 1, 3).reshape(bh, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(bh, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(bh, s, d)
    ref = flash_attention_ref(qf, kf, vf, causal=causal)
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_flash_kernel_bf16():
    q, k, v = _mk(1, 128, 128, 2, 64)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    out = flash_attention(qb, kb, vb, causal=True)
    ref = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=3e-2
    )


def test_flash_kernel_cross_attention_lengths():
    """sq != sk (decode-style / cross-attention) with padding."""
    q, k, v = _mk(2, 64, 200, 2, 32)
    out = flash_attention(q, k, v, causal=False, bq=64, bk=128)
    bh = 4
    qf = q.transpose(0, 2, 1, 3).reshape(bh, 64, 32)
    kf = k.transpose(0, 2, 1, 3).reshape(bh, 200, 32)
    vf = v.transpose(0, 2, 1, 3).reshape(bh, 200, 32)
    ref = flash_attention_ref(qf, kf, vf, causal=False)
    ref = ref.reshape(2, 2, 64, 32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)
