"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.coded_gemm import coded_gemm, coded_gemm_ref, crme_decode, crme_encode
from repro.kernels.conv2d import conv2d_im2col, conv2d_ref
from repro.kernels.matmul import matmul, matmul_ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("m,k,n", [
    (7, 5, 9), (128, 128, 128), (130, 257, 64), (1, 300, 1), (200, 64, 384),
    (8, 8, 8), (129, 1, 129),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_matmul_sweep(m, k, n, dtype):
    a = jnp.asarray(RNG.standard_normal((m, k)).astype(dtype))
    b = jnp.asarray(RNG.standard_normal((k, n)).astype(dtype))
    y = matmul(a, b)
    r = matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(r, np.float32), atol=2e-2, rtol=2e-2
    )


@pytest.mark.parametrize("shape", [
    (3, 12, 10, 8, 3, 3, 1, 1),
    (2, 16, 9, 5, 3, 2, 2, 0),
    (1, 7, 7, 4, 5, 5, 1, 2),
    (4, 9, 9, 3, 1, 1, 1, 0),
])
def test_conv2d_sweep(shape):
    C, H, W, N, KH, KW, s, p = shape
    x = jnp.asarray(RNG.standard_normal((C, H, W)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((N, C, KH, KW)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(conv2d_im2col(x, k, s, p)),
        np.asarray(conv2d_ref(x, k, s, p)),
        atol=1e-3,
    )


@pytest.mark.parametrize("m,k,n", [
    (7, 5, 9),        # odd everything: pad + trailing slice
    (128, 256, 128),  # block-aligned: the skip-pad fast path
    (16, 16, 3600),   # skinny decode-GEMM shape (q x q x F)
    (1, 300, 1),
])
@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_matmul_pipelined_bit_parity(m, k, n, relu, dtype):
    """The multi-buffered streaming lowering (num_buffers >= 2) is
    bit-identical to the single-buffered grid-K kernel: same bk-chunk fp32
    accumulation order, K zero-padding exact under fp32 addition."""
    from repro.kernels.matmul.kernel import matmul_pallas

    a = jnp.asarray(RNG.standard_normal((m, k)).astype(dtype))
    b = jnp.asarray(RNG.standard_normal((k, n)).astype(dtype))
    ref = np.asarray(matmul_pallas(a, b, relu=relu, num_buffers=1))
    for nb in (2, 4):
        y = np.asarray(matmul_pallas(a, b, relu=relu, num_buffers=nb))
        assert np.array_equal(y, ref), f"num_buffers={nb} diverged bitwise"
    if relu:
        assert (ref >= 0).all()


@pytest.mark.parametrize("ea,b,eb,nb,c,hh,wp,kh,kw,stride", [
    (2, 2, 2, 4, 3, 18, 32, 5, 5, 1),   # typical coded cell
    (2, 1, 2, 2, 1, 9, 9, 3, 3, 2),     # stride > 1, odd geometry
    (1, 2, 2, 3, 4, 16, 16, 3, 3, 1),   # degenerate ell_a = 1
    (3, 1, 1, 4, 2, 11, 13, 3, 5, 1),   # degenerate ell_b = 1, odd M/N/K
    (2, 2, 2, 4, 8, 10, 16, 1, 1, 1),   # 1x1 kernel, aligned K = 8
])
def test_worker_fused_vs_twostep_bit_parity(ea, b, eb, nb, c, hh, wp, kh,
                                            kw, stride):
    """In-kernel im2col and the two-step HBM-patch path are bit-identical:
    identical patch ordering (C, KH, KW) and identical fp32 chunk order."""
    from repro.kernels.conv2d.kernel import coded_worker_pallas

    xe = jnp.asarray(RNG.standard_normal((ea, b, c, hh, wp)), jnp.float32)
    ke = jnp.asarray(RNG.standard_normal((eb, nb, c, kh, kw)), jnp.float32)
    two = np.asarray(coded_worker_pallas(xe, ke, stride, fused_im2col=False))
    fused = np.asarray(coded_worker_pallas(xe, ke, stride, fused_im2col=True))
    assert np.array_equal(fused, two)
    ho = (hh - kh) // stride + 1
    if ho > 1:  # a split output-row tile must agree with the full-height one
        split = np.asarray(
            coded_worker_pallas(xe, ke, stride, fused_im2col=True, bo=1))
        assert np.array_equal(split, two)


def test_matmul_aligned_skips_padding():
    """Block-aligned operands take the no-copy path: no pad, no slice."""
    import jax

    from repro.kernels.matmul.kernel import matmul_pallas

    a = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((128, 256)), jnp.float32)
    text = jax.make_jaxpr(
        lambda a_, b_: matmul_pallas(a_, b_, num_buffers=2))(a, b).pretty_print()
    assert "pad" not in text and "slice" not in text
    # and an unaligned shape still pads (the guard is shape-specific)
    a2 = jnp.asarray(RNG.standard_normal((100, 100)), jnp.float32)
    b2 = jnp.asarray(RNG.standard_normal((100, 100)), jnp.float32)
    text2 = jax.make_jaxpr(
        lambda a_, b_: matmul_pallas(a_, b_, num_buffers=2))(a2, b2).pretty_print()
    assert "pad" in text2


@settings(max_examples=20, deadline=None)
@given(q=st.integers(2, 40), f=st.integers(1, 700), seed=st.integers(0, 99))
def test_coded_gemm_property(q, f, seed):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.standard_normal((q, q)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((q, f)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(coded_gemm(c, t)), np.asarray(coded_gemm_ref(c, t)), atol=1e-3
    )


def test_crme_encode_decode_kernels_roundtrip():
    """Pallas encode -> decode recovers the tensor list exactly."""
    from repro.core.crme import make_axis_codes, recovery_matrix

    k_a, n = 4, 5
    a, b = make_axis_codes(k_a, 2, n)
    parts = jnp.asarray(RNG.standard_normal((k_a, 3, 6, 4)), jnp.float32)
    coded = crme_encode(parts, a.matrix)
    assert coded.shape == (2 * n, 3, 6, 4)
    # decode identity check on the A axis alone: solve A_sub^T y = coded_sub
    sub = [0, 1, 2, 3]  # 4 coded streams = k_a
    e = a.matrix[:, sub]
    d = np.linalg.inv(e.T)
    back = crme_decode(d, coded[jnp.asarray(sub)])
    np.testing.assert_allclose(np.asarray(back), np.asarray(parts), atol=1e-4)


@pytest.mark.parametrize("shape", [
    # (ea, b, c, hh, wp, eb, nb, kh, kw, stride)
    (2, None, 3, 14, 14, 2, 4, 3, 3, 1),   # multi-share, multi-group
    (2, 2, 8, 12, 16, 2, 8, 3, 3, 1),      # batched
    (1, None, 4, 17, 17, 1, 6, 5, 5, 2),   # strided, 5x5
    (3, 1, 16, 10, 10, 2, 16, 1, 1, 1),    # 1x1: widest channel windows
    (1, None, 2, 9, 9, 3, 5, 2, 2, 1),     # tiny odd geometry
])
def test_worker_stream_k_bit_parity(shape):
    """The K-streamed fused worker kernel (share in HBM, per-chunk channel
    windows double-buffered into VMEM) is bit-identical to the
    whole-share-resident fused kernel: same taps, same bk-chunk fp32
    accumulation order."""
    from repro.kernels.conv2d.kernel import coded_worker_pallas

    ea, b, c, hh, wp, eb, nb, kh, kw, stride = shape
    xshape = (ea, b, c, hh, wp) if b else (ea, c, hh, wp)
    xe = jnp.asarray(RNG.standard_normal(xshape), jnp.float32)
    ke = jnp.asarray(RNG.standard_normal((eb, nb, c, kh, kw)), jnp.float32)
    resident = coded_worker_pallas(xe, ke, stride, fused_im2col=True,
                                   stream_k=False)
    streamed = coded_worker_pallas(xe, ke, stride, stream_k=True)
    assert np.array_equal(np.asarray(resident), np.asarray(streamed))


def test_worker_stream_k_auto_fallback(monkeypatch):
    """When the whole share no longer fits the VMEM guard but the streamed
    buffers do, the fused path is kept via stream_k auto-fallback (instead
    of dropping to the two-step HBM-patch path) — and stays bit-identical
    to the resident result computed under the roomy guard."""
    import repro.kernels.conv2d.kernel as K

    c, hh, wp, kh = 64, 40, 40, 3
    xe = jnp.asarray(RNG.standard_normal((1, c, hh, wp)), jnp.float32)
    ke = jnp.asarray(RNG.standard_normal((1, 8, c, kh, kh)), jnp.float32)
    ho = wo = hh - kh + 1
    bo = K.default_bo(ho, wo)
    ref = K.coded_worker_pallas(xe, ke, 1, fused_im2col=True, stream_k=False)
    monkeypatch.setattr(K, "_FUSED_VMEM_ELEMS", 90_000)  # share = 102400
    assert not K._fused_feasible((1, c, hh, wp), kh, kh, 1, ho, wo, bo)
    assert K._stream_feasible((1, c, hh, wp), kh, kh, 1, ho, wo, bo, 128)
    auto = K.coded_worker_pallas(xe, ke, 1)  # picks the streamed fused path
    assert np.array_equal(np.asarray(ref), np.asarray(auto))


def test_stream_k_channel_windows():
    """Window algebra: every chunk's channel window covers exactly its real
    columns, and windows stay small relative to C for multi-tap kernels."""
    from repro.kernels.conv2d.kernel import _k_windows, _pad_to

    ck, bk, kh, kw = 64 * 9, 128, 3, 3
    wins = _k_windows(ck, bk, kh, kw, _pad_to(ck, bk))
    for kk, (c_lo, cw) in enumerate(wins):
        k0, k1 = kk * bk, min(ck, (kk + 1) * bk) - 1
        assert c_lo == k0 // (kh * kw)
        assert c_lo + cw - 1 == k1 // (kh * kw)
    assert max(cw for _, cw in wins) <= -(-bk // (kh * kw)) + 1


@settings(max_examples=15, deadline=None)
@given(q=st.integers(2, 24), f=st.integers(1, 400), seed=st.integers(0, 99))
def test_coded_gemm_rebase_bit_parity(q, f, seed):
    """The multi-buffered ``matmul_pallas`` lowering of ``coded_gemm`` is
    bit-identical to the legacy feature-axis lowering: both contract the
    whole (tiny) code axis in one f32 dot, so the rebase changes schedule,
    never numerics."""
    from repro.kernels.coded_gemm.kernel import (coded_gemm_pallas,
                                                 coded_gemm_pallas_legacy)

    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.standard_normal((q, q)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((q, f)), jnp.float32)
    new = np.asarray(coded_gemm_pallas(c, t))
    old = np.asarray(coded_gemm_pallas_legacy(c, t))
    assert new.shape == old.shape == (q, f)
    assert np.array_equal(new, old), float(np.abs(new - old).max())
