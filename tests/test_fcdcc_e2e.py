"""End-to-end FCDCC: coded conv == direct conv for any delta survivors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import CodedConv2d, ConvGeometry, FcdccPlan
from repro.core.partition import np_reference_conv

RNG = np.random.default_rng(0)


def _run(n, k_a, k_b, C, H, W, N, KH, KW, s, p, ids):
    plan = FcdccPlan(n=n, k_a=k_a, k_b=k_b)
    geo = ConvGeometry(C, N, H, W, KH, KW, s, p, k_a, k_b)
    layer = CodedConv2d(plan, geo)
    x = RNG.standard_normal((C, H, W)).astype(np.float32)
    k = RNG.standard_normal((N, C, KH, KW)).astype(np.float32)
    y = layer.run_simulated(jnp.asarray(x), jnp.asarray(k), ids)
    ref = np_reference_conv(x, k, s, p)
    assert y.shape == ref.shape
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("n,k_a,k_b,ids", [
    (4, 2, 4, None),
    (6, 4, 4, [5, 3, 1, 0]),
    (5, 2, 2, [4]),
    (4, 1, 8, [3, 1, 0, 2]),
    (4, 8, 1, [0, 3, 2, 1]),
    (3, 1, 1, [2]),
])
def test_configs(n, k_a, k_b, ids):
    _run(n, k_a, k_b, C=3, H=13, W=11, N=8, KH=3, KW=3, s=1, p=1, ids=ids)


def test_stride_and_padding():
    _run(6, 4, 4, C=2, H=16, W=9, N=8, KH=3, KW=2, s=2, p=0, ids=[5, 3, 1, 0])
    _run(8, 4, 8, C=3, H=21, W=13, N=16, KH=5, KW=3, s=2, p=2,
         ids=[7, 6, 5, 4, 3, 2, 1, 0])


def test_paper_config_n20():
    """The paper's Table III config: (k_A,k_B)=(2,32), n=20, delta=16.
    Q=64 decode in float32 carries kappa(E)~1e4 -> looser tolerance here;
    the float64 MSE claim is covered by test_stability.py."""
    plan = FcdccPlan(n=20, k_a=2, k_b=32)
    geo = ConvGeometry(8, 64, 24, 24, 3, 3, 1, 1, 2, 32)
    layer = CodedConv2d(plan, geo)
    x = RNG.standard_normal((8, 24, 24)).astype(np.float32)
    k = RNG.standard_normal((64, 8, 3, 3)).astype(np.float32)
    y = layer.run_simulated(jnp.asarray(x), jnp.asarray(k), list(range(16)))
    ref = np_reference_conv(x, k, 1, 1)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=5e-2, atol=2e-2)


@pytest.mark.parametrize("n,k_a,k_b,batch,s,p", [
    (4, 2, 4, None, 1, 1),   # single image (the seed case)
    (6, 2, 4, 3, 1, 1),      # batched request batch
    (6, 4, 4, 2, 2, 0),      # stride > 1
    (8, 4, 8, 2, 2, 2),      # stride > 1 with padding > 0
    (4, 1, 8, 2, 1, 1),      # degenerate A axis (k_a = 1, ell_a = 1)
    (4, 8, 1, 2, 1, 0),      # degenerate B axis (k_b = 1, ell_b = 1)
    (3, 1, 1, 2, 2, 1),      # fully degenerate (single coded pair)
])
def test_pallas_backend_matches(n, k_a, k_b, batch, s, p):
    """The fused pallas worker (one im2col + one MXU GEMM per subtask)
    decodes identically to the fused lax path over batches, strides,
    padding, and degenerate code axes."""
    plan = FcdccPlan(n=n, k_a=k_a, k_b=k_b)
    geo = ConvGeometry(3, 8, 13, 11, 3, 3, s, p, k_a, k_b)
    shape = (3, 13, 11) if batch is None else (batch, 3, 13, 11)
    x = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((8, 3, 3, 3)), jnp.float32)
    y_lax = CodedConv2d(plan, geo, backend="lax").run_simulated(x, k)
    y_pal = CodedConv2d(plan, geo, backend="pallas").run_simulated(x, k)
    assert y_pal.shape == y_lax.shape
    np.testing.assert_allclose(np.asarray(y_lax), np.asarray(y_pal), atol=1e-3)


def test_pallas_fused_matches_unfused_loop():
    """Fused single-GEMM worker == the paper-literal ell_a*ell_b pairwise
    loop on the same coded shares (both pallas, batched)."""
    plan = FcdccPlan(n=6, k_a=2, k_b=4)
    geo = ConvGeometry(3, 8, 13, 11, 3, 3, 1, 1, 2, 4)
    x = jnp.asarray(RNG.standard_normal((3, 3, 13, 11)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((8, 3, 3, 3)), jnp.float32)
    fused = CodedConv2d(plan, geo, backend="pallas")
    loop = CodedConv2d(plan, geo, backend="pallas", fused_worker=False)
    xe, ke = fused.encode_inputs(x), fused.encode_filters(k)
    yf = fused.worker_compute(xe[0], ke[0])
    yl = loop.worker_compute(xe[0], ke[0])
    assert yf.shape == yl.shape  # (ell_a*ell_b, B, N/k_b, H'/k_a, W')
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yl), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    k_a=st.sampled_from([1, 2, 4]),
    k_b=st.sampled_from([1, 2, 4]),
    gamma=st.integers(0, 2),
    h=st.integers(8, 18),
    w=st.integers(6, 14),
    s=st.sampled_from([1, 2]),
    p=st.sampled_from([0, 1]),
    seed=st.integers(0, 100),
)
def test_property_any_survivors(k_a, k_b, gamma, h, w, s, p, seed):
    ell = (1 if k_a == 1 else 2) * (1 if k_b == 1 else 2)
    delta = (k_a * k_b) // ell
    n = delta + gamma
    rng = np.random.default_rng(seed)
    ids = sorted(rng.choice(n, delta, replace=False).tolist())
    _run(n, k_a, k_b, C=2, H=h, W=w, N=8, KH=3, KW=3, s=s, p=p, ids=ids)


def test_sharded_spmd_path():
    """run_sharded on a worker-axis mesh (subprocess w/ 4 fake devices)."""
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import CodedConv2d, ConvGeometry, FcdccPlan
from repro.core.partition import np_reference_conv
plan = FcdccPlan(n=4, k_a=2, k_b=4)
geo = ConvGeometry(3, 8, 12, 10, 3, 3, 1, 1, 2, 4)
layer = CodedConv2d(plan, geo)
rng = np.random.default_rng(0)
x = rng.standard_normal((3, 12, 10)).astype(np.float32)
k = rng.standard_normal((8, 3, 3, 3)).astype(np.float32)
mesh = jax.make_mesh((4,), ("workers",), axis_types=(jax.sharding.AxisType.Auto,))
y = layer.run_sharded(mesh, "workers", jnp.asarray(x), jnp.asarray(k), worker_ids=[3, 1])
ref = np_reference_conv(x, k, 1, 1)
np.testing.assert_allclose(np.asarray(y), ref, atol=2e-3)
print("SHARDED_OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        timeout=300,
    )
    assert "SHARDED_OK" in out.stdout, out.stderr[-2000:]
