"""Step-by-step decode must reproduce teacher-forced forward logits for the
generic-transformer cache paths (ring-write GQA, DUS GQA, MLA latent)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import schema_init
from repro.models.moe import MoEConfig
from repro.models.transformer import (
    LMConfig,
    MLAConfig,
    decode_step,
    forward,
    init_cache,
    lm_schema,
)

CASES = {
    "gqa": LMConfig(name="g", layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    head_dim=16, d_ff=128, vocab=101, qk_norm=True),
    "gqa-window": LMConfig(name="w", layers=2, d_model=64, n_heads=4,
                           n_kv_heads=2, head_dim=16, d_ff=128, vocab=101,
                           window=6, window_pattern="all"),
    "gemma2-like": LMConfig(name="s", layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, head_dim=16, d_ff=128, vocab=101,
                            attn_softcap=50.0, logit_softcap=30.0,
                            sandwich_norms=True, embed_scale=True),
    "mla-moe": LMConfig(
        name="m", layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=101, attn="mla",
        mla=MLAConfig(q_lora=32, kv_lora=24, qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
        moe=MoEConfig(n_routed=4, top_k=2, d_model=64, d_ff_expert=32,
                      n_shared=1, capacity_factor=4.0),
        n_dense_layers=1, tie_embeddings=False,
    ),
}


@pytest.mark.parametrize("case", list(CASES))
def test_decode_matches_forward(case):
    cfg = CASES[case]
    params = schema_init(lm_schema(cfg), jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    ref = forward(params, cfg, toks)

    cache = init_cache(cfg, 2, 16, jnp.float32)
    outs = []
    for t in range(12):
        lg, cache = decode_step(params, cfg, cache, toks[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-3)


def test_hymba_ring_buffer_wraps():
    """Hymba's windowed ring cache: decoding past the window length stays
    finite and consistent with a fresh longer-window run on the last step."""
    from repro.models import hymba

    cfg = hymba.HymbaConfig(name="h", layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, head_dim=16, d_ff=128, vocab=101,
                            ssm_state=8, window=8, chunk=8)
    params = schema_init(hymba.hymba_schema(cfg), jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 20), 0, 101)
    st = hymba.init_state(cfg, 1, 64, jnp.float32)
    for t in range(20):  # 20 > window=8: ring must wrap
        lg, st = hymba.decode_step(params, cfg, st, toks[:, t : t + 1], jnp.int32(t))
        assert not bool(jnp.isnan(lg).any()), t
    ref = hymba.forward(params, cfg, toks)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(ref[:, -1]), atol=5e-3
    )
