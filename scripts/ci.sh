#!/usr/bin/env bash
# Fast CI suite: the ROADMAP tier-1 verify command with slow (VGG-sized)
# cases deselected.  Extra args are passed through to pytest.
#
#   scripts/ci.sh            # fast suite
#   scripts/ci.sh -m ""      # include slow cases too
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q -m "not slow" "$@"
