#!/usr/bin/env bash
# Fast CI suite: the ROADMAP tier-1 verify command with slow (VGG-sized)
# cases deselected, then the serving-engine smoke benchmark (exp6), which
# asserts the continuous-batching server beats sequential run_pipeline
# under every straggler model.  Extra args are passed through to pytest.
#
# Tests run with a per-test watchdog (tests/conftest.py, REPRO_TEST_TIMEOUT
# seconds) so a hung scheduler/worker thread fails fast instead of wedging
# the suite; -x stops the run at the first failure.
#
#   scripts/ci.sh            # fast suite + serving smoke
#   scripts/ci.sh -m ""      # include slow cases too
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export REPRO_TEST_TIMEOUT="${REPRO_TEST_TIMEOUT:-300}"
python -m pytest -x -q -m "not slow" "$@"
python -m benchmarks.exp6_serving --smoke
