#!/usr/bin/env bash
# Fast CI suite: first the static-analysis gate (python -m repro.analysis
# --strict: jit-contract checks traced over every program the pipeline
# family can build, plus the concurrency lint over serving/runtime — any
# error OR warning fails before a single test runs), then the ROADMAP
# tier-1 verify command with slow (VGG-sized) cases deselected, then — when no pytest args override the selection —
# the slow-marked alexnet/vgg16 pallas pipeline parity geometries (the
# fused coded-worker kernel must match lax on every CNN_SPECS geometry;
# the fast lenet5 case already ran in the main suite), then the
# serving-engine smoke benchmark (exp6, asserts the continuous-batching
# server beats sequential run_pipeline under every straggler model), the
# fused pallas-worker smoke benchmark (exp7, asserts the fused kernel
# beats the unfused per-pair loop), the multi-model serving smoke
# benchmark (exp8, asserts two models on one shared coded pool beat two
# isolated split-pool servers on aggregate throughput under stragglers),
# and the partition-resident transition smoke benchmark (exp9, asserts
# the fused decode->relu->pool->re-encode transition path beats the
# full-tensor round trip summed over every layer boundary, with fp32
# parity and the bounded-program contract checked inside), and the
# kernel roofline smoke benchmark (exp10, asserts the pipelined +
# in-kernel-im2col worker kernel beats the pre-pipelining baseline on
# every cell with bit-identical fp32 outputs, and that no cell's
# speedup regressed >10% vs the committed BENCH_kernels.json
# trajectory).  Finally, under 8 emulated host devices
# (XLA_FLAGS=--xla_force_host_platform_device_count=8): the device-pool
# parity tests (threads-vs-device bit-parity, fastest-delta discard,
# dead-device elastic re-plan, per-device bounded programs — skipped in
# the single-device main run above) and the device-pool smoke benchmark
# (exp11, asserts the device pool's aggregate throughput >= the thread
# pool's with forced-subset bit-parity and no >10% regression vs the
# committed BENCH_devices.json trajectory), and the overlapped-serving
# smoke benchmark (exp12, asserts depth-2 round pipelining >= depth-1
# aggregate throughput under a staggered fixed-straggler Poisson cell,
# with single-shot forced-survivor bit-parity across depths 1/2/4, equal
# worker trace counts per depth, and no >10% regression vs the committed
# BENCH_serving.json trajectory), the coded-LM device-pool decode parity
# test (skipped in the single-device main run), and the coded LM decode
# smoke benchmark (exp13, asserts coded decode tokens/s >= 1.5x the
# uncoded straggler-bound baseline under a fixed 1-of-n straggler with
# exact token parity vs the undistributed reference decoder on every
# attempt, and no >10% regression vs the committed BENCH_lm.json
# trajectory).
# Extra args are passed through to the main pytest run.
#
# Tests run with a per-test watchdog (tests/conftest.py, REPRO_TEST_TIMEOUT
# seconds) so a hung scheduler/worker thread fails fast instead of wedging
# the suite; -x stops the run at the first failure.
#
#   scripts/ci.sh            # fast suite + slow pallas parity + smokes
#   scripts/ci.sh -m ""      # include all slow cases in the main run
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export REPRO_TEST_TIMEOUT="${REPRO_TEST_TIMEOUT:-300}"
# first-stage gate: static analysis (jit contracts over the full pipeline
# family + concurrency lint of serving/runtime) — strict means warnings
# fail too; machine-readable findings land in results/analysis_findings.json
mkdir -p results
python -m repro.analysis --strict --json-out results/analysis_findings.json
python -m pytest -x -q -m "not slow" "$@"
# skip the extra block only when the caller overrides marker selection
# (e.g. `-m ""` already ran the slow cases in the main suite above)
if [[ "$*" != *"-m"* ]]; then
  python -m pytest -x -q -m "slow" tests/test_pipeline.py -k "pallas"
  # fused-transition parity on the big archs, both backends (the fast
  # lenet5 cases already ran in the main suite)
  python -m pytest -x -q -m "slow" tests/test_fused_transitions.py
fi
python -m benchmarks.exp6_serving --smoke
python -m benchmarks.exp7_pallas_worker --smoke
python -m benchmarks.exp8_multimodel --smoke
python -m benchmarks.exp9_fused_transitions --smoke
python -m benchmarks.exp10_kernel_roofline --smoke
# device pool: multi-device parity tests + throughput/regression gate
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
python -m pytest -x -q tests/test_device_pool.py
# coded LM decode: device-pool decode parity runs here (it skips on a
# single-device jax), perf gate vs the committed BENCH_lm trajectory after
python -m pytest -x -q tests/test_coded_decoder.py -k "device_pool"
python -m benchmarks.exp11_device_pool --smoke
python -m benchmarks.exp12_overlap --smoke
python -m benchmarks.exp13_lm_decode --smoke
