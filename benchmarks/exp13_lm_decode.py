"""Experiment 13 (beyond paper): coded LM decode serving under stragglers.

The FCDCC claim, transplanted from ConvL rounds to LM decode steps: with
per-layer projection weights column-coded once and resident on the
workers, a decode step's ``4 x layers`` GEMM rounds each complete from
the fastest ``delta`` of ``n`` workers — so one straggling worker costs
nothing, while the uncoded column-split baseline (``UncodedPlan``: the
same worker pool, weights split ``n`` ways with no redundancy, identity
decode) must wait for ALL ``n`` shards every round and its token rate is
bound by the straggler.

The sweep serves a batch of prompts through ``CodedLMServer`` (continuous
token batching, threaded cluster pool) on the same LM config twice — the
coded plan vs the uncoded baseline — under a fixed 1-of-n straggler, and
reports decode tokens/s for each plus the coded/uncoded speedup.

Correctness gate, run single-shot on EVERY attempt (never retried): the
tokens served by BOTH servers must exactly match the undistributed
reference decoder's greedy output for every request.  Coding changes the
schedule, never the tokens.

The perf trajectory persists in ``BENCH_lm.json`` at the repo root
(committed): a plain run appends one dated run with per-cell
``{coded_tok_s, uncoded_tok_s, speedup}``.  ``--smoke`` is the CI gate
and is read-only: it asserts (a) coded decode tokens/s >= 1.5x the
uncoded straggler-bound baseline (best of 3 — the token-parity gate above
re-runs and must pass on every attempt), and (b) the fresh speedup is no
worse than 10% below the last committed run for the cell.

  PYTHONPATH=src python -m benchmarks.exp13_lm_decode          # append
  PYTHONPATH=src python -m benchmarks.exp13_lm_decode --smoke  # CI gate
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smollm_135m
from repro.core.decoder_pipeline import UncodedPlan, build_lm_decoder_pipeline
from repro.models import transformer as lm
from repro.runtime import StragglerModel
from repro.serving import CodedLMServer

from .common import emit

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_lm.json")
REGRESSION_TOL = 0.9  # fresh speedup must stay >= 0.9x the committed one
SPEEDUP_GATE = 1.5  # coded tokens/s vs uncoded under 1 straggler
MAX_PROMPT = 8


def load_bench(path: str = BENCH_PATH) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {"schema": 1, "runs": []}


def committed_speedups(bench: dict) -> dict:
    out = {}
    for run_ in bench["runs"]:
        for cell, rec in run_.get("cells", {}).items():
            out[cell] = rec["speedup"]
    return out


def _workload(rng, requests: int, gen: int, vocab: int):
    prompts = [rng.integers(1, vocab, size=rng.integers(2, MAX_PROMPT + 1))
               .tolist() for _ in range(requests)]
    gens = [int(rng.integers(max(2, gen // 2), gen + 1))
            for _ in range(requests)]
    return prompts, gens


def _reference(cfg, params, prompts, gens, max_len):
    """Undistributed greedy decode per request (prefill + step loop)."""
    outs = []
    for prompt, gen in zip(prompts, gens):
        toks = jnp.asarray([prompt])
        cache = lm.init_cache(cfg, 1, max_len, jnp.float32)
        logits, cache = lm.prefill(params, cfg, cache, toks)
        out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
        pos = len(prompt)
        for _ in range(gen - 1):
            logits, cache = lm.decode_step(
                params, cfg, cache, jnp.asarray([[out[-1]]], jnp.int32),
                jnp.int32(pos))
            out.append(int(jnp.argmax(logits[0, 0])))
            pos += 1
        outs.append(out)
    return outs


def _serve(cfg, params, plan_kw, straggler, prompts, gens, *, n, buckets,
           max_len):
    """One serving run; returns (tokens/s over the busy span, outputs)."""
    pipe = build_lm_decoder_pipeline(cfg, params, n, bucket_sizes=buckets,
                                     max_len=max_len, **plan_kw)
    # mode="threads": real per-worker executors with real straggler sleeps
    # — the simulated clock would hide the delay from wall time entirely
    srv = CodedLMServer(pipe, straggler, mode="threads",
                        max_prompt=MAX_PROMPT, poll_interval_s=0.002)
    with srv:
        # warm every (bucket, program) before timing: serving must not
        # jit-compile on the measured path
        srv.generate(prompts[0], 2, timeout=600.0)
        t0 = time.perf_counter()
        handles = [srv.submit(p, g) for p, g in zip(prompts, gens)]
        outs = [np.asarray(h.result(timeout=600.0)) for h in handles]
        wall = time.perf_counter() - t0
    tokens = sum(gens)
    return tokens / wall, outs


def run(quick: bool = True, smoke: bool = False, update: bool = True,
        requests: int | None = None, gen: int | None = None,
        delay_s: float | None = None):
    bundle = smollm_135m.smoke() if quick else smollm_135m.full()
    cfg = bundle.cfg
    params = bundle.init(jax.random.PRNGKey(0), jnp.float32)
    n, k_b = 4, 4
    buckets = (1, 2, 4)
    max_len = 32 if quick else 64
    requests = requests or (4 if quick else 8)
    gen = gen or (8 if quick else 16)
    delay_s = delay_s if delay_s is not None else 0.05
    rng = np.random.default_rng(0)
    prompts, gens = _workload(rng, requests, gen, cfg.vocab)
    refs = _reference(cfg, params, prompts, gens, max_len)
    delays = np.zeros(n)
    delays[2] = delay_s  # exactly one straggling worker
    straggler = StragglerModel(delays)
    cell = f"{cfg.name}/1of{n}-straggler"
    prior = committed_speedups(load_bench())
    best = None
    for attempt in range(3 if smoke else 1):
        coded_tok_s, coded_outs = _serve(
            cfg, params, {"k_b": k_b}, straggler, prompts, gens,
            n=n, buckets=buckets, max_len=max_len)
        uncoded_tok_s, uncoded_outs = _serve(
            cfg, params, {"plan": UncodedPlan(n)}, straggler, prompts, gens,
            n=n, buckets=buckets, max_len=max_len)
        # token-parity gate: single-shot, every attempt, never retried away
        for i, ref in enumerate(refs):
            if list(coded_outs[i]) != ref:
                raise SystemExit(
                    f"exp13/{cell}: coded tokens for request {i} diverge "
                    f"from the reference decoder")
            if list(uncoded_outs[i]) != ref:
                raise SystemExit(
                    f"exp13/{cell}: uncoded tokens for request {i} diverge "
                    f"from the reference decoder")
        speedup = coded_tok_s / uncoded_tok_s
        if best is None or speedup > best[0]:
            best = (speedup, coded_tok_s, uncoded_tok_s)
        if speedup >= SPEEDUP_GATE:
            break
        print(f"# exp13/{cell}: speedup {speedup:.2f}x < {SPEEDUP_GATE} on "
              f"attempt {attempt + 1}, retrying", flush=True)
    speedup, coded_tok_s, uncoded_tok_s = best
    emit(f"exp13/{cell}/coded", 1.0 / coded_tok_s,
         f"tok_per_s={coded_tok_s:.1f} requests={requests} "
         f"gen<={gen} delay_s={delay_s}")
    emit(f"exp13/{cell}/uncoded", 1.0 / uncoded_tok_s,
         f"tok_per_s={uncoded_tok_s:.1f} straggler_bound=1")
    emit(f"exp13/{cell}/speedup", 0.0, f"coded_vs_uncoded={speedup:.2f}x")
    rec = {
        "coded_tok_s": round(coded_tok_s, 2),
        "uncoded_tok_s": round(uncoded_tok_s, 2),
        "speedup": round(speedup, 3),
    }
    if smoke:
        if speedup < SPEEDUP_GATE:
            raise SystemExit(
                f"coded decode tokens/s is only {speedup:.2f}x the uncoded "
                f"straggler-bound baseline (gate: {SPEEDUP_GATE}x, best of 3)")
        committed = prior.get(cell)
        if committed and speedup < REGRESSION_TOL * committed:
            raise SystemExit(
                f"coded-decode speedup regressed >10% vs the committed "
                f"BENCH_lm trajectory: now {speedup:.3f}, committed "
                f"{committed}")
        return {cell: rec}
    if update:
        bench = load_bench()
        bench["runs"].append({
            "date": time.strftime("%Y-%m-%d"),
            "backend": jax.default_backend(),
            "config": cfg.name,
            "n": n,
            "k_b": k_b,
            "delay_s": delay_s,
            "requests": requests,
            "cells": {cell: rec},
        })
        tmp = f"{BENCH_PATH}.tmp"
        with open(tmp, "w") as f:
            json.dump(bench, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, BENCH_PATH)
    return {cell: rec}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full smollm-135m config (slow on CPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: coded decode tokens/s >= 1.5x the uncoded "
                         "straggler-bound baseline under a 1-of-n straggler, "
                         "exact token parity vs the reference decoder every "
                         "attempt, and no >10%% regression vs BENCH_lm.json "
                         "(read-only)")
    ap.add_argument("--no-update", action="store_true",
                    help="measure + print only; don't append to the ledger")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--gen", type=int, default=None)
    ap.add_argument("--delay-s", type=float, default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, smoke=args.smoke, update=not args.no_update,
        requests=args.requests, gen=args.gen, delay_s=args.delay_s)
