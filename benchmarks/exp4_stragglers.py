"""Experiment 4 (paper Fig. 6): robustness under straggler counts/delays.

n=32 workers, delta=24 (gamma=8); stragglers 0..12 with 1s and 2s injected
delays.  Completion time stays flat until stragglers exceed gamma — the
paper's robustness result — then jumps by the injected delay.

``--batch B`` runs the same sweep with a (B,C,H,W) batch riding through one
persistent coded cluster (resident coded filters, no per-call re-encode) —
the steady-state serving view of the same robustness claim.

  PYTHONPATH=src python -m benchmarks.exp4_stragglers --batch 8
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.fcdcc import FcdccPlan
from repro.models.cnn import CNN_SPECS, layer_geometry
from repro.runtime import FcdccCluster, StragglerModel

from .common import emit


def run(quick: bool = True, batch: int = 1):
    n, delta = 32, 24
    plan = FcdccPlan(n=n, k_a=2, k_b=2 * delta)
    rng = np.random.default_rng(0)
    hw = 57 if quick else 227
    layer = CNN_SPECS["alexnet"][1][2]  # conv3 3x3
    geo = layer_geometry(layer, hw, plan.k_a, plan.k_b)
    shape = (layer.in_ch, hw, hw) if batch <= 1 else (batch, layer.in_ch, hw, hw)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    k = jnp.asarray(
        rng.standard_normal((layer.out_ch, layer.in_ch, layer.kernel, layer.kernel)),
        jnp.float32,
    )
    tag = f"_b{batch}" if batch > 1 else ""
    for delay in (1.0, 2.0):
        # one persistent cluster per sweep: the jitted worker program and the
        # coded filters (resident under layer_name) are encoded/compiled once
        # and reused across all straggler counts
        cluster = FcdccCluster(plan, StragglerModel.none(n), mode="simulated")
        for s in (0, 2, 4, 6, 8, 10, 12):
            cluster.straggler = StragglerModel.fixed(n, s, delay, seed=s)
            _, t = cluster.run_layer(geo, x, k, layer_name="conv3")
            tolerated = s <= plan.gamma
            emit(
                f"exp4/stragglers{s}_delay{delay:.0f}s{tag}", t.compute_s,
                f"tolerated={tolerated} per_image={t.compute_s/max(batch,1):.4f}s",
            )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=1)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, batch=args.batch)
