"""Experiment 4 (paper Fig. 6): robustness under straggler counts/delays.

n=32 workers, delta=24 (gamma=8); stragglers 0..12 with 1s and 2s injected
delays.  Completion time stays flat until stragglers exceed gamma — the
paper's robustness result — then jumps by the injected delay.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.fcdcc import FcdccPlan
from repro.models.cnn import CNN_SPECS, layer_geometry
from repro.runtime import FcdccCluster, StragglerModel

from .common import emit


def run(quick: bool = True):
    n, delta = 32, 24
    plan = FcdccPlan(n=n, k_a=2, k_b=2 * delta)
    rng = np.random.default_rng(0)
    hw = 57 if quick else 227
    layer = CNN_SPECS["alexnet"][1][2]  # conv3 3x3
    geo = layer_geometry(layer, hw, plan.k_a, plan.k_b)
    x = jnp.asarray(rng.standard_normal((layer.in_ch, hw, hw)), jnp.float32)
    k = jnp.asarray(
        rng.standard_normal((layer.out_ch, layer.in_ch, layer.kernel, layer.kernel)),
        jnp.float32,
    )
    for delay in (1.0, 2.0):
        for s in (0, 2, 4, 6, 8, 10, 12):
            cluster = FcdccCluster(
                plan, StragglerModel.fixed(n, s, delay, seed=s), mode="simulated"
            )
            _, t = cluster.run_layer(geo, x, k)
            tolerated = s <= plan.gamma
            emit(
                f"exp4/stragglers{s}_delay{delay:.0f}s", t.compute_s,
                f"tolerated={tolerated}",
            )


if __name__ == "__main__":
    run()
