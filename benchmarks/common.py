"""Shared benchmark helpers."""
from __future__ import annotations

import time

import jax


def timed(fn, *args, repeat=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)
