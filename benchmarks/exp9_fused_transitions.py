"""Experiment 9 (beyond paper): partition-resident fused layer transitions.

The FCDCC per-layer protocol fully decodes each ConvL output, applies
ReLU/pool on the assembled ``(B, C, H, W)`` tensor, then re-encodes from
scratch for the next layer.  That inter-layer round trip — not the coded
GEMM — is the dominant non-worker cost of ``run_pipeline`` and the serving
loop (cf. CoCoI, arXiv:2501.06856: inter-task data movement caps
distributed CNN inference throughput).  ``fuse_transitions=True`` keeps the
activation in partition space end to end: decode only to the ``(k_a, k_b)``
grid, relu+pool per spatial partition with halo exchange, re-encode
directly — one jitted transition program per (layer, bucket).

Measured here, per CNN_SPECS arch x batch bucket (paired interleaved
timing: the two variants alternate inside one loop, so clock drift on a
shared box cancels instead of biasing whichever ran second):

  * ``transition/<layer>`` — one inter-layer transition: the round-trip
    path (``decoder_fn`` -> full tensor -> ``encoder`` of the next layer,
    two program dispatches) vs the fused transition program, same decode
    inverse and encode columns.  Numerical parity is asserted (fp32
    allclose) — decode/encode stay exact linear maps, so fusing changes no
    math.
  * ``e2e`` — whole-stack ``run_prepared`` images/s for both paths, plus
    the bounded-program check (worker + transition traces <=
    (geometries + transitions) x buckets).

``--smoke`` asserts the fused path beats the round trip on the transition
path end-to-end — the *total* decode->relu->pool->re-encode time summed
over every layer boundary of the stack.  The worker conv programs are the
same compiled objects' math in both variants, so the transition total is
exactly the component this mode changes; on this container (2 CPU cores)
the identical worker convs dominate whole-stack wall clock and its jitter
exceeds the few-percent fused margin, so the whole-stack ratio is emitted
as data while the gate additionally only sanity-bounds it (fused must stay
within 2x of round-trip e2e — a real regression trips it, scheduler noise
does not).

  PYTHONPATH=src python -m benchmarks.exp9_fused_transitions --smoke
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import CodedPipeline, plan_layers
from repro.models.cnn import CNN_SPECS, init_cnn, input_hw

from .common import emit


def paired(fn_a, fn_b, repeat: int = 7) -> tuple[float, float]:
    """min-of-N seconds for two thunks, interleaved and order-alternated so
    slow drift of a shared machine hits both equally."""
    jax.block_until_ready(fn_a())
    jax.block_until_ready(fn_b())
    ta, tb = [], []
    for i in range(repeat):
        pairs = ((fn_a, ta), (fn_b, tb)) if i % 2 == 0 else ((fn_b, tb), (fn_a, ta))
        for fn, acc in pairs:
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            acc.append(time.perf_counter() - t0)
    return min(ta), min(tb)


def _pipelines(arch: str, n: int, kab, backend: str = "lax"):
    params = init_cnn(arch, jax.random.PRNGKey(0))
    specs = plan_layers(CNN_SPECS[arch][1], input_hw(arch, smoke=True), n,
                        default_kab=kab)
    rt = CodedPipeline(specs, params, backend=backend)
    # donate_transitions=False: the paired transition timing below re-feeds
    # the same outs array into the jitted transition, which donation forbids
    fused = CodedPipeline(specs, params, backend=backend,
                          fuse_transitions=True, donate_transitions=False)
    return rt, fused


def time_transitions(rt: CodedPipeline, fused: CodedPipeline, batch: int,
                     rng) -> list[tuple[str, float, float]]:
    """Steady-state seconds per inter-layer transition, (round-trip, fused),
    with fp32 parity asserted on the produced coded shares."""
    spec0 = rt.specs[0]
    x = jnp.asarray(rng.standard_normal(
        (batch, spec0.geo.in_channels, spec0.geo.height, spec0.geo.width)),
        jnp.float32)
    prepared = rt.prepare()
    rows = []
    xe = rt.encoder(0)(x, prepared[0][0])
    for idx in range(len(rt.specs) - 1):
        m_sel, sel, d = prepared[idx]
        m_next = prepared[idx + 1][0]
        outs = jax.block_until_ready(
            rt.worker_program(idx)(xe, rt.coded_filters[idx][sel])
        )
        dec, enc = rt.decoder_fn(idx), rt.encoder(idx + 1)

        def roundtrip(o=outs, _dec=dec, _enc=enc, _d=d, _m=m_next):
            return _enc(_dec(o, _d), _m)

        trans = fused.transition_fn(idx)
        xe_rt = jax.block_until_ready(roundtrip())
        xe_fused = jax.block_until_ready(trans(outs, d, m_next))
        np.testing.assert_allclose(  # exact linear maps: fusing changes no math
            np.asarray(xe_fused), np.asarray(xe_rt), rtol=1e-4, atol=1e-4)
        t_rt, t_fused = paired(
            roundtrip, lambda o=outs, _d=d, _m=m_next: trans(o, _d, _m)
        )
        rows.append((rt.specs[idx].name, t_rt, t_fused))
        xe = xe_fused
    return rows


def time_e2e(rt: CodedPipeline, fused: CodedPipeline, batch: int, rng):
    """Whole-stack ``run_prepared`` seconds (round-trip, fused) + parity."""
    spec0 = rt.specs[0]
    x = jnp.asarray(rng.standard_normal(
        (batch, spec0.geo.in_channels, spec0.geo.height, spec0.geo.width)),
        jnp.float32)
    plan_rt, plan_fused = rt.prepare(), fused.prepare()
    y_rt = np.asarray(rt.run_prepared(x, plan_rt))
    y_fused = np.asarray(fused.run_prepared(x, plan_fused))
    np.testing.assert_allclose(y_fused, y_rt, rtol=1e-4, atol=1e-4)
    return paired(lambda: rt.run_prepared(x, plan_rt),
                  lambda: fused.run_prepared(x, plan_fused))


def run(quick: bool = True, buckets=None, assert_fused: bool = False):
    # quick keeps alexnet: its four transitions carry most of the measured
    # time, so the smoke gate's margin rides their (consistent) fused win
    # rather than lenet5's single tiny transition
    archs = ("lenet5", "alexnet") if quick else ("lenet5", "alexnet", "vgg16")
    buckets = buckets or ((1, 4) if quick else (1, 4, 8))
    n, kab = 8, (2, 4)
    rng = np.random.default_rng(0)
    trans_rt_total = trans_fused_total = 0.0
    e2e_failures = []
    for arch in archs:
        rt, fused = _pipelines(arch, n, kab)
        for batch in buckets:
            for name, t_rt, t_fused in time_transitions(rt, fused, batch, rng):
                trans_rt_total += t_rt
                trans_fused_total += t_fused
                emit(
                    f"exp9/{arch}/b{batch}/transition/{name}", t_fused,
                    f"roundtrip_us={t_rt*1e6:.1f} "
                    f"fused_speedup={t_rt/t_fused:.2f}x",
                )
            t_rt, t_fused = time_e2e(rt, fused, batch, rng)
            emit(
                f"exp9/{arch}/b{batch}/e2e", t_fused,
                f"roundtrip_us={t_rt*1e6:.1f} speedup={t_rt/t_fused:.2f}x "
                f"images_per_s={batch/t_fused:.1f} "
                f"roundtrip_images_per_s={batch/t_rt:.1f}",
            )
            if t_fused > 2.0 * t_rt:  # regression backstop, noise-proof
                e2e_failures.append((arch, batch, round(t_fused / t_rt, 2)))
        traces = fused.worker_program_traces + fused.transition_program_traces
        bound = (fused.num_geometries + fused.num_transitions) * len(buckets)
        assert traces <= bound, (
            f"bounded-program contract violated: {traces} traces > "
            f"{bound} = (geometries + transitions) x buckets"
        )
    speedup = trans_rt_total / trans_fused_total
    emit(
        "exp9/transition_total", trans_fused_total,
        f"roundtrip_us={trans_rt_total*1e6:.1f} fused_speedup={speedup:.2f}x",
    )
    if assert_fused:
        if speedup <= 1.0:
            raise SystemExit(
                f"fused transitions did not beat the round-trip transition "
                f"path: {speedup:.3f}x"
            )
        if e2e_failures:
            raise SystemExit(
                f"fused end-to-end regressed past the 2x noise bound: "
                f"{e2e_failures}"
            )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all three CNNs + bucket 8")
    ap.add_argument("--smoke", action="store_true",
                    help="quick sweep + assert fused beats the round-trip "
                         "transition path")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, assert_fused=args.smoke)
