"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/<cell>.json and derives the three per-device terms:

  compute    = HLO_FLOPs / PEAK_FLOPS_BF16
  memory     = HLO_bytes / HBM_BW
  collective = collective_wire_bytes / ICI_BW

plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) per device and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_bundle
from repro.configs.shapes import SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.common import count_params

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def active_params(arch: str) -> float:
    """N for MODEL_FLOPS: active params (MoE: shared + top-k routed)."""
    b = get_bundle(arch)
    n_total = count_params(b.schema)
    cfg = b.cfg
    moe = getattr(cfg, "moe", None)
    if not moe:
        return n_total
    n_moe_layers = cfg.layers - cfg.n_dense_layers
    per_expert = 3 * cfg.d_model * moe.d_ff_expert
    inactive = n_moe_layers * (moe.n_routed - moe.top_k) * per_expert
    return n_total - inactive


def attention_flops(arch: str, shape: str) -> float:
    """Useful attention-matmul FLOPs (global, fwd; causal halving applied).

    6*N*D ignores the quadratic attention term, which dominates at 32k+.
    """
    b = get_bundle(arch)
    cfg = b.cfg
    sh = SHAPES[shape]
    bsz, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    fam = b.family

    if fam == "ssm":
        return 0.0  # linear-time mixing counted via params
    if fam == "encdec":
        layers, heads, hd = cfg.dec_layers, cfg.n_heads, cfg.head_dim
        enc = 2 * cfg.enc_layers * bsz * cfg.enc_len**2 * heads * 2 * hd
        if kind == "decode":
            dec = 2 * layers * bsz * (s + cfg.enc_len) * heads * 2 * hd
            return dec  # encoder not re-run per token
        dec = layers * bsz * s * s * heads * 2 * hd  # causal: half of 2*
        cross = 2 * layers * bsz * s * cfg.enc_len * heads * 2 * hd
        return enc + dec + cross
    layers, heads = cfg.layers, cfg.n_heads
    if getattr(cfg, "attn", "gqa") == "mla":
        dqk = cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim
        dv = cfg.mla.v_dim
    else:
        dqk = dv = cfg.head_dim
    window = getattr(cfg, "window", None)
    s_kv = min(s, window) if (window and fam == "hybrid") else s
    if kind == "decode":
        return 2 * layers * bsz * s_kv * heads * (dqk + dv)
    # causal self-attention: half the S x S_kv rectangle is useful
    return layers * bsz * s * s_kv * heads * (dqk + dv)


def rows(mesh_tag: str = "16x16"):
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh_tag}.json"))):
        r = json.load(open(path))
        if r["status"] != "ok":
            out.append({**r, "terms": None})
            continue
        arch, shape = r["arch"], r["shape"]
        sh = SHAPES[shape]
        devices = r["devices"]
        h = r["hlo_cost"]
        t_comp = h["flops"] / PEAK_FLOPS_BF16
        t_mem = h["bytes"] / HBM_BW
        t_coll = h["collective_bytes"] / ICI_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        n_active = active_params(arch)
        tokens = sh["global_batch"] * (sh["seq_len"] if sh["kind"] != "decode" else 1)
        mult = 6 if sh["kind"] == "train" else 2
        attn = attention_flops(arch, shape) * (3 if sh["kind"] == "train" else 1)
        model_flops_dev = (mult * n_active * tokens + attn) / devices
        out.append({
            **r,
            "terms": terms,
            "dominant": dominant,
            "model_flops_per_dev": model_flops_dev,
            "useful_ratio": model_flops_dev / h["flops"] if h["flops"] else 0.0,
            "bound_time": max(terms.values()),
            "roofline_fraction": (
                (h["flops"] / PEAK_FLOPS_BF16) / max(terms.values())
                if max(terms.values()) > 0 else 0.0
            ),
        })
    return out


def run(quick: bool = True):
    table = rows()
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,hw_roofline_fraction")
    for r in table:
        if r["terms"] is None:
            print(f"{r['arch']},{r['shape']},SKIPPED,,,,{r.get('reason','')[:40]},")
            continue
        t = r["terms"]
        print(
            f"{r['arch']},{r['shape']},{t['compute']:.3e},{t['memory']:.3e},"
            f"{t['collective']:.3e},{r['dominant']},{r['useful_ratio']:.2f},"
            f"{r['roofline_fraction']:.3f}"
        )
    _print_baseline_comparison()


def _print_baseline_comparison():
    """Paper-faithful baseline vs optimized deltas (§Perf A/B)."""
    base_dir = os.path.join(
        os.path.dirname(__file__), "..", "results", "dryrun_paper_baseline"
    )
    if not os.path.isdir(base_dir):
        return
    print("\n# baseline-vs-optimized (per-device; bound = max roofline term)")
    print("arch,shape,flops_x,bytes_x,collective_x,temp_GiB_base,temp_GiB_opt")
    for bpath in sorted(glob.glob(os.path.join(base_dir, "*__16x16.json"))):
        b = json.load(open(bpath))
        opath = os.path.join(RESULTS, os.path.basename(bpath))
        if b["status"] != "ok" or not os.path.exists(opath):
            continue
        o = json.load(open(opath))
        if o["status"] != "ok":
            continue
        hb, ho = b["hlo_cost"], o["hlo_cost"]
        print(
            f"{b['arch']},{b['shape']},"
            f"{hb['flops']/max(ho['flops'],1):.2f},"
            f"{hb['bytes']/max(ho['bytes'],1):.2f},"
            f"{hb['collective_bytes']/max(ho['collective_bytes'],1):.2f},"
            f"{b['memory']['temp_size_in_bytes']/2**30:.1f},"
            f"{o['memory']['temp_size_in_bytes']/2**30:.1f}"
        )


if __name__ == "__main__":
    run()
