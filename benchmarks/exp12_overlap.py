"""Experiment 12 (beyond paper): overlapped serving rounds + perf ledger.

Measures the round-pipelining window of the ``CodedServer`` engine: with
``pipeline_depth >= 2`` the engine dispatches batch B's coded worker round
*before* collecting batch A's, so the master-side collect + decode of one
batch overlaps another batch's worker compute — and, on the device pool,
the straggler delays of consecutive rounds elapse concurrently instead of
back to back.

The sweep drives Poisson request arrivals at one resident CNN pipeline on
the device-resident worker pool (8 emulated host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, set at module top
when run as a script) under a *staggered* fixed-straggler model: the
``delta``-th fastest worker carries a ``delay_s`` critical path, every
slower worker is delayed further — so the fastest-``delta`` survivor
subset is deterministic and each round's wall time is dominated by the
injected delay that depth >= 2 can overlap.  Per depth in {1, 2, 4} it
reports images/s, e2e p50/p95/p99, and the engine's measured
``overlap_efficiency`` (serial phase seconds per busy wall second: ~1.0
at depth 1, > 1.0 exactly when rounds overlapped).

Correctness gates, run single-shot on EVERY attempt (never retried):

  * **bit-parity** — with forced survivors (workers ``delta..n-1``
    delayed) the outputs served at depth 2 and depth 4 are bit-identical
    fp32 to depth 1's, and all match the undistributed ``pipeline.run``
    within fp32 tolerance.  Pipelining reorders *scheduling*, never math.
  * **bounded-program contract** — the per-depth pipelines trace the same
    worker program count: a deeper window must not add jit traces.

The perf trajectory persists in ``BENCH_serving.json`` at the repo root
(committed): a plain run appends one dated run with per-cell
``{d1_img_per_s, d2_img_per_s, d4_img_per_s, speedup_d2, ...}``.
``--smoke`` is the CI gate and is read-only: it asserts (a) depth-2
aggregate throughput >= depth-1 under the staggered fixed-straggler model
(best of 3 — the parity gates above re-run and must pass on every
attempt), and (b) the fresh depth-2 speedup of every cell is no worse
than 10% below the last committed run for that cell.

  PYTHONPATH=src python -m benchmarks.exp12_overlap          # append
  PYTHONPATH=src python -m benchmarks.exp12_overlap --smoke  # CI gate
"""
from __future__ import annotations

import json
import os
import sys
import time

# Must precede jax's backend init: 8 emulated host devices when run as a
# script on a CPU box.  When imported by benchmarks.run, jax is already
# initialized and this is a no-op (run() then skips if single-device).
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8"
        ).strip()

import jax
import numpy as np

from repro.core.pipeline import build_cnn_pipeline
from repro.models.cnn import CNN_SPECS, init_cnn, input_hw
from repro.runtime import StragglerModel
from repro.serving import CodedServer

from .common import emit

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serving.json")
REGRESSION_TOL = 0.9  # fresh speedup must stay >= 0.9x the committed one
DEPTHS = (1, 2, 4)


def load_bench(path: str = BENCH_PATH) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {"schema": 1, "runs": []}


def committed_speedups(bench: dict) -> dict:
    """Per-cell depth-2-vs-depth-1 speedup of the most recent committed
    run that measured the cell."""
    out = {}
    for run_ in bench["runs"]:
        for cell, rec in run_.get("cells", {}).items():
            out[cell] = rec["speedup_d2"]
    return out


def _pipe(arch: str, n: int, kab, buckets):
    params = init_cnn(arch, jax.random.PRNGKey(0))
    return build_cnn_pipeline(arch, params, n, default_kab=kab,
                              input_hw=input_hw(arch, smoke=True),
                              bucket_sizes=buckets)


def _staggered(n: int, dm: int, delay_s: float) -> StragglerModel:
    """Deterministic-survivor straggler model whose critical path is the
    injected delay: the first ``dm - 1`` workers answer instantly, worker
    ``dm - 1`` carries ``delay_s`` (so every round *waits* that long for
    its delta-th shard), and the rest trail at >= 2.5x with a stagger so
    reaps never tie.  Survivors are always ``{0..dm-1}``."""
    delays = np.zeros(n)
    delays[dm - 1] = delay_s
    delays[dm:] = delay_s * (2.5 + 0.5 * np.arange(n - dm))
    return StragglerModel(delays)


def _server(pipe, straggler, depth: int, buckets) -> CodedServer:
    server = CodedServer(pipe, straggler, mode="threads", pool="device",
                         bucket_sizes=buckets, pipeline_depth=depth)
    server.warmup()
    return server


def _serve(server: CodedServer, xs, rate_hz: float, rng):
    """Poisson open-loop arrivals; returns (ServingStats, OverlapStats,
    outputs in submit order)."""
    gaps = rng.exponential(1.0 / rate_hz, size=len(xs))
    with server:
        handles = []
        for x, gap in zip(xs, gaps):
            handles.append(server.submit(x))
            time.sleep(gap)
        outs = [np.asarray(h.result(timeout=300.0)) for h in handles]
        stats = server.stats()
        ostats = server.metrics.overlap_stats()
    return stats, ostats, outs


def check_parity(arch: str, n: int, kab, buckets, rng,
                 requests: int = 6) -> None:
    """Forced-survivor bit-parity across pipeline depths (single-shot).

    Workers ``delta..n-1`` get a finite 0.25s delay, so every round of
    every depth decodes from the identical shard subset — the outputs
    served at depth 2 and 4 must be bit-identical fp32 to depth 1's, and
    all must match the undistributed pipeline within fp32 tolerance."""
    ref_pipe = _pipe(arch, n, kab, buckets)
    dm = max(spec.plan.delta for spec in ref_pipe.specs)
    delays = np.zeros(n)
    delays[dm:] = 0.25
    straggler = StragglerModel(delays)
    c0 = ref_pipe.specs[0].geo.in_channels
    hw0 = input_hw(arch, smoke=True)
    xs = [np.asarray(v, np.float32)
          for v in rng.standard_normal((requests, c0, hw0, hw0))]
    outs = {}
    for depth in DEPTHS:
        server = _server(_pipe(arch, n, kab, buckets), straggler, depth,
                         buckets)
        with server:
            handles = server.submit_many(xs)
            outs[depth] = [np.asarray(h.result(timeout=300.0))
                           for h in handles]
    for depth in DEPTHS[1:]:
        for i, (a, b) in enumerate(zip(outs[1], outs[depth])):
            if not np.array_equal(a, b):
                raise SystemExit(
                    f"{arch}: request {i} served at depth {depth} is not "
                    f"bit-identical to depth 1 under forced survivors")
    for i, x in enumerate(xs):
        ref = np.asarray(ref_pipe.run(x[None]))[0]
        np.testing.assert_allclose(outs[1][i], ref, rtol=1e-4, atol=1e-4)


def time_arch(arch: str, n: int, kab, buckets, requests: int,
              rate_hz: float, delay_s: float, rng):
    """One throughput/latency cell per depth under the staggered
    fixed-straggler model; asserts the bounded-program contract (equal
    worker trace counts across depths) on the way."""
    probe = _pipe(arch, n, kab, buckets)
    dm = max(spec.plan.delta for spec in probe.specs)
    straggler = _staggered(n, dm, delay_s)
    c0 = probe.specs[0].geo.in_channels
    hw0 = input_hw(arch, smoke=True)
    xs = [np.asarray(v, np.float32)
          for v in rng.standard_normal((requests, c0, hw0, hw0))]
    by_depth, traces = {}, {}
    for depth in DEPTHS:
        pipe = _pipe(arch, n, kab, buckets)
        server = _server(pipe, straggler, depth, buckets)
        stats, ostats, _ = _serve(server, xs, rate_hz, rng)
        by_depth[depth] = (stats, ostats)
        traces[depth] = pipe.worker_program_traces
    if len(set(traces.values())) != 1:
        raise SystemExit(
            f"{arch}: pipeline depth changed the worker trace count "
            f"(no-new-traces contract): {traces}")
    return by_depth


def run(quick: bool = True, smoke: bool = False, update: bool = True,
        requests: int | None = None, rate_hz: float = 400.0):
    ndev = len(jax.devices())
    if ndev < 2:
        msg = ("exp12 needs a multi-device host; set XLA_FLAGS="
               "--xla_force_host_platform_device_count=8 (or run as "
               "`python -m benchmarks.exp12_overlap`, which sets it)")
        if smoke:
            raise SystemExit(msg)
        print(f"# exp12 skipped: {msg}", flush=True)
        return {}
    archs = ("lenet5",) if quick else ("lenet5", "alexnet")
    n, kab = 8, (2, 4)
    buckets = (1,)  # one request per round: max rounds, max overlap surface
    requests = requests or (12 if quick else 24)
    delay_s = 0.03 if quick else 0.05
    rng = np.random.default_rng(0)
    prior = committed_speedups(load_bench())
    cells, regressions, failures = {}, [], []
    for arch in archs:
        # Best-of-3 on the PERF gate only: a loaded single-core CI box can
        # lose the overlap race to scheduler jitter.  Parity + the trace
        # bound are re-checked single-shot on every attempt — a wrong
        # result must never be retried away.
        best = None
        for attempt in range(3 if smoke else 1):
            check_parity(arch, n, kab, buckets, rng)
            by_depth = time_arch(arch, n, kab, buckets, requests, rate_hz,
                                 delay_s, rng)
            ips = {d: s.images_per_s for d, (s, _) in by_depth.items()}
            speedup_d2 = ips[2] / ips[1]
            if best is None or speedup_d2 > best[0]:
                best = (speedup_d2, by_depth)
            if speedup_d2 >= 1.0:
                break
            print(f"# exp12/{arch}: depth-2 speedup {speedup_d2:.2f}x < 1.0 "
                  f"on attempt {attempt + 1}, retrying", flush=True)
        speedup_d2, by_depth = best
        cell = f"{arch}/stagger"
        rec = {"speedup_d2": round(speedup_d2, 3)}
        for depth, (stats, ostats) in by_depth.items():
            rec[f"d{depth}_img_per_s"] = round(stats.images_per_s, 1)
            rec[f"d{depth}_e2e_p50_ms"] = round(stats.e2e_p50_s * 1e3, 1)
            emit(
                f"exp12/{cell}/d{depth}", 1.0 / stats.images_per_s,
                f"img_per_s={stats.images_per_s:.1f} "
                f"p50={stats.e2e_p50_s*1e3:.1f}ms "
                f"p95={stats.e2e_p95_s*1e3:.1f}ms "
                f"p99={stats.e2e_p99_s*1e3:.1f}ms "
                f"overlap_eff={ostats.overlap_efficiency:.2f} "
                f"max_depth={ostats.max_depth}",
            )
        emit(f"exp12/{cell}/speedup", 0.0,
             f"d2_vs_d1={speedup_d2:.2f}x "
             f"d4_vs_d1={by_depth[4][0].images_per_s / by_depth[1][0].images_per_s:.2f}x")
        cells[cell] = rec
        if speedup_d2 < 1.0:
            failures.append((cell, round(speedup_d2, 3)))
        committed = prior.get(cell)
        if committed and speedup_d2 < REGRESSION_TOL * committed:
            regressions.append((cell, round(speedup_d2, 3), committed))
    if smoke:
        if failures:
            raise SystemExit(
                f"depth-2 round pipelining did not beat depth-1 throughput "
                f"under the staggered straggler model (best of 3): "
                f"{failures}")
        if regressions:
            raise SystemExit(
                "pipelined-serving perf regressed >10% vs the committed "
                f"BENCH trajectory (cell, now, committed): {regressions}")
        return cells
    if update:
        bench = load_bench()
        bench["runs"].append({
            "date": time.strftime("%Y-%m-%d"),
            "backend": jax.default_backend(),
            "devices": ndev,
            "quick": quick,
            "requests": requests,
            "rate_hz": rate_hz,
            "delay_s": delay_s,
            "cells": cells,
        })
        tmp = f"{BENCH_PATH}.tmp"
        with open(tmp, "w") as f:
            json.dump(bench, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, BENCH_PATH)
    return cells


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="lenet5 + alexnet, more requests")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: depth-2 >= depth-1 aggregate throughput "
                         "under the staggered fixed-straggler model, forced-"
                         "survivor bit-parity across depths, equal trace "
                         "counts, and no >10%% regression vs "
                         "BENCH_serving.json (read-only)")
    ap.add_argument("--no-update", action="store_true",
                    help="measure + print only; don't append to the ledger")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate-hz", type=float, default=400.0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, smoke=args.smoke, update=not args.no_update,
        requests=args.requests, rate_hz=args.rate_hz)
