"""Experiment 2 (paper Fig. 3/4): numerical stability of CDC schemes.

Compares CRME (ours / the paper's) vs real-Vandermonde polynomial codes vs
Chebyshev-point (Fahim–Cadambe-style) codes on a VGG Conv4-like layer:
worst-case recovery-matrix condition number over random straggler patterns
and end-to-end float64 MSE.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import itertools  # noqa: E402

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.baselines import (  # noqa: E402
    chebyshev_points,
    make_poly_codes,
    poly_recovery_matrix,
    real_points,
)
from repro.core.crme import make_axis_codes, recovery_matrix  # noqa: E402
from repro.core.fcdcc import CodedConv2d, FcdccPlan  # noqa: E402
from repro.core.nsctc import decode_blocks, encode_tensor_list  # noqa: E402
from repro.core.partition import (  # noqa: E402
    ConvGeometry,
    apcp_partition,
    block_output_shape,
    kccp_partition,
    merge_output,
)
from .common import emit  # noqa: E402

CONFIGS = [(5, 4), (20, 16), (40, 32), (48, 32), (60, 32)]


def _poly_mse_and_cond(k_a, k_b, n, delta, points, x, k, geo, y_ref, rng):
    """ell=1 polynomial-code pipeline (1 conv per worker, delta = k_a*k_b)."""
    a, b = make_poly_codes(k_a, k_b, n, points)
    xe = encode_tensor_list(apcp_partition(x, geo), jnp.asarray(a.matrix))
    ke = encode_tensor_list(kccp_partition(k, geo), jnp.asarray(b.matrix))
    ids = sorted(rng.choice(n, size=delta, replace=False).tolist())
    conv = lambda xi, ki: jax.lax.conv_general_dilated(
        xi[None], ki, (geo.stride, geo.stride), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    outs = jnp.stack([conv(xe[i], ke[i]) for i in ids])
    e = poly_recovery_matrix(a, b, ids)
    cond = float(np.linalg.cond(e))
    try:
        rows = outs.reshape(delta, -1)
        true_rows = jnp.asarray(np.linalg.solve(e.T, np.asarray(rows)))
        blocks = true_rows.reshape((k_a * k_b,) + block_output_shape(geo))
        y = merge_output(blocks, geo)
        mse = float(jnp.mean((y - y_ref) ** 2))
    except np.linalg.LinAlgError:
        mse = float("inf")
    return mse, cond


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    # Conv4_1-of-VGG-like layer, spatially reduced for CPU
    c, n_out, hw = (64, 128, 28) if quick else (256, 512, 28)
    x = jnp.asarray(rng.standard_normal((c, hw, hw)))
    k = jnp.asarray(rng.standard_normal((n_out, c, 3, 3)) / (c * 9) ** 0.5)

    for n, delta in CONFIGS:
        # CRME (ours): delta = kA*kB/4
        k_a = 2
        k_b = 2 * delta  # delta = k_a*k_b/4
        plan = FcdccPlan(n=n, k_a=k_a, k_b=k_b)
        geo = ConvGeometry(c, n_out, hw, hw, 3, 3, 1, 1, k_a, k_b)
        layer = CodedConv2d(plan, geo)
        ids = sorted(rng.choice(n, size=delta, replace=False).tolist())
        y_ref = jax.lax.conv_general_dilated(
            x[None], k, (1, 1), ((1, 1), (1, 1)),
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )[0]
        y = layer.run_simulated(x, k, ids)
        a_code, b_code = plan.codes
        e = recovery_matrix(a_code, b_code, ids)
        mse = float(jnp.mean((y - y_ref) ** 2))
        emit(f"exp2/crme/n{n}_d{delta}", 0.0, f"mse={mse:.2e} cond={np.linalg.cond(e):.2e}")

        # baselines: ell=1 codes with k_a*k_b = delta subtasks
        kb1 = delta // 2
        for name, pts in (
            ("real_vandermonde", real_points(n)),
            ("chebyshev", chebyshev_points(n)),
        ):
            geo1 = ConvGeometry(c, n_out, hw, hw, 3, 3, 1, 1, 2, kb1)
            mse, cond = _poly_mse_and_cond(
                2, kb1, n, delta, pts, x, k, geo1, y_ref, rng
            )
            emit(f"exp2/{name}/n{n}_d{delta}", 0.0, f"mse={mse:.2e} cond={cond:.2e}")


if __name__ == "__main__":
    run()
