"""Experiment 6 (beyond paper): coded serving engine under request traffic.

Drives Poisson request arrivals at a ``CodedServer`` (continuous batching
over one resident ``CodedPipeline``) for several straggler models — fixed
stragglers, random-uniform stragglers, dead workers — and reports
per-request p50/p95/p99 end-to-end latency plus images/s throughput,
against the sequential baseline that issues one ``run_pipeline`` call per
request on the same cluster configuration.

The claim measured here is the serving-system one (cf. CoCoI): coded
redundancy handles the stragglers, continuous batching amortizes the
per-layer encode/dispatch/decode overhead across concurrent requests —
so the engine sustains strictly higher throughput than per-request calls
under the *same* straggler model.

  PYTHONPATH=src python -m benchmarks.exp6_serving --smoke
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.models.cnn import CNN_SPECS, init_cnn, input_hw
from repro.runtime import FcdccCluster, StragglerModel
from repro.serving import CodedServer
from repro.core.pipeline import build_cnn_pipeline

from .common import emit

BUCKETS = (1, 2, 4, 8)


def _scenarios(n: int, delay: float, seed: int = 0):
    dead = np.zeros(n)
    dead[seed % n] = np.inf
    return {
        "none": StragglerModel.none(n),
        "fixed2": StragglerModel.fixed(n, 2, delay, seed=seed),
        "random_p25": StragglerModel.random_uniform(n, 0.25, delay, seed=seed),
        "dead1": StragglerModel(dead),
    }


def _sequential_baseline(arch, params, n, kab, hw, straggler, xs):
    """One ``run_pipeline`` call per request on a warm persistent cluster —
    the pre-serving way to handle concurrent traffic."""
    pipeline = build_cnn_pipeline(arch, params, n, default_kab=kab,
                                  input_hw=hw)
    cluster = FcdccCluster(pipeline.specs[0].plan, straggler, mode="threads")
    cluster.load_pipeline(pipeline)
    cluster.run_pipeline(xs[0][None])  # warm: jit + resident filters
    t0 = time.perf_counter()
    for x in xs:
        cluster.run_pipeline(x[None])
    wall = time.perf_counter() - t0
    cluster.shutdown()
    return len(xs) / wall


def _serve(arch, params, n, kab, hw, straggler, xs, rate_hz, rng):
    server = CodedServer.from_cnn(
        arch, params, n, default_kab=kab, input_hw=hw,
        straggler=straggler, mode="threads", bucket_sizes=BUCKETS,
    )
    server.warmup()
    gaps = rng.exponential(1.0 / rate_hz, size=len(xs))
    with server:
        handles = []
        for x, gap in zip(xs, gaps):
            handles.append(server.submit(x))
            time.sleep(gap)
        results = [h.result(timeout=300.0) for h in handles]
        stats = server.stats()
    return stats, server.pipeline, results[0]


def run(quick: bool = True, requests: int | None = None,
        rate_hz: float = 400.0, assert_speedup: bool = False):
    arch = "lenet5" if quick else "alexnet"
    n, kab = 8, (2, 4)
    # always the reduced resolution: even --full keeps AlexNet at the CPU
    # demo size — the sweep scales request *traffic*, not image size
    hw = input_hw(arch, smoke=True)
    delay = 0.05 if quick else 0.2
    requests = requests or (16 if quick else 32)

    rng = np.random.default_rng(0)
    params = init_cnn(arch, jax.random.PRNGKey(0))
    c0 = CNN_SPECS[arch][1][0].in_ch
    xs = [np.asarray(v, np.float32)
          for v in rng.standard_normal((requests, c0, hw, hw))]

    failures = []
    for name, straggler in _scenarios(n, delay).items():
        # Best-of-3 on the PERF gate only: a single sweep on a loaded CI
        # box can lose the speedup race to scheduler jitter, so a failing
        # perf measurement is re-run (up to 3 attempts, best speedup kept).
        # Correctness below is single-shot — a wrong result must never be
        # retried away.
        best = None
        for attempt in range(3 if assert_speedup else 1):
            seq_ips = _sequential_baseline(arch, params, n, kab, hw,
                                           straggler, xs)
            stats, pipeline, y0 = _serve(arch, params, n, kab, hw, straggler,
                                         xs, rate_hz, rng)
            # single-shot correctness gate, checked on EVERY attempt: the
            # served answer for request 0 must match the undistributed
            # pipeline run (hard failure, never retried — only the timing
            # race below is flaky, results are not)
            ref = pipeline.run(xs[0][None])
            np.testing.assert_allclose(
                np.asarray(y0), np.asarray(ref)[0], rtol=1e-4, atol=1e-4,
            )
            speedup = stats.images_per_s / seq_ips
            if best is None or speedup > best[0]:
                best = (speedup, seq_ips, stats, pipeline)
            if name == "none" or speedup > 1.0:
                break
            print(f"# exp6/{arch}/{name}: speedup {speedup:.2f}x <= 1.0 "
                  f"on attempt {attempt + 1}, retrying", flush=True)
        speedup, seq_ips, stats, pipeline = best
        emit(
            f"exp6/{arch}/{name}/serving_e2e_p50", stats.e2e_p50_s,
            f"p95={stats.e2e_p95_s*1e3:.1f}ms p99={stats.e2e_p99_s*1e3:.1f}ms "
            f"queue_p50={stats.queue_wait_p50_s*1e3:.1f}ms "
            f"mean_batch={stats.mean_batch_real:.2f}",
        )
        emit(
            f"exp6/{arch}/{name}/serving_throughput", 1.0 / stats.images_per_s,
            f"images_per_s={stats.images_per_s:.1f} "
            f"sequential={seq_ips:.1f} speedup={speedup:.2f}x "
            f"program_traces={pipeline.worker_program_traces}",
        )
        # the acceptance claim is about straggler models: continuous
        # batching must beat per-request calls under the *same* injected
        # stragglers.  The straggler-free row is informational — its margin
        # is pure scheduler-overhead-vs-amortization and too timing-noise
        # sensitive to gate CI on.
        if name != "none" and speedup <= 1.0:
            failures.append((name, round(speedup, 3)))

    if assert_speedup and failures:
        raise SystemExit(
            f"serving engine did not beat sequential run_pipeline "
            f"(best of 3): {failures}"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="AlexNet-scale sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep + assert serving beats sequential")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate-hz", type=float, default=400.0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, requests=args.requests, rate_hz=args.rate_hz,
        assert_speedup=args.smoke)
