"""Experiment 8 (beyond paper): multi-model coded serving on one shared pool.

Registers two CNNs (lenet5 + alexnet, under *different* ``(k_a, k_b)``
plans) on ONE ``CodedServer`` sharing a single n-worker coded pool, drives
Poisson request arrivals at both models concurrently, and compares against
the split-pool baseline: two isolated single-model servers, each owning
half the workers, serving the same traffic concurrently.

The claim measured here is the multi-tenant serving one (cf. CoCoI as a
deployed system, sglang-style multi-model engines): pooling the workers
pools the *coded redundancy*.  Each model's recovery threshold delta stays
fixed, so the shared pool rides out up to ``n - delta`` stragglers, while
a split pool's halves are stuck with ``n/2 - delta`` each — with 5 of 8
workers slowed, every 4+4 split has a half with at least 3 stragglers that
must wait a full straggler delay per layer round, but the shared pool
still decodes from its 3 fast workers.  Fair-share scheduling keeps both
models progressing, and equal-depth coalescing re-packs each model's
bursty fragments into full buckets.

Reported per straggler scenario: per-model p50/p95/p99 end-to-end latency
and images/s for shared and split, plus the aggregate throughput of each.
``--smoke`` asserts shared-pool aggregate throughput beats split-pool
under the straggler scenario and that the jit program count stays bounded
by geometries x buckets summed over the models.

  PYTHONPATH=src python -m benchmarks.exp8_multimodel --smoke
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.core.pipeline import build_cnn_pipeline
from repro.models.cnn import CNN_SPECS, init_cnn, input_hw
from repro.runtime import StragglerModel
from repro.serving import CodedServer

from .common import emit

BUCKETS = (1, 2, 4)
N = 8
SLOWED = 5  # stragglers in the shared pool (any 4+4 split gets >= 3)
MODELS = {"lenet5": (2, 4), "alexnet": (4, 2)}  # distinct plans on one pool


def _scenarios(n: int, delay: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    slowed = np.zeros(n)
    slowed[rng.choice(n, size=SLOWED, replace=False)] = delay
    return {"none": StragglerModel.none(n), f"slow{SLOWED}": StragglerModel(slowed)}


def _build_pipeline(arch, params, n, hw):
    return build_cnn_pipeline(arch, params, n, default_kab=MODELS[arch],
                              input_hw=hw, bucket_sizes=BUCKETS)


def _drive(targets, xs_by_model, rate_hz, seed=0):
    """Fire Poisson traffic at every (model -> server) target concurrently
    (one client thread per model) and wait for every result.  Returns the
    combined completed-request records of all servers involved."""
    handles_by_model = {m: [] for m in targets}
    errs = []

    def client(model, server, xs, gaps):
        try:
            for x, gap in zip(xs, gaps):
                handles_by_model[model].append(server.submit(x, model))
                time.sleep(gap)
        except BaseException as e:  # surfaced after join
            errs.append(e)

    rng = np.random.default_rng(seed)
    threads = [
        threading.Thread(target=client, args=(
            m, server, xs_by_model[m],
            rng.exponential(1.0 / rate_hz, size=len(xs_by_model[m])),
        ))
        for m, server in targets.items()
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]
    for m, handles in handles_by_model.items():
        for h in handles:
            h.result(timeout=300.0)
    records = []
    for server in set(targets.values()):
        records.extend(server.metrics.records())
    return records


def _aggregate_ips(records) -> float:
    wall = max(r.finish_t for r in records) - min(r.arrival_t for r in records)
    return len(records) / wall if wall > 0 else float("inf")


def run(quick: bool = True, requests: int | None = None,
        rate_hz: float = 100.0, assert_speedup: bool = False):
    # reduced resolutions: the sweep scales request *traffic* and pool
    # topology, not image size (alexnet shrinks further in quick mode)
    hws = {"lenet5": input_hw("lenet5", smoke=True),
           "alexnet": 67 if quick else input_hw("alexnet", smoke=True)}
    delay = 0.08 if quick else 0.2
    requests = requests or (6 if quick else 16)

    rng = np.random.default_rng(0)
    params = {a: init_cnn(a, jax.random.PRNGKey(i))
              for i, a in enumerate(MODELS)}
    xs_by_model = {
        a: [np.asarray(v, np.float32) for v in rng.standard_normal(
            (requests, CNN_SPECS[a][1][0].in_ch, hws[a], hws[a]))]
        for a in MODELS
    }

    failures = []
    for scen_name, straggler in _scenarios(N, delay).items():
        # -- shared pool: both models resident on one n-worker server ------
        shared = CodedServer(straggler=straggler, mode="threads",
                             bucket_sizes=BUCKETS)
        for arch in MODELS:
            shared.register_model(
                arch, _build_pipeline(arch, params[arch], N, hws[arch]))
        shared.warmup()
        with shared:
            shared_recs = _drive({a: shared for a in MODELS}, xs_by_model,
                                 rate_hz)
        shared_ips = _aggregate_ips(shared_recs)
        shared_stats = shared.per_model_stats()
        traces = sum(s.pipeline.worker_program_traces
                     for s in shared.models.values())
        trace_bound = sum(s.pipeline.num_geometries * len(BUCKETS)
                          for s in shared.models.values())

        # -- split pools: two isolated servers, half the workers each ------
        half = N // 2
        split_servers = {}
        for i, arch in enumerate(MODELS):
            sub = StragglerModel(straggler.delays[i * half:(i + 1) * half])
            srv = CodedServer(
                _build_pipeline(arch, params[arch], half, hws[arch]),
                sub, mode="threads", model=arch,
            )
            srv.warmup()
            srv.start()
            split_servers[arch] = srv
        try:
            split_recs = _drive(split_servers, xs_by_model, rate_hz)
        finally:
            for srv in split_servers.values():
                srv.shutdown()
        split_ips = _aggregate_ips(split_recs)

        for arch in MODELS:
            st = shared_stats[arch]
            sp = split_servers[arch].stats()
            emit(
                f"exp8/{arch}/{scen_name}/shared_e2e_p50", st.e2e_p50_s,
                f"p95={st.e2e_p95_s*1e3:.1f}ms p99={st.e2e_p99_s*1e3:.1f}ms "
                f"images_per_s={st.images_per_s:.1f} "
                f"split_p95={sp.e2e_p95_s*1e3:.1f}ms",
            )
        speedup = shared_ips / split_ips
        emit(
            f"exp8/aggregate/{scen_name}/shared_throughput", 1.0 / shared_ips,
            f"images_per_s={shared_ips:.1f} split={split_ips:.1f} "
            f"speedup={speedup:.2f}x coalesced={shared.stats().coalesced} "
            f"traces={traces}<={trace_bound}",
        )
        assert traces <= trace_bound, (traces, trace_bound)
        # gate only on the straggler scenario: straggler-free throughput is
        # a pure engine-overhead-vs-parallel-pools race and timing-noisy
        if scen_name != "none" and speedup <= 1.0:
            failures.append((scen_name, round(speedup, 3)))

    if assert_speedup and failures:
        raise SystemExit(
            f"shared-pool multi-model serving did not beat the split-pool "
            f"baseline: {failures}"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full smoke-resolution sweep, more traffic")
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep + assert shared beats split pools")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per model")
    ap.add_argument("--rate-hz", type=float, default=100.0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, requests=args.requests, rate_hz=args.rate_hz,
        assert_speedup=args.smoke)
