"""Benchmark harness: one experiment per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only exp1,exp5]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes (slow)")
    ap.add_argument("--only", default=None, help="comma list: exp1..exp13,roofline")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size for the coded-pipeline sections (exp1/exp4)")
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from . import (
        exp1_naive_vs_fcdcc,
        exp2_stability,
        exp3_scalability,
        exp4_stragglers,
        exp5_partition_opt,
        exp6_serving,
        exp7_pallas_worker,
        exp8_multimodel,
        exp9_fused_transitions,
        exp10_kernel_roofline,
        exp11_device_pool,
        exp12_overlap,
        exp13_lm_decode,
        roofline_report,
    )

    experiments = {
        "exp1": lambda quick: exp1_naive_vs_fcdcc.run(quick, batch=args.batch),
        "exp2": exp2_stability.run,
        "exp3": exp3_scalability.run,
        "exp4": lambda quick: exp4_stragglers.run(quick, batch=args.batch),
        "exp5": exp5_partition_opt.run,
        "exp6": exp6_serving.run,
        "exp7": exp7_pallas_worker.run,
        "exp8": exp8_multimodel.run,
        "exp9": exp9_fused_transitions.run,
        "exp10": exp10_kernel_roofline.run,
        "exp11": exp11_device_pool.run,
        "exp12": exp12_overlap.run,
        "exp13": exp13_lm_decode.run,
        "roofline": roofline_report.run,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in experiments.items():
        if only and name not in only:
            continue
        try:
            fn(quick=quick)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
