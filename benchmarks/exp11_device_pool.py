"""Experiment 11 (beyond paper): device pool vs thread pool + perf ledger.

Times the full coded pipeline (``FcdccCluster.run_pipeline``) on the
paper's CNNs across batch buckets, under the two worker executors:

  * ``threads`` — the per-worker single-thread executors (the pre-PR pool:
    every coded subtask is a host thread calling into the one shared
    device queue).
  * ``device``  — the device-resident pool: each coded worker pinned to
    its own ``jax.Device``, filters resident per device, pure async
    dispatch, fastest-delta reaped via per-array readiness.

On a CPU-only box the devices are emulated host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — set at module
top when run as a script, *before* jax initializes).  The device pool
must still win: dispatch is async (no n blocking host threads per round)
and the per-device queues overlap transfer with compute.

Correctness gates measured alongside the timing (every run, not just
``--smoke``):

  * **bit-parity** — with a forced fastest-delta subset (finite injected
    delays on workers ``delta..n-1``) both pools must pick the identical
    shard subset and produce bit-identical fp32 outputs.
  * **surviving-shard gather** — the decode consumed only the fastest
    delta shards: every ``LayerTiming.used_workers`` is a subset of the
    undelayed workers and the delayed workers' times are NaN (discarded).
  * **bounded-program contract** — per *device*, worker traces stay
    ``<= distinct geometries x buckets`` (no per-request or per-round
    recompilation on any device).

Timing is interleaved and order-rotated (exp10's discipline): each round
times both pools once in rotating order, so clock drift cancels.

The perf trajectory persists in ``BENCH_devices.json`` at the repo root
(committed): a plain run appends one dated run with per-cell
``{threads_us, device_us, speedup}`` plus the aggregate images/s of both
pools.  ``--smoke`` is the CI gate and is read-only: it asserts (a) the
device pool's aggregate throughput >= the thread pool's, (b) the
correctness gates above, and (c) every cell's fresh speedup is no worse
than 10% below the last committed run for that cell.

  PYTHONPATH=src python -m benchmarks.exp11_device_pool          # append
  PYTHONPATH=src python -m benchmarks.exp11_device_pool --smoke  # CI gate
"""
from __future__ import annotations

import json
import os
import sys
import time

# Must precede jax's backend init: 8 emulated host devices when run as a
# script on a CPU box.  When imported by benchmarks.run, jax is already
# initialized and this is a no-op (run() then skips if single-device).
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8"
        ).strip()

import jax
import numpy as np

from repro.core.pipeline import build_cnn_pipeline
from repro.models.cnn import CNN_SPECS, init_cnn, input_hw
from repro.runtime import FcdccCluster, StragglerModel

from .common import emit
from .exp10_kernel_roofline import interleaved

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_devices.json")
REGRESSION_TOL = 0.9  # fresh speedup must stay >= 0.9x the committed one


def load_bench(path: str = BENCH_PATH) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {"schema": 1, "runs": []}


def committed_speedups(bench: dict) -> dict:
    """Per-cell device-vs-threads speedup of the most recent committed run
    that measured the cell."""
    out = {}
    for run_ in bench["runs"]:
        for cell, rec in run_.get("cells", {}).items():
            out[cell] = rec["speedup"]
    return out


def _pipe(arch: str, n: int, kab):
    params = init_cnn(arch, jax.random.PRNGKey(0))
    return build_cnn_pipeline(arch, params, n, default_kab=kab,
                              input_hw=input_hw(arch, smoke=True))


def check_parity(arch: str, pipe, n: int, rng) -> None:
    """Forced-subset bit-parity + surviving-shard gather, threads vs device.

    Workers ``dm..n-1`` get a finite 0.25s delay, so both pools must keep
    exactly the undelayed subset for every layer — making their decodes
    (and therefore the full pipeline outputs) bit-identical fp32.
    """
    dm = max(spec.plan.delta for spec in pipe.specs)
    delays = np.zeros(n)
    delays[dm:] = 0.25
    straggler = StragglerModel(delays)
    x = np.asarray(rng.standard_normal(
        (1, pipe.specs[0].geo.in_channels) + (input_hw(arch, smoke=True),) * 2
    ), np.float32)
    outs, timings = {}, {}
    for pool in ("threads", "device"):
        cluster = FcdccCluster(pipe.specs[0].plan, straggler=straggler,
                               mode="threads", backend="lax", pool=pool)
        try:
            cluster.load_pipeline(pipe, arch)
            y, ts = cluster.run_pipeline(x, model=arch)
            outs[pool] = np.asarray(y)
            timings[pool] = ts
        finally:
            cluster.shutdown()
    if not np.array_equal(outs["threads"], outs["device"]):
        raise SystemExit(
            f"{arch}: forced-subset outputs differ bitwise between the "
            f"thread and device pools")
    delayed = set(range(dm, n))
    for pool, ts in timings.items():
        for t in ts:
            # a delayed worker may legitimately finish (and be measured)
            # after the subset was sealed; what it must never be is *used*
            if set(t.used_workers) & delayed:
                raise SystemExit(
                    f"{arch}/{t.name} [{pool}]: decode consumed a delayed "
                    f"shard: used={t.used_workers}")


def time_arch(arch: str, n: int, kab, buckets, rng, repeat: int = 3):
    """Per-bucket seconds for both pools + the device bounded-trace bound."""
    pipe = {"threads": _pipe(arch, n, kab), "device": _pipe(arch, n, kab)}
    clusters = {
        pool: FcdccCluster(pipe[pool].specs[0].plan, straggler=None,
                           mode="threads", backend="lax", pool=pool)
        for pool in ("threads", "device")
    }
    cells = {}
    try:
        for pool, cluster in clusters.items():
            cluster.load_pipeline(pipe[pool], arch)
        c0 = pipe["threads"].specs[0].geo.in_channels
        hw0 = input_hw(arch, smoke=True)
        for batch in buckets:
            x = np.asarray(rng.standard_normal((batch, c0, hw0, hw0)),
                           np.float32)
            fns = {
                pool: (lambda cl=clusters[pool]:
                       cl.run_pipeline(x, model=arch)[0])
                for pool in ("threads", "device")
            }
            cells[batch] = interleaved(fns, repeat=repeat)
        # bounded-program contract: per device, worker traces stay within
        # distinct geometries x buckets (compile once per cell, never per
        # round).  The thread pool's equivalent is asserted by the tier-1
        # suite; here the *per-device* caches are the new surface.
        # distinct layer geometries: layers sharing a program_key still
        # trace once per shape signature, i.e. once per ConvL per bucket
        geos = len(pipe["device"].specs)
        bound = geos * len(buckets)
        traces = clusters["device"]._pool_impl().program_traces()
        over = {str(d): c for d, c in traces.items() if c > bound}
        if over:
            raise SystemExit(
                f"{arch}: per-device trace count exceeded "
                f"geometries({geos}) x buckets({len(buckets)}) = {bound}: "
                f"{over}")
    finally:
        for cluster in clusters.values():
            cluster.shutdown()
    return cells


def run(quick: bool = True, smoke: bool = False, update: bool = True,
        repeat: int = 3):
    ndev = len(jax.devices())
    if ndev < 2:
        msg = ("exp11 needs a multi-device host; set XLA_FLAGS="
               "--xla_force_host_platform_device_count=8 (or run as "
               "`python -m benchmarks.exp11_device_pool`, which sets it)")
        if smoke:
            raise SystemExit(msg)
        print(f"# exp11 skipped: {msg}", flush=True)
        return {}
    archs = ("lenet5", "alexnet") if quick else ("lenet5", "alexnet", "vgg16")
    buckets = (1, 4) if quick else (1, 4, 8)
    n, kab = 8, (2, 4)
    rng = np.random.default_rng(0)
    prior = committed_speedups(load_bench())
    cells, regressions = {}, []
    agg = {"threads": 0.0, "device": 0.0}  # images/s, summed over cells
    for arch in archs:
        check_parity(arch, _pipe(arch, n, kab), n, rng)
        for batch, ts in time_arch(arch, n, kab, buckets, rng,
                                   repeat=repeat).items():
            cell = f"{arch}/b{batch}"
            speedup = ts["threads"] / ts["device"]
            cells[cell] = {
                "threads_us": round(ts["threads"] * 1e6, 1),
                "device_us": round(ts["device"] * 1e6, 1),
                "speedup": round(speedup, 3),
            }
            for pool in ("threads", "device"):
                agg[pool] += batch / ts[pool]
                emit(f"exp11/{cell}/{pool}", ts[pool],
                     f"device_vs_threads={speedup:.2f}x")
            committed = prior.get(cell)
            if committed and speedup < REGRESSION_TOL * committed:
                regressions.append((cell, round(speedup, 3), committed))
    emit("exp11/aggregate", 0.0,
         f"threads={agg['threads']:.1f}img/s device={agg['device']:.1f}img/s")
    if smoke:
        if agg["device"] < agg["threads"]:
            raise SystemExit(
                f"device pool did not beat the thread pool in aggregate "
                f"throughput: device={agg['device']:.1f} img/s < "
                f"threads={agg['threads']:.1f} img/s")
        if regressions:
            raise SystemExit(
                "device-pool perf regressed >10% vs the committed BENCH "
                f"trajectory (cell, now, committed): {regressions}")
        return cells
    if update:
        bench = load_bench()
        bench["runs"].append({
            "date": time.strftime("%Y-%m-%d"),
            "backend": jax.default_backend(),
            "devices": ndev,
            "quick": quick,
            "cells": cells,
            "aggregate_img_per_s": {k: round(v, 1) for k, v in agg.items()},
        })
        tmp = f"{BENCH_PATH}.tmp"
        with open(tmp, "w") as f:
            json.dump(bench, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, BENCH_PATH)
    return cells


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all three CNNs + bucket 8")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: device >= threads aggregate throughput, "
                         "forced-subset bit-parity, surviving-shard gather, "
                         "bounded per-device traces, and no >10%% regression "
                         "vs BENCH_devices.json (read-only)")
    ap.add_argument("--no-update", action="store_true",
                    help="measure + print only; don't append to the ledger")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, smoke=args.smoke, update=not args.no_update)
