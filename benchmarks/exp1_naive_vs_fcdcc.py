"""Experiment 1 (paper Table III): FCDCC vs naive single-node per ConvL.

Reports per-layer: naive conv time, FCDCC per-worker compute time (the
paper's distributed latency proxy: subtask time on one node), decode
overhead, and float64 MSE vs the naive output.  Config (k_A,k_B)=(2,32),
n=18, delta=16 as in the paper (``--quick`` shrinks n and the VGG input).
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.fcdcc import CodedConv2d, FcdccPlan  # noqa: E402
from repro.models.cnn import CNN_SPECS, layer_geometry  # noqa: E402

from .common import emit, timed  # noqa: E402


def run(quick: bool = True):
    n = 6 if quick else 18
    k_a, k_b = 2, (8 if quick else 32)
    plan = FcdccPlan(n=n, k_a=k_a, k_b=k_b)
    rng = np.random.default_rng(0)

    nets = {
        "lenet5": 32,
        "alexnet": 227 if not quick else 113,
        "vgg16": 224 if not quick else 56,
    }
    for net, hw0 in nets.items():
        hw = hw0
        _, layers = CNN_SPECS[net]
        for layer in layers:
            if layer.out_ch % k_b:
                kb_l = max(x for x in (1, 2, 4, 8) if layer.out_ch % x == 0)
            else:
                kb_l = k_b
            lplan = FcdccPlan(n=n, k_a=k_a, k_b=kb_l)
            geo = layer_geometry(layer, hw, k_a, kb_l)
            x = jnp.asarray(rng.standard_normal((layer.in_ch, hw, hw)))
            k = jnp.asarray(
                rng.standard_normal((layer.out_ch, layer.in_ch, layer.kernel, layer.kernel))
                / (layer.in_ch * layer.kernel**2) ** 0.5
            )
            coded = CodedConv2d(lplan, geo)

            naive = jax.jit(
                lambda x, k: jax.lax.conv_general_dilated(
                    x[None], k, (layer.stride, layer.stride),
                    ((layer.padding, layer.padding),) * 2,
                    dimension_numbers=("NCHW", "OIHW", "NCHW"),
                )[0]
            )
            t_naive = timed(naive, x, k)
            y_naive = naive(x, k)

            xe = coded.encode_inputs(x)
            ke = coded.encode_filters(k)
            worker = jax.jit(coded.worker_compute)
            t_worker = timed(worker, xe[0], ke[0])

            ids = list(range(lplan.delta))
            outs = jax.vmap(coded.worker_compute)(xe[jnp.asarray(ids)], ke[jnp.asarray(ids)])
            t_decode = timed(lambda o: coded.decode(ids, o), outs)
            y = coded.decode(ids, outs)
            mse = float(jnp.mean((y - y_naive) ** 2))
            emit(
                f"exp1/{net}/{layer.name}/naive", t_naive,
                f"hw={hw}",
            )
            emit(
                f"exp1/{net}/{layer.name}/fcdcc_worker", t_worker,
                f"speedup={t_naive/t_worker:.1f}x mse={mse:.2e} decode_ms={t_decode*1e3:.2f}",
            )
            ho = geo.out_h
            hw = ho // layer.pool if layer.pool > 1 else ho


if __name__ == "__main__":
    run()
