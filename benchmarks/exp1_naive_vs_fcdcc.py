"""Experiment 1 (paper Table III): FCDCC vs naive single-node per ConvL.

Two sections:

  * per-layer (the paper's table): naive conv time, FCDCC per-worker compute
    time (the paper's distributed latency proxy: subtask time on one node),
    decode overhead, and float64 MSE vs the naive output.  Config
    (k_A,k_B)=(2,32), n=18, delta=16 as in the paper (``--quick`` shrinks n
    and the VGG input).
  * whole-network amortization (beyond paper): the seed executed one image
    at a time and re-encoded filters + re-jitted the worker program for
    every layer of every call ("cold start").  The ``CodedPipeline`` engine
    pays that once; ``--batch B`` then streams a (B,C,H,W) batch through the
    resident coded network and reports steady-state per-image latency, which
    must come in far below the cold-start path.

  PYTHONPATH=src python -m benchmarks.exp1_naive_vs_fcdcc --batch 8
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.fcdcc import CodedConv2d, FcdccPlan  # noqa: E402
from repro.core.pipeline import CodedPipeline, plan_layers  # noqa: E402
from repro.models.cnn import CNN_SPECS, init_cnn, layer_geometry  # noqa: E402

from .common import emit, timed  # noqa: E402


def _per_layer_kab(layers, k_a, k_b):
    """Per-layer (k_a, k_b): shrink k_b to a divisor of out_ch (avoids
    channel zero-pad waste) as the seed benchmark did."""
    out = {}
    for layer in layers:
        if layer.out_ch % k_b:
            kb_l = max(x for x in (1, 2, 4, 8) if layer.out_ch % x == 0)
        else:
            kb_l = k_b
        out[layer.name] = (k_a, kb_l)
    return out


def run_per_layer(nets: dict, n: int, k_a: int, k_b: int):
    rng = np.random.default_rng(0)
    for net, hw0 in nets.items():
        hw = hw0
        _, layers = CNN_SPECS[net]
        kab = _per_layer_kab(layers, k_a, k_b)
        for layer in layers:
            kb_l = kab[layer.name][1]
            lplan = FcdccPlan(n=n, k_a=k_a, k_b=kb_l)
            geo = layer_geometry(layer, hw, k_a, kb_l)
            x = jnp.asarray(rng.standard_normal((layer.in_ch, hw, hw)))
            k = jnp.asarray(
                rng.standard_normal((layer.out_ch, layer.in_ch, layer.kernel, layer.kernel))
                / (layer.in_ch * layer.kernel**2) ** 0.5
            )
            coded = CodedConv2d(lplan, geo)

            naive = jax.jit(
                lambda x, k: jax.lax.conv_general_dilated(
                    x[None], k, (layer.stride, layer.stride),
                    ((layer.padding, layer.padding),) * 2,
                    dimension_numbers=("NCHW", "OIHW", "NCHW"),
                )[0]
            )
            t_naive = timed(naive, x, k)
            y_naive = naive(x, k)

            xe = coded.encode_inputs(x)
            ke = coded.encode_filters(k)
            worker = jax.jit(coded.worker_compute)
            t_worker = timed(worker, xe[0], ke[0])

            ids = list(range(lplan.delta))
            outs = jax.vmap(coded.worker_compute)(xe[jnp.asarray(ids)], ke[jnp.asarray(ids)])
            t_decode = timed(lambda o: coded.decode(ids, o), outs)
            y = coded.decode(ids, outs)
            mse = float(jnp.mean((y - y_naive) ** 2))
            emit(
                f"exp1/{net}/{layer.name}/naive", t_naive,
                f"hw={hw}",
            )
            emit(
                f"exp1/{net}/{layer.name}/fcdcc_worker", t_worker,
                f"speedup={t_naive/t_worker:.1f}x mse={mse:.2e} decode_ms={t_decode*1e3:.2f}",
            )
            ho = geo.out_h
            hw = ho // layer.pool if layer.pool > 1 else ho


def run_pipeline_amortized(nets: dict, n: int, k_a: int, k_b: int, batch: int):
    """Cold-start (the seed's per-layer rebuild) vs steady-state batched
    coded inference through a resident ``CodedPipeline``."""
    import time

    rng = np.random.default_rng(1)
    for net, hw0 in nets.items():
        _, layers = CNN_SPECS[net]
        kab = _per_layer_kab(layers, k_a, k_b)
        params = init_cnn(net, jax.random.PRNGKey(0), dtype=jnp.float64)
        c0 = layers[0].in_ch
        x1 = jnp.asarray(rng.standard_normal((c0, hw0, hw0)))
        xb = jnp.asarray(rng.standard_normal((batch, c0, hw0, hw0)))

        def cold_run():
            # the seed path: rebuild everything — re-partition, re-encode
            # filters, re-jit the worker program — for one image
            specs = plan_layers(layers, hw0, n, default_kab=(k_a, k_b),
                                per_layer_kab=kab)
            pipe = CodedPipeline(specs, params)
            return pipe.run(x1)

        t0 = time.perf_counter()
        jax.block_until_ready(cold_run())
        t_cold = time.perf_counter() - t0

        specs = plan_layers(layers, hw0, n, default_kab=(k_a, k_b),
                            per_layer_kab=kab)
        pipe = CodedPipeline(specs, params)
        t_steady_batch = timed(lambda xx: pipe.run(xx), xb)
        t_steady = t_steady_batch / batch
        emit(
            f"exp1/{net}/pipeline/cold_start_per_image", t_cold,
            "encode+jit every layer (seed path) batch=1",
        )
        emit(
            f"exp1/{net}/pipeline/steady_per_image", t_steady,
            f"batch={batch} amortized={t_cold/t_steady:.1f}x "
            f"programs={pipe.num_worker_programs} "
            f"filter_encodes={pipe.filter_encode_calls}/{len(layers)}",
        )


def run(quick: bool = True, batch: int = 4):
    n = 6 if quick else 18
    k_a, k_b = 2, (8 if quick else 32)
    nets = {
        "lenet5": 32,
        "alexnet": 227 if not quick else 113,
        "vgg16": 224 if not quick else 56,
    }
    run_per_layer(nets, n, k_a, k_b)
    run_pipeline_amortized(nets, n, k_a, k_b, batch)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size for the steady-state pipeline section")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, batch=args.batch)
