"""Experiment 10 (beyond paper): kernel roofline + persistent perf ledger.

Times the coded-worker kernel — the op the cluster launches n times per
layer per batch — on real (geometry, bucket) cells from ``plan_layers``
over the paper's CNNs, under three configurations:

  * ``baseline`` — the pre-PR kernel: two-step im2col (HBM patch tensor via
    ``conv_general_dilated_patches``) feeding the single-buffered grid-K
    ``matmul_pallas`` (``num_buffers=1``), default tiles.
  * ``fused``    — in-kernel im2col (``fused_im2col=True``): patch rows
    gathered inside the kernel, no HBM patch tensor, multi-buffered GEMM.
  * ``tuned``    — whatever the autotune ledger picks for the cell
    (``repro.kernels.autotune.tune_worker`` sweeps both strategies, so
    tuned is never a worse *choice* than either — modulo timing noise).

All three accumulate fp32 over identical K chunks in the same order, so
their outputs must be **bit-identical** (asserted, ``np.array_equal``).

Timing is interleaved and order-rotated (cf. exp9's paired timing): each
round times every variant once in rotating order, so clock drift on a
shared box cancels instead of biasing whichever ran last.

The perf trajectory persists in ``BENCH_kernels.json`` at the repo root
(committed): a plain run appends one dated run with per-cell
``{baseline_us, fused_us, tuned_us, speedup}``.  ``--smoke`` is the CI
gate and is read-only: it asserts (a) fused beats baseline on every cell,
(b) bit-identical outputs, and (c) the fresh fused-vs-baseline speedup of
every cell is no worse than 10% below the last committed run for that
cell — a kernel regression fails CI even if everything stays "correct".

  PYTHONPATH=src python -m benchmarks.exp10_kernel_roofline          # append
  PYTHONPATH=src python -m benchmarks.exp10_kernel_roofline --smoke  # CI gate
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fcdcc import CodedConv2d
from repro.core.pipeline import plan_layers
from repro.kernels import autotune
from repro.kernels.conv2d.kernel import coded_worker_pallas
from repro.models.cnn import CNN_SPECS, input_hw

from .common import emit

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kernels.json")
VARIANTS = ("baseline", "fused", "tuned")
REGRESSION_TOL = 0.9  # fresh speedup must stay >= 0.9x the committed one


def _middle_spec(arch: str, n: int, kab):
    hw0, layers = CNN_SPECS[arch]
    specs = plan_layers(layers, input_hw(arch, smoke=True), n,
                        default_kab=kab)
    return specs[len(specs) // 2]


def interleaved(fns: dict, repeat: int = 5) -> dict:
    """min-of-N seconds per named thunk, one call of each per round in
    rotating order (exp9's paired timing generalized to N variants)."""
    names = list(fns)
    for name in names:  # compile + warm outside the timed region
        jax.block_until_ready(fns[name]())
    ts = {name: [] for name in names}
    for i in range(repeat):
        order = names[i % len(names):] + names[:i % len(names)]
        for name in order:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[name]())
            ts[name].append(time.perf_counter() - t0)
    return {name: min(v) for name, v in ts.items()}


def time_cell(spec, batch: int, rng, repeat: int = 5):
    """Seconds per variant for one worker subtask cell + bit-parity check."""
    geo = spec.geo
    x = jnp.asarray(rng.standard_normal(
        (batch, geo.in_channels, geo.height, geo.width)), jnp.float32)
    k = jnp.asarray(rng.standard_normal(
        (geo.out_channels, geo.in_channels, geo.kernel_h, geo.kernel_w)),
        jnp.float32)
    enc = CodedConv2d(spec.plan, spec.geo, backend="lax")
    xe = jax.block_until_ready(enc.encode_inputs(x)[0])
    ke = jax.block_until_ready(enc.encode_filters(k)[0])
    stride = geo.stride
    tuned_kw = autotune.tune_worker(tuple(xe.shape), tuple(ke.shape), stride)
    configs = {
        "baseline": {"fused_im2col": False, "num_buffers": 1},
        "fused": {"fused_im2col": True},
        "tuned": tuned_kw,
    }
    fns, outs = {}, {}
    for name, kw in configs.items():
        fn = jax.jit(lambda a, b, kw_=dict(kw): coded_worker_pallas(
            a, b, stride, **kw_))
        outs[name] = np.asarray(jax.block_until_ready(fn(xe, ke)))
        fns[name] = lambda fn_=fn: fn_(xe, ke)
    for name in ("fused", "tuned"):  # same fp32 chunk order -> bit-identical
        assert np.array_equal(outs[name], outs["baseline"]), (
            f"{name} output differs bitwise from baseline for {spec.name}")
    return interleaved(fns, repeat=repeat), tuned_kw


def load_bench(path: str = BENCH_PATH) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {"schema": 1, "runs": []}


def committed_speedups(bench: dict) -> dict:
    """Per-cell fused-vs-baseline speedup of the most recent committed run
    that measured the cell."""
    out = {}
    for run in bench["runs"]:
        for cell, rec in run.get("cells", {}).items():
            out[cell] = rec["speedup"]
    return out


def run(quick: bool = True, smoke: bool = False, update: bool = True):
    archs = ("lenet5", "alexnet") if quick else ("lenet5", "alexnet", "vgg16")
    buckets = (1, 4) if quick else (1, 4, 8)
    n, kab = 8, (2, 4)
    rng = np.random.default_rng(0)
    prior = committed_speedups(load_bench())
    cells, failures, regressions = {}, [], []
    for arch in archs:
        spec = _middle_spec(arch, n, kab)
        for batch in buckets:
            ts, tuned_kw = time_cell(spec, batch, rng)
            cell = f"{arch}/{spec.name}/b{batch}"
            speedup = ts["baseline"] / ts["fused"]
            cells[cell] = {
                "baseline_us": round(ts["baseline"] * 1e6, 1),
                "fused_us": round(ts["fused"] * 1e6, 1),
                "tuned_us": round(ts["tuned"] * 1e6, 1),
                "speedup": round(speedup, 3),
            }
            for name in VARIANTS:
                emit(f"exp10/{cell}/{name}", ts[name],
                     f"fused_vs_baseline={speedup:.2f}x "
                     f"tuned={tuned_kw}")
            if speedup <= 1.0:
                failures.append((cell, round(speedup, 3)))
            committed = prior.get(cell)
            if committed and speedup < REGRESSION_TOL * committed:
                regressions.append((cell, round(speedup, 3), committed))
    if smoke:
        if failures:
            raise SystemExit(
                f"fused kernel did not beat the baseline: {failures}")
        if regressions:
            raise SystemExit(
                "kernel perf regressed >10% vs the committed BENCH "
                f"trajectory (cell, now, committed): {regressions}")
        return cells
    if update:
        bench = load_bench()
        bench["runs"].append({
            "date": time.strftime("%Y-%m-%d"),
            "backend": jax.default_backend(),
            "interpret": True,
            "quick": quick,
            "cells": cells,
        })
        tmp = f"{BENCH_PATH}.tmp"
        with open(tmp, "w") as f:
            json.dump(bench, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, BENCH_PATH)
    return cells


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all three CNNs + bucket 8")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: assert fused beats baseline bit-exactly "
                         "and no >10%% regression vs BENCH_kernels.json "
                         "(read-only)")
    ap.add_argument("--no-update", action="store_true",
                    help="measure + print only; don't append to the ledger")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, smoke=args.smoke, update=not args.no_update)
