"""Experiment 5 (paper Fig. 7 + Table IV): optimal (k_A, k_B) per ConvL.

Minimizes U(k_A,k_B) = C_comm + C_store (lambda_comp = 0, as in the paper)
with AWS-pricing weights lambda_comm = 0.09, lambda_store = 0.023 over
Q in {16, 32, 64} for LeNet-5 / AlexNet / VGGNet ConvLs, and checks the
discrete optimum against Theorem 1's continuous solution.
"""
from __future__ import annotations

from repro.core.cost import CostWeights, continuous_optimum, optimal_partition
from repro.models.cnn import CNN_SPECS, layer_geometry

from .common import emit

W = CostWeights(comm=0.09, store=0.023, comp=0.0)


def run(quick: bool = True):
    for net in ("lenet5", "alexnet", "vgg16"):
        hw0, layers = CNN_SPECS[net]
        for q in (16, 32, 64):
            hw = hw0
            picks = []
            for layer in layers:
                geo = layer_geometry(layer, hw)
                (ka, kb), cost, _ = optimal_partition(geo, q, W)
                kc = continuous_optimum(geo, q, W)
                picks.append(f"{layer.name}:({ka},{kb})")
                emit(
                    f"exp5/{net}/Q{q}/{layer.name}", 0.0,
                    f"kA*={ka} kB*={kb} U={cost:.0f} kA_cont={kc:.1f}",
                )
                hw = geo.out_h // layer.pool if layer.pool > 1 else geo.out_h


if __name__ == "__main__":
    run()
