"""Experiment 3 (paper Fig. 5): scalability in (n, delta).

Average completion time of AlexNet ConvLs under FCDCC as worker count n
and recovery threshold delta grow (gamma = 4 fixed).  Simulated-clock
cluster: per-subtask compute is measured (jitted, steady-state) and the
master finishes at the delta-th fastest worker.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.fcdcc import FcdccPlan
from repro.models.cnn import CNN_SPECS, layer_geometry
from repro.runtime import FcdccCluster, StragglerModel

from .common import emit

GRID = [(8, 4), (12, 8), (20, 16), (28, 24), (36, 32)]


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    # full spatial size even in quick mode: at small sizes per-subtask
    # dispatch overhead (~ms) drowns the 1/Q workload trend of Fig. 5
    hw0 = 227
    _, layers = CNN_SPECS["alexnet"]
    for n, delta in GRID:
        # delta = k_a*k_b/4 -> pick k_a=2, k_b=2*delta
        plan = FcdccPlan(n=n, k_a=2, k_b=2 * delta)
        total = 0.0
        hw = hw0
        for layer in layers:
            k_b = 2 * delta
            if layer.out_ch % k_b:
                k_b = max(x for x in range(1, layer.out_ch + 1)
                          if layer.out_ch % x == 0 and (x == 1 or x % 2 == 0) and x <= 2 * delta)
            lplan = FcdccPlan(n=n, k_a=2, k_b=k_b) if k_b != 2 * delta else plan
            geo = layer_geometry(layer, hw, lplan.k_a, lplan.k_b)
            x = jnp.asarray(rng.standard_normal((layer.in_ch, hw, hw)), jnp.float32)
            kk = jnp.asarray(
                rng.standard_normal((layer.out_ch, layer.in_ch, layer.kernel, layer.kernel)),
                jnp.float32,
            )
            cluster = FcdccCluster(lplan, StragglerModel.none(n), mode="simulated")
            _, t = cluster.run_layer(geo, x, kk)
            total += t.compute_s
            hw = geo.out_h // layer.pool if layer.pool > 1 else geo.out_h
        emit(f"exp3/alexnet_n{n}_d{delta}", total, f"gamma={n-delta}")


if __name__ == "__main__":
    run()
