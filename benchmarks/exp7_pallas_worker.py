"""Experiment 7 (beyond paper): the fused batched Pallas coded-worker kernel.

Times ONE worker's coded subtask — the hot op the cluster dispatches n times
per layer per batch — under three implementations:

  * ``lax_fused``      — one batched ``lax.conv_general_dilated`` (XLA's own
    conv lowering; the pre-existing fast path).
  * ``pallas_unfused`` — the pre-PR ``backend="pallas"`` path: the
    paper-literal ``ell_a * ell_b`` pairwise loop, each pair a per-image
    ``conv2d_im2col`` vmapped over the request batch — ``ell_a*ell_b*B``
    tiny GEMM launches.
  * ``pallas_fused``   — the fused kernel (``coded_worker_pallas``): the
    ``ell_a`` coded shares x batch B collapse into the GEMM M dimension,
    the ``ell_b`` coded filter groups concatenate into N — one im2col +
    one MXU tile sweep per worker per layer.

Geometries are real per-layer specs from ``plan_layers`` over the paper's
CNNs (the middle ConvL of each stack at the CPU smoke resolution), swept
over the serving engine's batch buckets.  ``--smoke`` asserts the fused
kernel beats the unfused loop on every measured cell.

  PYTHONPATH=src python -m benchmarks.exp7_pallas_worker --smoke
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fcdcc import CodedConv2d
from repro.core.pipeline import plan_layers
from repro.models.cnn import CNN_SPECS, input_hw

from .common import emit, timed

VARIANTS = ("lax_fused", "pallas_unfused", "pallas_fused")


def _middle_spec(arch: str, n: int, kab):
    hw0, layers = CNN_SPECS[arch]
    specs = plan_layers(layers, input_hw(arch, smoke=True), n,
                        default_kab=kab)
    return specs[len(specs) // 2]


def _worker_variants(spec):
    return {
        "lax_fused": CodedConv2d(spec.plan, spec.geo, backend="lax"),
        "pallas_unfused": CodedConv2d(spec.plan, spec.geo, backend="pallas",
                                      fused_worker=False),
        "pallas_fused": CodedConv2d(spec.plan, spec.geo, backend="pallas"),
    }


def time_worker(spec, batch: int, rng) -> dict[str, float]:
    """Steady-state seconds for one worker's coded subtask per variant."""
    geo = spec.geo
    x = jnp.asarray(rng.standard_normal(
        (batch, geo.in_channels, geo.height, geo.width)), jnp.float32)
    k = jnp.asarray(rng.standard_normal(
        (geo.out_channels, geo.in_channels, geo.kernel_h, geo.kernel_w)),
        jnp.float32)
    variants = _worker_variants(spec)
    enc = variants["lax_fused"]  # encode is backend-independent
    xe = jax.block_until_ready(enc.encode_inputs(x))
    ke = jax.block_until_ready(enc.encode_filters(k))
    out = {}
    ref = None
    for name, layer in variants.items():
        fn = jax.jit(layer.worker_compute)
        y = jax.block_until_ready(fn(xe[0], ke[0]))
        if ref is None:
            ref = np.asarray(y)
        else:  # all three compute the same coded subtask
            np.testing.assert_allclose(np.asarray(y), ref, atol=1e-3)
        out[name] = timed(fn, xe[0], ke[0])
    return out


def run(quick: bool = True, buckets=None, assert_fused: bool = False):
    archs = ("lenet5",) if quick else ("lenet5", "alexnet", "vgg16")
    buckets = buckets or ((1, 4) if quick else (1, 4, 8))
    n, kab = 8, (2, 4)
    rng = np.random.default_rng(0)
    failures = []
    for arch in archs:
        spec = _middle_spec(arch, n, kab)
        for batch in buckets:
            ts = time_worker(spec, batch, rng)
            fused_speedup = ts["pallas_unfused"] / ts["pallas_fused"]
            for name in VARIANTS:
                emit(
                    f"exp7/{arch}/{spec.name}/b{batch}/{name}", ts[name],
                    f"geo={spec.geo.in_channels}x{spec.geo.height}"
                    f"->{spec.geo.out_channels} "
                    f"fused_vs_unfused={fused_speedup:.2f}x "
                    f"lax_vs_fused={ts['lax_fused']/ts['pallas_fused']:.2f}x",
                )
            if fused_speedup <= 1.0:
                failures.append((arch, batch, round(fused_speedup, 3)))
    if assert_fused and failures:
        raise SystemExit(
            f"fused pallas worker did not beat the unfused loop: {failures}"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all three CNNs + bucket 8")
    ap.add_argument("--smoke", action="store_true",
                    help="quick sweep + assert fused beats unfused")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, assert_fused=args.smoke)
