"""AdamW with decoupled weight decay, global-norm clipping and fp32 moments
over (possibly bf16) params.  Pure-pytree implementation — state shards
exactly like the params (same logical axes)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
