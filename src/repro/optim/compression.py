"""Gradient compression for cross-pod reduction.

Two schemes, both with error feedback so compression noise does not bias
the optimizer:

* int8 stochastic-free linear quantization (per-leaf absmax scaling) —
  4x cross-pod bytes reduction; decompression is exact up to 1/127 absmax.
* top-k sparsification (keep the largest |g| entries per leaf).

Usage in the train step: grads are reduced normally inside a pod (full
ICI bandwidth); the *cross-pod* contribution is compressed before the
"pod"-axis reduction.  In the single-program pjit view we model this as
compress -> decompress around the pod-mean, which makes the numerics of
the deployed system reproducible in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(x):
    absmax = jnp.max(jnp.abs(x)) + 1e-12
    q = jnp.clip(jnp.round(x / absmax * 127.0), -127, 127).astype(jnp.int8)
    return q, absmax


def int8_decompress(q, absmax):
    return q.astype(jnp.float32) * (absmax / 127.0)


def topk_compress(x, frac: float):
    flat = x.reshape(-1)
    k = max(int(flat.size * frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    return kept, idx, flat.size


def topk_decompress(kept, idx, size, shape):
    out = jnp.zeros((size,), kept.dtype).at[idx].set(kept)
    return out.reshape(shape)


def compress_tree(grads, residual, scheme: str = "int8", topk_frac: float = 0.01):
    """Error-feedback compression: returns (decompressed_grads, new_residual).

    ``residual`` accumulates what compression dropped; it is added back
    before the next round (error feedback / EF21-style).
    """
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, r):
        x = g.astype(jnp.float32) + r
        if scheme == "int8":
            q, s = int8_compress(x)
            d = int8_decompress(q, s)
        elif scheme == "topk":
            kept, idx, size = topk_compress(x, topk_frac)
            d = topk_decompress(kept, idx, size, x.shape)
        else:
            raise ValueError(scheme)
        return d.astype(g.dtype), x - d

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    dec = jax.tree.unflatten(tdef, [o[0] for o in outs])
    res = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return dec, res


def compressed_bytes(grads, scheme: str = "int8", topk_frac: float = 0.01) -> int:
    """Cross-pod bytes after compression (for the roofline collective term)."""
    n = sum(x.size for x in jax.tree.leaves(grads))
    if scheme == "int8":
        return n  # 1 byte/entry
    if scheme == "topk":
        return int(n * topk_frac) * 8  # value + index
    raise ValueError(scheme)
