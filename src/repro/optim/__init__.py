from .adamw import AdamWConfig, apply_updates, global_norm, init_state
from .compression import compress_tree, compressed_bytes
from .schedule import cosine_with_warmup
