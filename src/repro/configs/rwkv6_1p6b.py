"""RWKV6-1.6B "Finch" — attention-free, data-dependent decay
[arXiv:2404.05892].  O(1) recurrent state -> runs long_500k."""
from repro.models.registry import make_rwkv_bundle
from repro.models.rwkv6 import RwkvConfig

ARCH = "rwkv6-1.6b"


def full():
    cfg = RwkvConfig(
        name=ARCH,
        layers=24,
        d_model=2048,
        d_ff=7168,
        vocab=65536,
        head_dim=64,
    )
    return make_rwkv_bundle(cfg)


def smoke():
    cfg = RwkvConfig(
        name=ARCH + "-smoke",
        layers=2,
        d_model=64,
        d_ff=128,
        vocab=256,
        head_dim=16,
        decay_lora=8,
        chunk=8,
    )
    return make_rwkv_bundle(cfg)
