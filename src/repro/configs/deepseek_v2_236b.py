"""DeepSeek-V2 236B — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]."""
from repro.models.moe import MoEConfig
from repro.models.registry import make_lm_bundle
from repro.models.transformer import LMConfig, MLAConfig

ARCH = "deepseek-v2-236b"


def full(dispatch_groups: int = 16):
    cfg = LMConfig(
        name=ARCH,
        layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12288,  # dense-first layer width (hf); experts use 1536
        vocab=102400,
        attn="mla",
        mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
        moe=MoEConfig(
            n_routed=160, top_k=6, d_model=5120, d_ff_expert=1536, n_shared=2,
            dispatch_groups=dispatch_groups,
        ),
        n_dense_layers=1,
        tie_embeddings=False,
        max_seq=32768,
    )
    return make_lm_bundle(cfg)


def smoke():
    cfg = LMConfig(
        name=ARCH + "-smoke",
        layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        attn="mla",
        mla=MLAConfig(q_lora=0, kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
        moe=MoEConfig(n_routed=8, top_k=2, d_model=64, d_ff_expert=32, n_shared=2),
        n_dense_layers=1,
        tie_embeddings=False,
        max_seq=128,
    )
    return make_lm_bundle(cfg)
