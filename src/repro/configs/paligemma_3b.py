"""PaliGemma-3B — SigLIP frontend stubbed as 256 prefix patch embeddings;
gemma-1 2B text backbone (MQA kv=1) [arXiv:2407.07726; hf]."""
from repro.models.registry import make_lm_bundle
from repro.models.transformer import LMConfig

ARCH = "paligemma-3b"


def full():
    cfg = LMConfig(
        name=ARCH,
        layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab=257216,
        act="gelu",
        embed_scale=True,
        tie_embeddings=True,
        max_seq=32768,
    )
    return make_lm_bundle(cfg, family="vlm")


def smoke():
    cfg = LMConfig(
        name=ARCH + "-smoke",
        layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab=256,
        act="gelu",
        embed_scale=True,
        max_seq=128,
    )
    return make_lm_bundle(cfg, family="vlm")
