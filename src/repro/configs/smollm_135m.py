"""SmolLM-135M — llama-arch small [hf:HuggingFaceTB/SmolLM-135M].
9 heads / 3 kv heads do not divide model=16 -> those dims replicate
(divisibility-aware sharding helper)."""
from repro.models.registry import make_lm_bundle
from repro.models.transformer import LMConfig

ARCH = "smollm-135m"


def full():
    cfg = LMConfig(
        name=ARCH,
        layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        head_dim=64,
        d_ff=1536,
        vocab=49152,
        tie_embeddings=True,
        max_seq=32768,
    )
    return make_lm_bundle(cfg)


def smoke():
    cfg = LMConfig(
        name=ARCH + "-smoke",
        layers=2,
        d_model=48,
        n_heads=3,
        n_kv_heads=1,
        head_dim=16,
        d_ff=96,
        vocab=256,
        max_seq=128,
    )
    return make_lm_bundle(cfg)
