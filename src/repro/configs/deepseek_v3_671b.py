"""DeepSeek-V3 671B — MLA + 1 shared + 256 routed top-8 MoE, 3 dense-first
layers [arXiv:2412.19437; hf].  MTP head omitted (DESIGN.md §4)."""
from repro.models.moe import MoEConfig
from repro.models.registry import make_lm_bundle
from repro.models.transformer import LMConfig, MLAConfig

ARCH = "deepseek-v3-671b"


def full(dispatch_groups: int = 16):
    cfg = LMConfig(
        name=ARCH,
        layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=18432,  # dense-first layers (hf); assigned d_ff=2048 is the expert width
        vocab=129280,
        attn="mla",
        mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
        moe=MoEConfig(
            n_routed=256, top_k=8, d_model=7168, d_ff_expert=2048, n_shared=1,
            dispatch_groups=dispatch_groups,
        ),
        n_dense_layers=3,
        tie_embeddings=False,
        max_seq=32768,
    )
    return make_lm_bundle(cfg)


def smoke():
    cfg = LMConfig(
        name=ARCH + "-smoke",
        layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        attn="mla",
        mla=MLAConfig(q_lora=32, kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
        moe=MoEConfig(n_routed=8, top_k=2, d_model=64, d_ff_expert=32, n_shared=1),
        n_dense_layers=1,
        tie_embeddings=False,
        max_seq=128,
    )
    return make_lm_bundle(cfg)
