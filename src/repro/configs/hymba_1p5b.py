"""Hymba-1.5B — parallel attention + mamba heads [arXiv:2411.13676; hf].
Sub-quadratic (windowed attn + SSM) -> runs long_500k."""
from repro.models.hymba import HymbaConfig
from repro.models.registry import make_hymba_bundle

ARCH = "hymba-1.5b"


def full():
    cfg = HymbaConfig(
        name=ARCH,
        layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab=32001,
        ssm_state=16,
        window=1024,
    )
    return make_hymba_bundle(cfg)


def smoke():
    cfg = HymbaConfig(
        name=ARCH + "-smoke",
        layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        ssm_state=8,
        window=16,
        chunk=8,
    )
    return make_hymba_bundle(cfg)
