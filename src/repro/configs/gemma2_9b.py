"""Gemma2-9B — local/global alternating windows, attn+logit softcaps,
sandwich norms, scaled embeddings [arXiv:2408.00118; hf]."""
from repro.models.registry import make_lm_bundle
from repro.models.transformer import LMConfig

ARCH = "gemma2-9b"


def full():
    cfg = LMConfig(
        name=ARCH,
        layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab=256000,
        act="gelu",
        attn_softcap=50.0,
        logit_softcap=30.0,
        window=4096,
        window_pattern="alternate",
        sandwich_norms=True,
        embed_scale=True,
        tie_embeddings=True,
        max_seq=32768,
    )
    return make_lm_bundle(cfg)


def smoke():
    cfg = LMConfig(
        name=ARCH + "-smoke",
        layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        act="gelu",
        attn_softcap=50.0,
        logit_softcap=30.0,
        window=16,
        window_pattern="alternate",
        sandwich_norms=True,
        embed_scale=True,
        max_seq=128,
    )
    return make_lm_bundle(cfg)
