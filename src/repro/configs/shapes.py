"""Assigned input shapes and ShapeDtypeStruct builders for the dry-run.

Every (arch x shape) cell resolves to a step kind:
  train_4k    -> train_step   (loss + grads + optimizer update)
  prefill_32k -> prefill_step (full-sequence logits)
  decode_32k  -> serve_step   (1 new token against a seq_len KV cache)
  long_500k   -> serve_step   (batch=1, 512k context; sub-quadratic archs only)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str

    @property
    def kind(self):
        return SHAPES[self.shape]["kind"]


def token_struct(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def batch_structs(bundle, shape_name: str, *, smoke_scale: int | None = None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns (batch_dict, cache_or_None).  No device allocation.
    """
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    if smoke_scale:
        b, s = max(b // smoke_scale, 2), max(s // smoke_scale, 16)
    kind = sh["kind"]
    cfg = bundle.cfg

    extras = {}
    if bundle.family == "encdec":
        d = cfg.d_model
        extras["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_len, d), jnp.bfloat16)
    if bundle.family == "vlm":
        extras["prefix"] = jax.ShapeDtypeStruct((b, 256, cfg.d_model), jnp.bfloat16)

    if kind == "train":
        batch = {"tokens": token_struct(b, s), "labels": token_struct(b, s), **extras}
        return batch, None
    if kind == "prefill":
        batch = {"tokens": token_struct(b, s), **extras}
        return batch, None
    # decode: one new token against an s-long cache/state
    batch = {
        "tokens": token_struct(b, 1),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    cache = jax.eval_shape(lambda: bundle.make_cache(b, s))
    return batch, cache
