"""Whisper-medium backbone — enc-dec, conv frontend stubbed
[arXiv:2212.04356].  Decode shapes run the decoder; long_500k skipped
(full attention)."""
from repro.models.registry import make_whisper_bundle
from repro.models.whisper import WhisperConfig

ARCH = "whisper-medium"


def full():
    cfg = WhisperConfig(
        name=ARCH,
        enc_layers=24,
        dec_layers=24,
        d_model=1024,
        n_heads=16,
        d_ff=4096,
        vocab=51865,
        enc_len=1500,
        max_dec_len=32768,
    )
    return make_whisper_bundle(cfg)


def smoke():
    cfg = WhisperConfig(
        name=ARCH + "-smoke",
        enc_layers=2,
        dec_layers=2,
        d_model=64,
        n_heads=4,
        d_ff=128,
        vocab=256,
        enc_len=12,
        max_dec_len=64,
    )
    return make_whisper_bundle(cfg)
