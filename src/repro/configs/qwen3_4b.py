"""Qwen3-4B — GQA + per-head qk-norm [hf:Qwen/Qwen3-4B family]."""
from repro.models.registry import make_lm_bundle
from repro.models.transformer import LMConfig

ARCH = "qwen3-4b"


def full():
    cfg = LMConfig(
        name=ARCH,
        layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab=151936,
        qk_norm=True,
        tie_embeddings=True,
        rope_base=1000000.0,
        max_seq=32768,
    )
    return make_lm_bundle(cfg)


def smoke():
    cfg = LMConfig(
        name=ARCH + "-smoke",
        layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        qk_norm=True,
        max_seq=128,
    )
    return make_lm_bundle(cfg)
