"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from importlib import import_module

_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "smollm-135m": "smollm_135m",
    "gemma2-9b": "gemma2_9b",
    "qwen3-4b": "qwen3_4b",
    "hymba-1.5b": "hymba_1p5b",
    "whisper-medium": "whisper_medium",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "paligemma-3b": "paligemma_3b",
}

ARCH_IDS = list(_MODULES)


def get_bundle(arch: str, *, smoke: bool = False, **kw):
    mod = import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke() if smoke else mod.full(**kw) if kw else mod.full()
