"""CodeQwen1.5-7B — dense MHA (kv == heads) [hf:Qwen/CodeQwen1.5-7B]."""
from repro.models.registry import make_lm_bundle
from repro.models.transformer import LMConfig

ARCH = "codeqwen1.5-7b"


def full():
    cfg = LMConfig(
        name=ARCH,
        layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        head_dim=128,
        d_ff=13440,
        vocab=92416,
        tie_embeddings=False,
        rope_base=1000000.0,
        max_seq=65536,
    )
    return make_lm_bundle(cfg)


def smoke():
    cfg = LMConfig(
        name=ARCH + "-smoke",
        layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        tie_embeddings=False,
        max_seq=128,
    )
    return make_lm_bundle(cfg)
