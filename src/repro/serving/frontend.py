"""Stdlib JSON/HTTP front-end for the multi-model ``CodedServer``.

An HTTP server with a BOUNDED handler pool (no third-party deps) in front
of the engine:

  * ``POST /v1/infer``  — body ``{"model": "...", "input": [[[...]]]}``
    (a nested-list ``(C, H, W)`` tensor; ``model`` optional while a single
    model is registered).  The handler submits to the engine and awaits the
    result on the scheduler's ONE shared completion condition
    (``CodedServer.wait_many``: timeout-sliced waits, no thread parked per
    request Event), so HTTP concurrency maps onto engine concurrency —
    concurrent posts land in the same continuous batches.  A request whose
    result does not arrive within ``result_timeout_s`` answers **504**.
    Replies ``{"model", "request_id", "shape", "output", "latency_s"}``.
    Batched form: ``{"model": "...", "inputs": [t1, t2, ...]}`` submits
    every image in one round trip — all of them fan out to the engine
    *before* the handler waits, then ONE ``wait_many`` covers the whole
    list — and replies ``{"model", "count", "results": [...]}`` with one
    entry per input in order: the single-image payload on success, or
    ``{"error": "..."}`` for that item alone (one bad or timed-out image
    never fails its siblings; an engine that is down or draining is a
    request-level 503, same as the single form).
  * ``GET /v1/models``  — registered models with input shape/dtype, layer
    count and bucket sizes.
  * ``GET /v1/stats``   — aggregate + per-model ``ServingStats``.

Connections are served by ``handler_pool`` pooled threads
(``_PooledHTTPServer``) instead of one spawned thread per connection, so a
burst of slow requests queues at the accept loop instead of growing an
unbounded thread count.

``ServingFrontend`` owns the socket lifecycle: ``start()`` binds (an
ephemeral port when ``port=0``) and serves from a background thread;
``shutdown()`` drains gracefully — stop accepting, join the handler pool
(every accepted request answered), then drain the engine itself (when the
front-end owns it).  Wired into ``launch/serve.py`` via ``--http-port``.
"""
from __future__ import annotations

import json
import threading
from concurrent.futures import ThreadPoolExecutor
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from .engine import CodedServer

__all__ = ["ServingFrontend"]


def _stats_dict(stats) -> dict:
    d = {k: v for k, v in stats.__dict__.items()}
    # nan is not valid JSON; percentiles of an empty window become null
    return {k: (None if isinstance(v, float) and not np.isfinite(v) else v)
            for k, v in d.items()}


def _overlap_dict(ov) -> dict:
    # dataclass fields + the derived serial_s / overlap_efficiency
    d = {**ov.__dict__, "serial_s": ov.serial_s,
         "overlap_efficiency": ov.overlap_efficiency}
    return {k: (None if isinstance(v, float) and not np.isfinite(v) else v)
            for k, v in d.items()}


class _PooledHTTPServer(ThreadingHTTPServer):
    """``ThreadingHTTPServer`` serving connections from a BOUNDED pool.

    The stock mixin spawns one thread per accepted connection — under a
    burst of slow requests that grows without bound, and each thread parks
    on its own ``Request.done`` event.  Here ``process_request`` hands the
    connection to a fixed ``ThreadPoolExecutor`` instead: at most
    ``pool_size`` requests are in service, later accepts queue in the
    executor, and ``server_close`` joins the pool so graceful drain still
    answers every accepted request before the engine goes away."""

    def __init__(self, addr, handler, pool_size: int):
        if pool_size < 1:
            raise ValueError(f"pool_size must be >= 1, got {pool_size}")
        super().__init__(addr, handler)
        self._pool = ThreadPoolExecutor(
            max_workers=pool_size, thread_name_prefix="coded-http"
        )

    def process_request(self, request, client_address) -> None:
        # process_request_thread = finish_request + error handling +
        # shutdown_request, exactly what the per-connection thread ran
        self._pool.submit(self.process_request_thread, request,
                          client_address)

    def server_close(self) -> None:
        super().server_close()
        self._pool.shutdown(wait=True)


class _Handler(BaseHTTPRequestHandler):
    # set per-server via the factory in ServingFrontend
    server_version = "CodedServing/1.0"
    engine: CodedServer = None
    result_timeout_s: float = 120.0
    # socket read timeout: an idle client connection (opened, nothing sent)
    # must error out rather than pin a handler thread forever — shutdown()
    # joins every handler, so one stalled reader would hang the drain
    timeout = 30.0

    def log_message(self, *args) -> None:  # quiet: the engine has metrics
        pass

    # -- plumbing ----------------------------------------------------------
    def _reply(self, code: int, payload: dict) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, message: str) -> None:
        self._reply(code, {"error": message})

    # -- routes ------------------------------------------------------------
    def do_GET(self) -> None:
        if self.path == "/v1/models":
            models = []
            for name, state in self.engine.models.items():
                pipe = state.pipeline
                models.append({
                    "name": name,
                    "input_shape": list(pipe.input_shape),
                    "dtype": np.dtype(pipe.input_dtype).name,
                    "layers": len(pipe.specs),
                    "bucket_sizes": list(pipe.bucket_sizes or ()),
                })
            self._reply(200, {"models": models})
        elif self.path == "/v1/stats":
            agg = _stats_dict(self.engine.stats())
            agg["overlap"] = _overlap_dict(self.engine.overlap_stats())
            per_model = {}
            for m, s in self.engine.per_model_stats().items():
                per_model[m] = _stats_dict(s)
                per_model[m]["overlap"] = _overlap_dict(
                    self.engine.overlap_stats(m))
            self._reply(200, {"aggregate": agg, "per_model": per_model})
        else:
            self._error(404, f"no route {self.path!r}")

    def do_POST(self) -> None:
        if self.path != "/v1/infer":
            self._error(404, f"no route {self.path!r}")
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(length) or b"{}")
            if not isinstance(payload, dict):
                raise ValueError(f"body must be a JSON object, "
                                 f"got {type(payload).__name__}")
            if "inputs" in payload:
                if "input" in payload:
                    raise ValueError("pass either 'input' or 'inputs', not both")
                raw = payload["inputs"]
                if not isinstance(raw, list) or not raw:
                    raise ValueError("'inputs' must be a non-empty list of "
                                     "(C, H, W) tensors")
                batch = list(raw)
            else:
                batch = None
                x = np.asarray(payload["input"], dtype=np.float32)
        except (ValueError, TypeError, KeyError, json.JSONDecodeError) as err:
            self._error(400, f"bad request body: {err}")
            return
        model = payload.get("model")
        if not self.engine.models:
            self._error(503, "no model registered")
            return
        if model is not None and model not in self.engine.models:
            self._error(404, f"unknown model {model!r}; registered: "
                             f"{sorted(self.engine.models)}")
            return
        if model is None and len(self.engine.models) > 1:
            self._error(400, f"{len(self.engine.models)} models registered "
                             f"({sorted(self.engine.models)}); pass model=")
            return
        resolved = (model if model is not None
                    else self.engine.model_names()[0])
        if batch is None:
            try:
                handle = self.engine.submit(x, model)
            except ValueError as err:  # wrong shape / model field required
                self._error(400, str(err))
                return
            except RuntimeError as err:  # engine not running / draining
                self._error(503, str(err))
                return
            if not self.engine.wait_many([handle],
                                         timeout=self.result_timeout_s):
                # the request is NOT cancelled — the engine may still finish
                # it — but this handler's slot is released with a timeout
                self._error(504, f"request {handle.request_id} not done "
                                 f"after {self.result_timeout_s}s")
                return
            item = self._gather(handle)
            if "error" in item:
                self._error(503, item["error"])
                return
            self._reply(200, {"model": resolved, **item})
            return
        # batched: fan every image out BEFORE waiting on any result, so
        # the whole list rides the engine's continuous batches in one HTTP
        # round trip, then ONE shared-condition wait covers all of them;
        # per-ITEM problems (bad tensor, wrong shape, timeout) are reported
        # per item and never fail siblings, while engine-down is a
        # request-level condition and answers 503 like the single form
        handles = []
        for i, raw_x in enumerate(batch):
            try:
                xi = np.asarray(raw_x, dtype=np.float32)
                handles.append(self.engine.submit(xi, model))
            except (ValueError, TypeError) as err:  # bad tensor / shape
                handles.append(f"bad input [{i}]: {err}")
            except RuntimeError as err:  # engine not running / draining
                self._error(503, str(err))
                return
        self.engine.wait_many([h for h in handles if not isinstance(h, str)],
                              timeout=self.result_timeout_s)
        results = []
        for h in handles:
            if isinstance(h, str):
                results.append({"error": h})
            elif not h.done():
                results.append({"error": f"TimeoutError: request "
                                         f"{h.request_id} not done after "
                                         f"{self.result_timeout_s}s"})
            else:
                results.append(self._gather(h))
        self._reply(200, {
            "model": resolved,
            "count": len(results),
            "results": results,
        })

    def _gather(self, handle) -> dict:
        """The per-item reply payload for a handle ``wait_many`` already
        saw complete (``result`` returns without blocking)."""
        try:
            y = np.asarray(handle.result(timeout=0))
        except Exception as err:  # degraded cluster, engine shutdown, ...
            return {"error": f"{type(err).__name__}: {err}"}
        return {
            "request_id": handle.request_id,
            "shape": list(y.shape),
            "output": y.tolist(),
            "latency_s": handle.latency_s,
        }


class ServingFrontend:
    """HTTP front-end over a ``CodedServer``.

    ``manage_server=True`` ties the engine lifecycle to the front-end:
    ``start()`` starts the engine (unless already running) and
    ``shutdown()`` drains it after the HTTP side is quiesced.  With
    ``port=0`` the OS picks a free port — read ``.port`` after start.
    """

    def __init__(self, engine: CodedServer, *, host: str = "127.0.0.1",
                 port: int = 0, manage_server: bool = True,
                 result_timeout_s: float = 120.0, handler_pool: int = 8):
        self.engine = engine
        self.manage_server = manage_server
        handler = type("Handler", (_Handler,), {
            "engine": engine, "result_timeout_s": result_timeout_s,
        })
        # bounded pool instead of a thread per connection; server_close()
        # joins the pool, so graceful drain answers every accepted request
        # before the engine shuts down
        self.httpd = _PooledHTTPServer((host, port), handler, handler_pool)
        self._thread: threading.Thread | None = None

    @property
    def host(self) -> str:
        return self.httpd.server_address[0]

    @property
    def port(self) -> int:
        return self.httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ServingFrontend":
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        if self.manage_server and self.engine._thread is None:
            self.engine.start()
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, name="coded-serving-http",
            daemon=True,
        )
        self._thread.start()
        return self

    def shutdown(self) -> None:
        """Graceful drain: stop accepting, join the handler pool (each
        in-service request completes once the engine delivers — or times
        out to a 504), then drain the engine (when managed).  Idempotent."""
        thread, self._thread = self._thread, None
        if thread is not None:
            self.httpd.shutdown()       # stop the accept loop
            thread.join(30.0)
        # joins the bounded handler pool, so every accepted request gets
        # its response before the engine goes away
        self.httpd.server_close()
        if self.manage_server and self.engine._thread is not None:
            self.engine.shutdown(drain=True)

    def __enter__(self) -> "ServingFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()
