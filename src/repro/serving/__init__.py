"""Coded serving engine: continuous-batching inference over a resident
``CodedPipeline`` (scheduler + engine loop + per-request metrics)."""
from .engine import CodedServer
from .metrics import MetricsCollector, RequestRecord, ServingStats, percentile
from .scheduler import (
    Request,
    RequestHandle,
    RequestQueue,
    ScheduledBatch,
    Scheduler,
)

__all__ = [
    "CodedServer",
    "MetricsCollector",
    "RequestRecord",
    "ServingStats",
    "percentile",
    "Request",
    "RequestHandle",
    "RequestQueue",
    "ScheduledBatch",
    "Scheduler",
]
