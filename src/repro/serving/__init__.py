"""Coded serving engine: continuous-batching inference over resident
``CodedPipeline``s — multi-model scheduler + engine loop + per-request
metrics + stdlib HTTP front-end."""
from .engine import CodedServer
from .frontend import ServingFrontend
from .lm_engine import CodedLMServer, pack_request, unpack_request
from .metrics import (
    MetricsCollector,
    OverlapStats,
    RequestRecord,
    ServingStats,
    percentile,
)
from .scheduler import (
    MultiScheduler,
    Request,
    RequestHandle,
    RequestQueue,
    ScheduledBatch,
    Scheduler,
)

__all__ = [
    "CodedServer",
    "CodedLMServer",
    "pack_request",
    "unpack_request",
    "ServingFrontend",
    "MetricsCollector",
    "OverlapStats",
    "RequestRecord",
    "ServingStats",
    "percentile",
    "MultiScheduler",
    "Request",
    "RequestHandle",
    "RequestQueue",
    "ScheduledBatch",
    "Scheduler",
]
