"""Per-request serving metrics: latency breakdown, percentiles, throughput.

Every request that flows through the ``CodedServer`` leaves one
``RequestRecord`` (arrival -> batch start -> finish, tagged with its
model); ``MetricsCollector`` aggregates them into a ``ServingStats`` with
queue-wait / execute / end-to-end percentiles and images/s throughput —
the numbers ``benchmarks/exp6_serving.py`` compares against the
sequential ``run_pipeline`` baseline.  Multi-model servers get the same
stats *per model* (``stats(model=...)`` / ``per_model_stats()``) while
the aggregate view stays exactly the single-model one; equal-depth batch
merges are counted per model too (``count_coalesced``).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Sequence

import numpy as np

__all__ = ["OverlapStats", "RequestRecord", "ServingStats",
           "MetricsCollector", "percentile"]


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """Lifecycle timestamps of one served request (``time.perf_counter``)."""

    request_id: int
    arrival_t: float   # submit() called
    start_t: float     # its batch began executing layer 0
    finish_t: float    # result decoded and delivered
    bucket: int        # padded batch size the request rode in
    batch_real: int    # real (unpadded) requests in that batch
    model: str = ""    # model namespace the request was served under

    @property
    def queue_wait_s(self) -> float:
        return self.start_t - self.arrival_t

    @property
    def execute_s(self) -> float:
        return self.finish_t - self.start_t

    @property
    def e2e_s(self) -> float:
        return self.finish_t - self.arrival_t


def percentile(xs: Sequence[float], p: float) -> float:
    """Linear-interpolated percentile (p in [0, 100]); nan when empty."""
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, dtype=np.float64), p))


@dataclasses.dataclass(frozen=True)
class ServingStats:
    """Aggregate over a set of completed requests."""

    completed: int
    wall_s: float            # first arrival -> last finish
    images_per_s: float
    e2e_p50_s: float
    e2e_p95_s: float
    e2e_p99_s: float
    queue_wait_p50_s: float
    queue_wait_p95_s: float
    execute_p50_s: float
    execute_p95_s: float
    mean_batch_real: float   # average *real* occupancy of executed buckets
    coalesced: int = 0       # equal-depth batch merges behind these requests

    def summary_line(self) -> str:
        return (
            f"{self.completed} reqs in {self.wall_s:.3f}s "
            f"({self.images_per_s:.1f} img/s) "
            f"e2e p50/p95/p99 {self.e2e_p50_s*1e3:.1f}/"
            f"{self.e2e_p95_s*1e3:.1f}/{self.e2e_p99_s*1e3:.1f} ms "
            f"queue p50 {self.queue_wait_p50_s*1e3:.1f} ms "
            f"mean batch {self.mean_batch_real:.2f}"
        )


@dataclasses.dataclass(frozen=True)
class OverlapStats:
    """Per-phase round timings under pipelined serving.

    Each collected worker round contributes one (dispatch, worker, collect,
    transition) tuple; ``busy_wall_s`` is the engine's wall time with at
    least one round in flight.  ``overlap_efficiency`` is the observable
    form of the pipelining win: serial phase seconds per busy wall second —
    ~1.0 at depth 1 (phases ARE the wall), > 1.0 when master-side
    collect/transition of one batch overlapped another batch's worker
    compute."""

    rounds: int            # collected worker rounds
    dispatch_s: float      # sum: master-side encode + submit
    worker_s: float        # sum: dispatch -> delta-th result visible
    collect_s: float       # sum: reap + gather (decode excluded)
    transition_s: float    # sum: decode or fused transition
    busy_wall_s: float     # wall time with >= 1 round in flight
    max_depth: int         # deepest pipeline window actually reached

    @property
    def serial_s(self) -> float:
        """What the phases would cost executed back to back."""
        return (self.dispatch_s + self.worker_s + self.collect_s
                + self.transition_s)

    @property
    def overlap_efficiency(self) -> float:
        """serial_s / busy_wall_s (nan before any busy span closes)."""
        if self.busy_wall_s <= 0:
            return float("nan")
        return self.serial_s / self.busy_wall_s


class MetricsCollector:
    """Thread-safe sink for ``RequestRecord``s (the engine thread writes,
    callers read a snapshot).  Records are tagged per model; ``stats``
    with no argument is the aggregate over every model."""

    def __init__(self):
        self._lock = threading.Lock()
        self._records: list[RequestRecord] = []  # guarded-by: self._lock
        self._coalesced: dict[str, int] = {}  # guarded-by: self._lock
        # per-model round phase tuples (dispatch, worker, collect, transition)
        self._phases: dict[str, list[tuple]] = {}  # guarded-by: self._lock
        self._busy_wall_s: float = 0.0  # guarded-by: self._lock
        self._max_depth: int = 0  # guarded-by: self._lock

    def record(self, rec: RequestRecord) -> None:
        with self._lock:
            self._records.append(rec)

    def count_coalesced(self, model: str, merges: int = 1) -> None:
        """Account ``merges`` equal-depth batch merges to ``model``."""
        with self._lock:
            self._coalesced[model] = self._coalesced.get(model, 0) + merges

    def record_phases(self, model: str, *, dispatch_s: float, worker_s: float,
                      collect_s: float, transition_s: float) -> None:
        """One collected worker round's phase breakdown (engine thread)."""
        with self._lock:
            self._phases.setdefault(model, []).append(
                (dispatch_s, worker_s, collect_s, transition_s)
            )

    def note_busy(self, wall_s: float) -> None:
        """Close one busy span: ``wall_s`` seconds with >= 1 round in
        flight (the engine calls this when its window drains to empty)."""
        with self._lock:
            self._busy_wall_s += wall_s

    def note_depth(self, depth: int) -> None:
        """Track the deepest pipeline window observed."""
        with self._lock:
            if depth > self._max_depth:
                self._max_depth = depth

    def overlap_stats(self, model: str | None = None) -> OverlapStats:
        """Aggregate ``OverlapStats`` — all models, or one model's rounds
        (busy wall and max depth are engine-wide either way)."""
        with self._lock:
            if model is None:
                phases = [p for ps in self._phases.values() for p in ps]
            else:
                phases = list(self._phases.get(model, []))
            busy, depth = self._busy_wall_s, self._max_depth
        sums = [sum(p[k] for p in phases) for k in range(4)] \
            if phases else [0.0] * 4
        return OverlapStats(
            rounds=len(phases), dispatch_s=sums[0], worker_s=sums[1],
            collect_s=sums[2], transition_s=sums[3],
            busy_wall_s=busy, max_depth=depth,
        )

    def records(self, model: str | None = None) -> list[RequestRecord]:
        with self._lock:
            recs = list(self._records)
        if model is None:
            return recs
        return [r for r in recs if r.model == model]

    def models(self) -> list[str]:
        """Model names seen so far (served requests or counted merges)."""
        with self._lock:
            seen = {r.model for r in self._records} | set(self._coalesced)
        return sorted(seen)

    def reset(self) -> None:
        with self._lock:
            self._records.clear()
            self._coalesced.clear()
            self._phases.clear()
            self._busy_wall_s = 0.0
            self._max_depth = 0

    def coalesced(self, model: str | None = None) -> int:
        with self._lock:
            if model is None:
                return sum(self._coalesced.values())
            return self._coalesced.get(model, 0)

    def stats(self, model: str | None = None) -> ServingStats:
        """Aggregate stats — over every model (``model=None``, the
        single-model view exp6 prints) or one model's requests only."""
        recs = self.records(model)
        merges = self.coalesced(model)
        if not recs:
            return ServingStats(0, 0.0, 0.0, *([float("nan")] * 7), 0.0,
                                coalesced=merges)
        e2e = [r.e2e_s for r in recs]
        qw = [r.queue_wait_s for r in recs]
        ex = [r.execute_s for r in recs]
        wall = max(r.finish_t for r in recs) - min(r.arrival_t for r in recs)
        return ServingStats(
            completed=len(recs),
            wall_s=wall,
            images_per_s=len(recs) / wall if wall > 0 else float("inf"),
            e2e_p50_s=percentile(e2e, 50),
            e2e_p95_s=percentile(e2e, 95),
            e2e_p99_s=percentile(e2e, 99),
            queue_wait_p50_s=percentile(qw, 50),
            queue_wait_p95_s=percentile(qw, 95),
            execute_p50_s=percentile(ex, 50),
            execute_p95_s=percentile(ex, 95),
            mean_batch_real=float(np.mean([r.batch_real for r in recs])),
            coalesced=merges,
        )

    def per_model_stats(self) -> dict[str, "ServingStats"]:
        """One ``ServingStats`` per model seen (aggregate view unchanged)."""
        return {m: self.stats(m) for m in self.models()}
