"""Continuous-batching scheduler for coded CNN inference requests.

The sglang-style serving decomposition, adapted from token iterations to
ConvL iterations: a thread-safe ``RequestQueue`` admits single-image
requests, and the ``Scheduler`` assembles them into bucketed
``ScheduledBatch``es and decides which in-flight batch advances by one
layer next.

Two properties make this *continuous* rather than static batching:

  * late arrivals are admitted at every **layer boundary** — the engine
    asks the scheduler for work between layers, so a request that shows up
    while batch A is on conv3 starts as batch B at conv1 immediately
    instead of waiting for A to drain;
  * batch sizes are **bucketed** (padded up to the pipeline's
    ``bucket_sizes``), so jit compiles one program per (layer, bucket) —
    a bounded set — never one per observed batch size.

Scheduling policy is deepest-layer-first: finishing an almost-done batch
frees its requests (latency) before opening a new front (throughput);
ties break FIFO.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Callable

import jax.numpy as jnp

__all__ = ["Request", "RequestHandle", "RequestQueue", "ScheduledBatch",
           "Scheduler"]


@dataclasses.dataclass
class Request:
    """One in-flight inference request for a single ``(C, H, W)`` image."""

    request_id: int
    x: jnp.ndarray
    arrival_t: float
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: object = None
    error: BaseException | None = None
    start_t: float = float("nan")   # set when its batch starts layer 0
    finish_t: float = float("nan")
    _finish_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False
    )

    def finish(self, result=None, error: BaseException | None = None) -> None:
        """First writer wins: the engine thread and a shutdown-timeout
        ``cancel_all`` may race here, and a result delivered just before
        the cancellation must never be overwritten by it (nor vice versa)."""
        with self._finish_lock:
            if self.done.is_set():
                return
            self.result = result
            self.error = error
            self.finish_t = time.perf_counter()
            self.done.set()


class RequestHandle:
    """Caller-side future for a submitted request."""

    def __init__(self, request: Request):
        self._request = request

    @property
    def request_id(self) -> int:
        return self._request.request_id

    def done(self) -> bool:
        return self._request.done.is_set()

    def result(self, timeout: float | None = 60.0):
        """Block until the request completes; raises its error (e.g. a
        ``ClusterDegraded``) or ``TimeoutError``.  The default timeout is a
        fail-fast guard — a wedged scheduler thread surfaces here instead
        of hanging the caller forever."""
        if not self._request.done.wait(timeout):
            raise TimeoutError(
                f"request {self._request.request_id} not done after {timeout}s"
            )
        if self._request.error is not None:
            raise self._request.error
        return self._request.result

    @property
    def latency_s(self) -> float:
        """End-to-end seconds (nan until done)."""
        return self._request.finish_t - self._request.arrival_t


class RequestQueue:
    """Thread-safe FIFO with a condition the engine loop can wait on."""

    def __init__(self):
        # reentrant: the engine holds the condition while checking len()
        self._lock = threading.RLock()
        self.not_empty = threading.Condition(self._lock)
        self._queue: list[Request] = []
        self._ids = itertools.count()

    def submit(self, x: jnp.ndarray) -> RequestHandle:
        req = Request(next(self._ids), x, time.perf_counter())
        with self.not_empty:
            self._queue.append(req)
            self.not_empty.notify_all()
        return RequestHandle(req)

    def pop_up_to(self, k: int) -> list[Request]:
        with self._lock:
            taken, self._queue = self._queue[:k], self._queue[k:]
            return taken

    def drain(self) -> list[Request]:
        with self._lock:
            taken, self._queue = self._queue, []
            return taken

    def __len__(self) -> int:
        with self._lock:
            return len(self._queue)


@dataclasses.dataclass
class ScheduledBatch:
    """A bucketed group of requests advancing through the ConvL stack
    together.  ``x`` is the current activation, ``(bucket, C, H, W)``;
    rows past ``len(requests)`` are zero padding."""

    requests: list[Request]
    x: jnp.ndarray
    bucket: int
    layer_idx: int = 0
    timings: list = dataclasses.field(default_factory=list)

    @property
    def real(self) -> int:
        return len(self.requests)


class Scheduler:
    """Queue + in-flight set + assembly/advance policy.

    ``pad_to_bucket`` comes from the pipeline so the padded batch sizes
    match the jit program buckets exactly.  The engine loop drives it:
    ``admit()`` at each layer boundary, then ``next_batch()`` to pick what
    advances.
    """

    def __init__(self, pad_to_bucket: Callable, *, max_batch: int,
                 max_inflight: int = 2):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.queue = RequestQueue()
        self.inflight: list[ScheduledBatch] = []
        # guards ``inflight``: normally only the engine thread mutates it,
        # but a shutdown whose join timed out calls ``cancel_all`` from the
        # caller thread while the engine may still be running
        self._lock = threading.Lock()
        self.pad_to_bucket = pad_to_bucket
        self.max_batch = max_batch
        self.max_inflight = max_inflight

    def submit(self, x: jnp.ndarray) -> RequestHandle:
        return self.queue.submit(x)

    def has_work(self) -> bool:
        with self._lock:
            inflight = bool(self.inflight)
        return inflight or len(self.queue) > 0

    def admit(self) -> ScheduledBatch | None:
        """Assemble waiting requests into one new bucketed batch (layer 0)
        if capacity allows.  Called at every layer boundary — this is the
        continuous-batching admission point."""
        with self._lock:
            if len(self.inflight) >= self.max_inflight:
                return None
        reqs = self.queue.pop_up_to(self.max_batch)
        if not reqs:
            return None
        x = jnp.stack([r.x for r in reqs], axis=0)
        x, real = self.pad_to_bucket(x)
        assert real == len(reqs)
        batch = ScheduledBatch(reqs, x, bucket=int(x.shape[0]))
        now = time.perf_counter()
        for r in reqs:
            r.start_t = now
        with self._lock:
            self.inflight.append(batch)
        return batch

    def next_batch(self) -> ScheduledBatch | None:
        """Deepest-layer-first (FIFO among ties): drain nearly-finished
        batches before starting fresh ones."""
        with self._lock:
            if not self.inflight:
                return None
            return max(self.inflight, key=lambda b: b.layer_idx)

    def retire(self, batch: ScheduledBatch) -> None:
        with self._lock:
            if batch in self.inflight:  # may already be gone: a shutdown
                self.inflight.remove(batch)  # timeout cancel_all'ed it

    def cancel_all(self, error: BaseException) -> int:
        """Fail every queued and in-flight request (engine shutdown without
        drain, or a shutdown whose engine join timed out).  Returns the
        number of requests cancelled.  ``Request.finish`` is first-writer-
        wins, so racing the still-running engine can't clobber a result it
        delivered concurrently."""
        with self._lock:
            batches, self.inflight = self.inflight, []
        cancelled = 0
        for req in self.queue.drain():
            req.finish(error=error)
            cancelled += 1
        for batch in batches:
            for req in batch.requests:
                req.finish(error=error)
                cancelled += 1
        return cancelled
