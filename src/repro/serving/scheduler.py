"""Continuous-batching scheduler for coded CNN inference requests.

The sglang-style serving decomposition, adapted from token iterations to
ConvL iterations: a thread-safe ``RequestQueue`` admits single-image
requests, and the ``Scheduler`` assembles them into bucketed
``ScheduledBatch``es and decides which in-flight batch advances by one
layer next.

Two properties make this *continuous* rather than static batching:

  * late arrivals are admitted at every **layer boundary** — the engine
    asks the scheduler for work between layers, so a request that shows up
    while batch A is on conv3 starts as batch B at conv1 immediately
    instead of waiting for A to drain;
  * batch sizes are **bucketed** (padded up to the pipeline's
    ``bucket_sizes``), so jit compiles one program per (layer, bucket) —
    a bounded set — never one per observed batch size.

Scheduling policy is deepest-layer-first: finishing an almost-done batch
frees its requests (latency) before opening a new front (throughput);
ties break FIFO.

Multi-model serving stacks one ``Scheduler`` per registered model under a
``MultiScheduler``: each model keeps its own queue, buckets, and in-flight
set, and the engine's pick is fair-share — a rotating round-robin sweep
*across* the models with in-flight work (no model with pending work ever
waits more than one full sweep of the others, and idle periods build up
no deficit), then deepest-first *within* the chosen model.  Two in-flight batches of the
same model sitting at the same layer boundary are coalesced into one
bucketed batch when their combined real size fits (``coalesce``), so
bursty arrivals converge back to full buckets instead of draining as
fragments.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Callable

import jax.numpy as jnp

__all__ = ["Request", "RequestHandle", "RequestQueue", "ScheduledBatch",
           "Scheduler", "MultiScheduler"]


def _take_batch(x: jnp.ndarray, n: int, axis: int) -> jnp.ndarray:
    """The first ``n`` entries of ``x`` along ``axis`` (static slice)."""
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, n)
    return x[tuple(idx)]


@dataclasses.dataclass
class Request:
    """One in-flight inference request for a single ``(C, H, W)`` image."""

    request_id: int
    x: jnp.ndarray
    arrival_t: float
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: object = None  # guarded-by: self._finish_lock
    error: BaseException | None = None  # guarded-by: self._finish_lock
    # set when its batch is first *dispatched* to the workers (queue-wait
    # ends at dispatch, not collect — under round pipelining a batch can
    # sit dispatched while an older round collects)  # guarded-by: engine-thread
    start_t: float = float("nan")
    finish_t: float = float("nan")  # guarded-by: self._finish_lock
    _finish_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False
    )
    # shared completion condition (MultiScheduler.completion): notified on
    # every finish so bounded waiter pools (``CodedServer.wait_many``, the
    # HTTP front-end) can wait for many requests on ONE condition instead
    # of parking a thread per request.  None for standalone queues.
    completion: threading.Condition | None = dataclasses.field(
        default=None, repr=False
    )

    def finish(self, result=None, error: BaseException | None = None) -> None:
        """First writer wins: the engine thread and a shutdown-timeout
        ``cancel_all`` may race here, and a result delivered just before
        the cancellation must never be overwritten by it (nor vice versa)."""
        with self._finish_lock:
            if self.done.is_set():
                return
            self.result = result
            self.error = error
            self.finish_t = time.perf_counter()
            self.done.set()
        # outside _finish_lock: waiters re-check handle.done() themselves,
        # and nesting the condition under the finish lock would order them
        completion = self.completion
        if completion is not None:
            with completion:
                completion.notify_all()


class RequestHandle:
    """Caller-side future for a submitted request."""

    def __init__(self, request: Request):
        self._request = request

    @property
    def request_id(self) -> int:
        return self._request.request_id

    def done(self) -> bool:
        return self._request.done.is_set()

    def result(self, timeout: float | None = 60.0):
        """Block until the request completes; raises its error (e.g. a
        ``ClusterDegraded``) or ``TimeoutError``.  The default timeout is a
        fail-fast guard — a wedged scheduler thread surfaces here instead
        of hanging the caller forever."""
        if not self._request.done.wait(timeout):
            raise TimeoutError(
                f"request {self._request.request_id} not done after {timeout}s"
            )
        if self._request.error is not None:
            raise self._request.error
        return self._request.result

    @property
    def latency_s(self) -> float:
        """End-to-end seconds (nan until done)."""
        return self._request.finish_t - self._request.arrival_t


class RequestQueue:
    """Thread-safe FIFO with a condition the engine loop can wait on.

    ``not_empty``/``ids`` may be shared across queues: a ``MultiScheduler``
    hands every model queue the same condition (one engine wait covers all
    models) and the same id counter (request ids stay unique server-wide).
    """

    def __init__(self, not_empty: threading.Condition | None = None,
                 ids=None, completion: threading.Condition | None = None):
        # reentrant: the engine holds the condition while checking len()
        self.not_empty = (threading.Condition(threading.RLock())
                          if not_empty is None else not_empty)
        self._lock = self.not_empty
        self._queue: list[Request] = []  # guarded-by: self._lock
        self._ids = itertools.count() if ids is None else ids
        # handed to every Request: notified when it finishes (see Request)
        self._completion = completion

    def submit(self, x: jnp.ndarray) -> RequestHandle:
        req = Request(next(self._ids), x, time.perf_counter(),
                      completion=self._completion)
        with self.not_empty:
            self._queue.append(req)
            self.not_empty.notify_all()
        return RequestHandle(req)

    def pop_up_to(self, k: int) -> list[Request]:
        with self._lock:
            taken, self._queue = self._queue[:k], self._queue[k:]
            return taken

    def drain(self) -> list[Request]:
        with self._lock:
            taken, self._queue = self._queue, []
            return taken

    def __len__(self) -> int:
        with self._lock:
            return len(self._queue)


@dataclasses.dataclass
class ScheduledBatch:
    """A bucketed group of requests advancing through the ConvL stack
    together.  ``x`` is the current activation — ``(bucket, C, H, W)`` on
    the round-trip path, or (partition-resident serving, mid-stack) the
    coded input shares ``(n, ell_a, bucket, C, h_hat, Wp)`` with the batch
    on ``batch_axis``; entries past ``len(requests)`` along that axis are
    zero padding."""

    requests: list[Request]
    x: jnp.ndarray
    bucket: int
    layer_idx: int = 0
    model: str = ""
    timings: list = dataclasses.field(default_factory=list)
    # which axis of ``x`` is the request batch: 0 for raw/merged tensors,
    # 2 while carrying partition-resident coded shares between layers
    batch_axis: int = 0
    # True while a worker round for this batch is in flight (dispatched but
    # not collected): such a batch must not be picked again or coalesced —
    # its ``x`` is stale until the round lands.  The engine thread flips it
    # around dispatch/collect.  # guarded-by: engine-thread
    dispatched: bool = False

    @property
    def real(self) -> int:
        return len(self.requests)


class Scheduler:
    """Queue + in-flight set + assembly/advance policy for ONE model.

    ``pad_to_bucket`` comes from the model's pipeline so the padded batch
    sizes match its jit program buckets exactly.  The engine loop drives
    it: ``admit()`` at each layer boundary, ``coalesce()`` to re-pack
    equal-depth fragments, then ``next_batch()`` to pick what advances.
    """

    def __init__(self, pad_to_bucket: Callable, *, max_batch: int,
                 max_inflight: int = 2, name: str = "",
                 queue: RequestQueue | None = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.name = name
        self.queue = queue if queue is not None else RequestQueue()
        self.inflight: list[ScheduledBatch] = []  # guarded-by: self._lock
        # guards ``inflight``: normally only the engine thread mutates it,
        # but a shutdown whose join timed out calls ``cancel_all`` from the
        # caller thread while the engine may still be running
        self._lock = threading.Lock()
        self.pad_to_bucket = pad_to_bucket
        self.max_batch = max_batch
        self.max_inflight = max_inflight
        # two-phase deregistration fencing (``CodedServer.unregister_model``):
        # ``closed`` rejects NEW submits while queued + in-flight work
        # drains; ``fenced`` additionally stops admission/coalescing — after
        # the fence the model's ``pad_to_bucket``/bucket bindings are never
        # consulted again, so the pipeline behind them can be torn down.
        # Writes go through ``_lock`` so a close/fence from the caller
        # thread is a proper release/acquire edge against the engine's
        # reads (a plain unfenced bool write has no ordering guarantee).
        self.closed = False  # guarded-by: self._lock
        self.fenced = False  # guarded-by: self._lock

    def close(self) -> None:
        """Phase 1 of removal: reject new submits, keep serving what's in."""
        with self._lock:
            self.closed = True

    def fence(self) -> None:
        """Phase 2 of removal: stop consulting this model's bucket bindings
        entirely (implies ``close``).  Idempotent."""
        with self._lock:
            self.closed = True
            self.fenced = True

    def submit(self, x: jnp.ndarray) -> RequestHandle:
        if self.closed:
            raise RuntimeError(
                f"model {self.name!r} is being unregistered; no new requests"
            )
        return self.queue.submit(x)

    def has_work(self) -> bool:
        with self._lock:
            inflight = bool(self.inflight)
        return inflight or len(self.queue) > 0

    def admit(self, limit: int | None = None) -> ScheduledBatch | None:
        """Assemble waiting requests into one new bucketed batch (layer 0)
        if capacity allows.  Called at every layer boundary — this is the
        continuous-batching admission point.  ``limit`` caps the batch
        below ``max_batch`` (tests use it to force fragmented batches)."""
        if self.fenced:  # mid-removal: bucket bindings must not be consulted
            return None
        with self._lock:
            if len(self.inflight) >= self.max_inflight:
                return None
        take = self.max_batch if limit is None else min(limit, self.max_batch)
        reqs = self.queue.pop_up_to(take)
        if not reqs:
            return None
        x = jnp.stack([r.x for r in reqs], axis=0)
        x, real = self.pad_to_bucket(x)
        assert real == len(reqs)
        batch = ScheduledBatch(reqs, x, bucket=int(x.shape[0]),
                               model=self.name)
        # start_t is NOT stamped here: queue-wait ends at the batch's first
        # *dispatch* (the engine stamps it), so admitted-but-waiting time —
        # e.g. behind a full pipeline window — still counts as queueing
        with self._lock:
            self.inflight.append(batch)
        return batch

    def can_admit(self) -> bool:
        """Non-mutating: would ``admit()`` assemble a batch right now?"""
        if self.fenced:
            return False
        with self._lock:
            if len(self.inflight) >= self.max_inflight:
                return False
        return len(self.queue) > 0

    def has_undispatched(self) -> bool:
        """Any in-flight batch waiting at a boundary (not mid-round)?"""
        with self._lock:
            return any(not b.dispatched for b in self.inflight)

    def coalesce(self) -> int:
        """Merge in-flight batches sitting at the SAME layer boundary into
        one bucketed batch while the combined real size fits ``max_batch``.

        Rows are independent through every coded layer (the batch axis
        rides inside each worker's subtask), so a merged batch decodes to
        exactly the per-batch results — this only trades fragments for one
        fuller bucket (fewer master/worker rounds).  Fragments arise from
        admission racing arrivals, and — under multi-model fair share —
        from a model's batches waiting at a boundary while another model
        advances.  Partition-resident batches merge the same way, just on
        their coded-share batch axis (equal depth implies equal state
        layout; zero padding encodes to zero shares).  Returns the number
        of merges performed (the engine accounts them into
        ``MetricsCollector`` — the single counter)."""
        if self.fenced:  # pad_to_bucket is off-limits mid-removal
            return 0
        merges = 0
        with self._lock:
            by_depth: dict[int, list[ScheduledBatch]] = {}
            for b in self.inflight:
                if b.dispatched:
                    # mid-round: its ``x`` is stale until the collect lands,
                    # so only same-boundary batches NOT in flight merge
                    continue
                by_depth.setdefault(b.layer_idx, []).append(b)
            for group in by_depth.values():
                group.sort(key=lambda b: b.real)
                while len(group) > 1:
                    a, b = group[0], group[1]
                    if a.real + b.real > self.max_batch:
                        break
                    ax = a.batch_axis
                    assert ax == b.batch_axis, (ax, b.batch_axis)
                    x = jnp.concatenate(
                        [_take_batch(a.x, a.real, ax),
                         _take_batch(b.x, b.real, ax)], axis=ax
                    )
                    # pass axis only off the default: pad_to_bucket may be a
                    # plain (x) -> (padded, real) callable without an axis
                    # parameter (only CodedPipeline's method accepts one,
                    # and only partition-resident batches need it)
                    x, real = (self.pad_to_bucket(x) if ax == 0
                               else self.pad_to_bucket(x, axis=ax))
                    a.requests.extend(b.requests)
                    a.x, a.bucket = x, int(x.shape[ax])
                    # a's timings describe the merged batch's past; b's are
                    # dropped with b (only per-request metrics survive)
                    self.inflight.remove(b)
                    group.pop(1)
                    group.sort(key=lambda b: b.real)
                    merges += 1
        return merges

    def next_batch(self) -> ScheduledBatch | None:
        """Deepest-layer-first (FIFO among ties): drain nearly-finished
        batches before starting fresh ones.  Batches with a round already
        in flight are skipped — they advance when their collect lands, not
        by being picked again."""
        with self._lock:
            ready = [b for b in self.inflight if not b.dispatched]
            if not ready:
                return None
            return max(ready, key=lambda b: b.layer_idx)

    def retire(self, batch: ScheduledBatch) -> None:
        with self._lock:
            if batch in self.inflight:  # may already be gone: a shutdown
                self.inflight.remove(batch)  # timeout cancel_all'ed it

    def cancel_all(self, error: BaseException) -> int:
        """Fail every queued and in-flight request (engine shutdown without
        drain, or a shutdown whose engine join timed out).  Returns the
        number of requests cancelled.  ``Request.finish`` is first-writer-
        wins, so racing the still-running engine can't clobber a result it
        delivered concurrently."""
        with self._lock:
            batches, self.inflight = self.inflight, []
        cancelled = 0
        for req in self.queue.drain():
            req.finish(error=error)
            cancelled += 1
        for batch in batches:
            for req in batch.requests:
                req.finish(error=error)
                cancelled += 1
        return cancelled


class MultiScheduler:
    """Per-model ``Scheduler``s under one fair-share policy.

    Every model registered with ``add_model`` gets its own queue (sharing
    ONE condition and id counter, so a submit to any model wakes the one
    engine loop and request ids stay unique server-wide), its own buckets,
    and its own in-flight capacity.  The engine drives:

      * ``admit()``   — one new batch from some model with queued work and
        free capacity, rotating so no model's queue monopolizes admission;
      * ``coalesce()``— equal-depth merges inside every model;
      * ``next_batch()`` — the fair-share pick: a rotating sweep over the
        models, granting up to ``weight`` consecutive layer rounds to the
        next model with in-flight work (idle models are skipped without
        losing their turn's place).  A model with work is never more than
        the sum of the *other* models' weights rounds away from its next
        round — with unit weights, one full sweep — and the bound is
        positional, NOT a least-served count, so a model that idles while
        another serves builds up no deficit it could later monopolize the
        engine with.  Within the chosen model the pick stays deepest-first.
    """

    def __init__(self):
        self.not_empty = threading.Condition(threading.RLock())
        # notified (by the finishing thread) whenever ANY request of any
        # model completes: one condition serves every result waiter
        # (``CodedServer.wait_many``, the HTTP front-end's bounded pool)
        self.completion = threading.Condition()
        self._ids = itertools.count()
        self.schedulers: dict[str, Scheduler] = {}  # guarded-by: self.not_empty
        # integer fair-share weights: a model gets up to ``weight``
        # consecutive rounds per sweep position
        self.weights: dict[str, int] = {}  # guarded-by: self.not_empty
        # accounting only (stats/tests): layer-rounds granted per model
        self.served_rounds: dict[str, int] = {}  # guarded-by: self.not_empty
        # sweep cursors: only the engine thread advances these
        self._admit_rr = 0  # guarded-by: engine-thread
        self._pick_rr = 0  # guarded-by: engine-thread
        # rounds granted at the current sweep position
        self._pick_credit = 0  # guarded-by: engine-thread

    def add_model(self, name: str, pad_to_bucket: Callable, *,
                  max_batch: int, max_inflight: int = 2,
                  weight: int = 1) -> Scheduler:
        if not isinstance(weight, int) or weight < 1:
            raise ValueError(f"weight must be an integer >= 1, got {weight!r}")
        sched = Scheduler(
            pad_to_bucket, max_batch=max_batch, max_inflight=max_inflight,
            name=name,
            queue=RequestQueue(self.not_empty, self._ids, self.completion),
        )
        # registry mutations serialize on ``not_empty``: the engine may be
        # registering/removing a model live while its loop snapshots names
        with self.not_empty:
            if name in self.schedulers:
                raise ValueError(f"model {name!r} already registered")
            self.schedulers[name] = sched
            self.weights[name] = weight
            self.served_rounds[name] = 0
        return sched

    def remove_model(self, name: str) -> Scheduler:
        """Drop model ``name`` from the registry (its scheduler should
        already be fenced and drained/cancelled — this only unlinks it).
        The rotating sweep positions are plain indices modulo the live name
        list, re-snapshotted every call, so no re-indexing is needed."""
        with self.not_empty:
            sched = self.schedulers.pop(name)
            self.weights.pop(name, None)
            self.served_rounds.pop(name, None)
        return sched

    def fence(self, name: str) -> Scheduler:
        """Fence one model mid-removal: its ``pad_to_bucket``/bucket
        bindings are never consulted again (submit/admit/coalesce all
        refuse) while the registry entry stays visible for draining."""
        sched = self.schedulers[name]
        sched.fence()
        return sched

    def _snapshot(self) -> list[str]:
        with self.not_empty:
            return list(self.schedulers)

    def __getitem__(self, name: str) -> Scheduler:
        return self.schedulers[name]

    def submit(self, model: str, x: jnp.ndarray) -> RequestHandle:
        return self.schedulers[model].submit(x)

    def has_work(self) -> bool:
        return any(s.has_work() for s in list(self.schedulers.values()))

    def queued(self) -> int:
        return sum(len(s.queue) for s in list(self.schedulers.values()))

    def dispatchable(self) -> bool:
        """Is there work the engine could dispatch *right now* — a queued
        request that would admit, or an in-flight batch waiting at a
        boundary?  The reaper polls this to abandon its wait when a free
        pipeline-window slot could be filled instead."""
        return any(s.can_admit() or s.has_undispatched()
                   for s in list(self.schedulers.values()))

    def admit(self) -> ScheduledBatch | None:
        """Admit one new batch from the next model (rotating) that has both
        queued requests and free in-flight capacity.  The engine loops this
        until it returns None — all models' capacity fills at one boundary.
        The name list is a lock-guarded snapshot: a model registered or
        removed concurrently is simply missed/skipped this boundary."""
        names = self._snapshot()
        for off in range(len(names)):
            name = names[(self._admit_rr + off) % len(names)]
            sched = self.schedulers.get(name)
            if sched is None:  # removed since the snapshot
                continue
            batch = sched.admit()
            if batch is not None:
                self._admit_rr = (self._admit_rr + off + 1) % len(names)
                return batch
        return None

    def coalesce(self) -> dict[str, int]:
        """Equal-depth merges per model (empty dict = nothing merged)."""
        out = {}
        for name in self._snapshot():
            sched = self.schedulers.get(name)
            merges = sched.coalesce() if sched is not None else 0
            if merges:
                out[name] = merges
        return out

    def next_batch(self) -> tuple[str, ScheduledBatch] | None:
        """Fair-share pick: the rotating weighted sweep (see class
        docstring), one served round accounted to the winner.  A model with
        ``weight=w`` is granted up to ``w`` consecutive rounds before the
        sweep position advances; skipping an idle model forfeits any credit
        it had at its position (positional bound, no banked deficit)."""
        names = self._snapshot()
        for off in range(len(names)):
            pos = (self._pick_rr + off) % len(names)
            name = names[pos]
            sched = self.schedulers.get(name)
            if sched is None:  # removed since the snapshot
                continue
            batch = sched.next_batch()
            if batch is not None:
                if off:  # swept past idle models: restart credit here
                    self._pick_rr, self._pick_credit = pos, 0
                self._pick_credit += 1
                if self._pick_credit >= self.weights.get(name, 1):
                    self._pick_rr = (pos + 1) % len(names)
                    self._pick_credit = 0
                # under the condition: ``remove_model`` may pop the entry
                # from another thread between the membership check and the
                # increment, resurrecting the key with a stale count
                with self.not_empty:
                    if name in self.served_rounds:
                        self.served_rounds[name] += 1
                return name, batch
        return None

    def retire(self, model: str, batch: ScheduledBatch) -> None:
        sched = self.schedulers.get(model)
        if sched is not None:  # may have been unregistered mid-flight
            sched.retire(batch)

    def cancel_all(self, error: BaseException) -> int:
        return sum(s.cancel_all(error) for s in list(self.schedulers.values()))
