"""``CodedLMServer``: continuous-batching LM *token* serving over a
resident ``CodedDecoderPipeline``.

The CNN server (``engine.py``) admits late arrivals at ConvL boundaries;
an LM decode loop has a finer natural boundary — the decode *step*.  This
engine keeps a fixed pool of request *slots* (rows of the pipeline's KV
slot caches).  Each iteration of the engine thread:

  1. **admit** — pops waiting prompts into free slots through the shared
     ``MultiScheduler`` (so admission fairness/bucketing/inflight caps are
     the same machinery CNN models use), runs ONE batched jitted prefill
     for the whole admitted group, scatters the filled K/V rows into the
     group's (contiguous) cache slots, and emits each row's first token
     from its own last-prompt-position logits;
  2. **step** — advances every active slot one token with a single coded
     decode step: ``4 x layers`` worker GEMM rounds dispatched through the
     cluster's ``dispatch_pipeline_layer``/``collect_pipeline_layer`` seam
     (fastest-delta gather; stragglers beyond gamma never waited on),
     batched at the slot-prefix bucket;
  3. **complete** — finished requests resolve their handles with the
     generated tokens; their slots are recycled by compacting the last
     active row down (slot state plus the K/V cache rows move together),
     so active slots always form a prefix and new admissions scatter
     contiguously.

Prompts are packed as fixed-width int32 rows (``pack_request``) so the
scheduler's stack/pad machinery applies unchanged.  Prompt rows padded
beyond their true length leave garbage K/V at positions >= plen — never
attended: the decode step at position p overwrites position p before the
causal mask first exposes it.

The server can own its ``FcdccCluster`` or *share* one (pass
``cluster=``): registered under its own model namespace, the LM's coded
GEMM rounds and a CNN pipeline's ConvL rounds run on the same persistent
worker pool — the paper's one-pool-many-models deployment extended across
model families.
"""
from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decoder_pipeline import CodedDecoderPipeline
from repro.runtime import ClusterDegraded, FcdccCluster, StragglerModel

from .scheduler import MultiScheduler, RequestHandle, ScheduledBatch

__all__ = ["CodedLMServer", "pack_request", "unpack_request"]


def pack_request(prompt, max_new_tokens: int, max_prompt: int) -> np.ndarray:
    """One request as a fixed-width int32 row ``[plen, gen, tokens...]`` —
    equal-width rows are what lets the scheduler stack and pad prompt
    batches exactly like image batches."""
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    if prompt.size < 1:
        raise ValueError("prompt must have at least one token")
    if prompt.size > max_prompt:
        raise ValueError(
            f"prompt length {prompt.size} exceeds max_prompt={max_prompt}"
        )
    if max_new_tokens < 1:
        raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
    row = np.zeros(2 + max_prompt, np.int32)
    row[0] = prompt.size
    row[1] = max_new_tokens
    row[2:2 + prompt.size] = prompt
    return row


def unpack_request(row: np.ndarray) -> tuple[np.ndarray, int]:
    """Inverse of ``pack_request``: (prompt tokens, max_new_tokens)."""
    row = np.asarray(row)
    plen, gen = int(row[0]), int(row[1])
    return row[2:2 + plen].astype(np.int32), gen


class _Slot:
    """Engine-private per-request decode state riding one KV cache row.
    Only the engine thread creates, advances, and recycles these.
    # guarded-by: engine-thread"""

    __slots__ = ("req", "batch", "remaining", "tokens")

    def __init__(self, req, batch: ScheduledBatch, remaining: int,
                 first_token: int):
        self.req = req
        self.batch = batch
        self.remaining = remaining
        self.tokens = [first_token]


class CodedLMServer:
    """Continuous-batching greedy-decode server over one coded decoder
    pipeline.  ``submit()`` is thread-safe and returns a ``RequestHandle``
    whose ``result()`` is the generated token array.  Use as a context
    manager or ``start()``/``shutdown()``.

    ``execution="cluster"`` (default) runs every GEMM round through the
    master/worker runtime; ``execution="direct"`` runs the single-process
    vmapped path (optionally with ``worker_ids`` forcing a survivor
    subset) — no cluster, useful for tests and parity baselines.
    """

    def __init__(self, pipeline: CodedDecoderPipeline,
                 straggler: StragglerModel | None = None, *,
                 cluster: FcdccCluster | None = None,
                 scheduler: MultiScheduler | None = None,
                 mode: str = "simulated", execution: str = "cluster",
                 model: str = "lm", max_prompt: int = 16,
                 slots: int | None = None, max_inflight: int | None = None,
                 worker_ids=None, pool: str | None = None, devices=None,
                 poll_interval_s: float = 0.005):
        if execution not in ("cluster", "direct"):
            raise ValueError(f"unknown execution mode {execution!r}")
        if pipeline.bucket_sizes is None:
            raise ValueError("pipeline needs bucket_sizes for serving")
        if max_prompt < 1 or max_prompt >= pipeline.max_len:
            raise ValueError(
                f"need 1 <= max_prompt < max_len={pipeline.max_len}, "
                f"got {max_prompt}"
            )
        self.pipeline = pipeline
        self.model = model
        self.execution = execution
        self.max_prompt = int(max_prompt)
        self.slots = int(slots if slots is not None else pipeline.max_batch)
        if self.slots < pipeline.max_batch:
            raise ValueError(
                f"slots={self.slots} < largest bucket {pipeline.max_batch}"
            )
        self.worker_ids = worker_ids
        self.cluster = cluster
        self._owns_cluster = cluster is None and execution == "cluster"
        if execution == "cluster":
            if self.cluster is None:
                self.cluster = FcdccCluster(
                    pipeline.specs[0].plan, straggler, mode=mode,
                    backend=pipeline.backend, interpret=pipeline.interpret,
                    pool=pool if pool is not None else pipeline.pool,
                    devices=devices if devices is not None
                    else pipeline.devices,
                )
            self.cluster.load_pipeline(pipeline, model)
        self.scheduler = scheduler if scheduler is not None else MultiScheduler()
        self.scheduler.add_model(
            model, pipeline.pad_to_bucket, max_batch=pipeline.max_batch,
            max_inflight=(max_inflight if max_inflight is not None
                          else max(2, self.slots)),
        )
        self._poll_interval_s = poll_interval_s
        self._stop = threading.Event()
        self._drain = True  # guarded-by: control-thread
        self._thread: threading.Thread | None = None  # guarded-by: control-thread
        # token-throughput counters, written only by the engine thread and
        # read by stats() (plain int/float reads are atomic enough for
        # monitoring)  # guarded-by: engine-thread
        self.tokens_generated = 0
        self.decode_steps = 0
        self.decode_time_s = 0.0
        self.prefill_time_s = 0.0
        self.requests_served = 0

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "CodedLMServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._engine_loop, name="coded-lm-engine", daemon=True
        )
        self._thread.start()
        return self

    def shutdown(self, *, drain: bool = True, timeout: float = 120.0) -> None:
        """Stop the engine; ``drain=True`` finishes queued + in-flight
        requests first.  Idempotent."""
        self._drain = drain
        self._stop.set()
        thread = self._thread
        if thread is not None:
            with self.scheduler.not_empty:
                self.scheduler.not_empty.notify_all()
            thread.join(timeout)
            if thread.is_alive():
                err = TimeoutError(f"engine thread not done after {timeout}s")
                self.scheduler.cancel_all(err)
                raise err
            self._thread = None
            self.scheduler.cancel_all(RuntimeError("server shut down"))
        if self._owns_cluster and self.cluster is not None:
            self.cluster.shutdown()

    def __enter__(self) -> "CodedLMServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- request path --------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int) -> RequestHandle:
        """Enqueue one prompt (sequence of token ids) for greedy decoding
        of ``max_new_tokens`` tokens."""
        row = pack_request(prompt, max_new_tokens, self.max_prompt)
        if self._thread is None or self._stop.is_set():
            raise RuntimeError("server not running; call start()")
        return self.scheduler.submit(self.model, jnp.asarray(row))

    def generate(self, prompt, max_new_tokens: int,
                 timeout: float = 120.0) -> np.ndarray:
        return self.submit(prompt, max_new_tokens).result(timeout=timeout)

    def tokens_per_second(self) -> float:
        busy = self.decode_time_s + self.prefill_time_s
        return self.tokens_generated / busy if busy > 0 else 0.0

    # -- engine loop ---------------------------------------------------------
    def _engine_loop(self) -> None:
        pipe = self.pipeline
        sched = self.scheduler[self.model]
        cache = pipe.init_slot_cache(self.slots)
        # host-side per-slot decode state; active slots are ALWAYS the
        # prefix [0, len(slots_live)) — compaction maintains the invariant
        slots_live: list[_Slot] = []  # guarded-by: engine-thread
        last_tok = np.zeros(self.slots, np.int32)  # guarded-by: engine-thread
        pos = np.zeros(self.slots, np.int32)  # guarded-by: engine-thread
        outstanding: dict[int, int] = {}  # id(batch) -> unfinished rows  # guarded-by: engine-thread

        def finish_slot(i: int, err: BaseException | None = None) -> None:
            slot = slots_live[i]
            if err is None:
                slot.req.finish(result=np.asarray(slot.tokens, np.int32))
                self.requests_served += 1
            else:
                slot.req.finish(error=err)
            key = id(slot.batch)
            outstanding[key] -= 1
            if outstanding[key] == 0:
                del outstanding[key]
                self.scheduler.retire(self.model, slot.batch)
            # compact: move the last active row into the freed slot so the
            # active region stays a prefix (cache rows travel with it)
            j = len(slots_live) - 1
            if i != j:
                slots_live[i] = slots_live[j]
                last_tok[i], pos[i] = last_tok[j], pos[j]
                for c in cache:
                    c["k"] = pipe.slot_write(c["k"], pipe.slot_take(c["k"], j), i)
                    c["v"] = pipe.slot_write(c["v"], pipe.slot_take(c["v"], j), i)
            slots_live.pop()
            last_tok[j] = pos[j] = 0

        def fail_all(err: BaseException) -> None:
            for i in range(len(slots_live) - 1, -1, -1):
                finish_slot(i, err)

        while True:
            if self._stop.is_set() and (
                not self._drain or (not slots_live and not sched.has_work())
            ):
                break
            # -- admit into free slots (late admission per decode step) -----
            while len(slots_live) < self.slots:
                batch = sched.admit(limit=self.slots - len(slots_live))
                if batch is None:
                    break
                try:
                    self._admit(batch, cache, slots_live, last_tok, pos,
                                outstanding, finish_slot)
                except Exception as err:
                    for req in batch.requests:
                        req.finish(error=err)
                    self.scheduler.retire(self.model, batch)
            if not slots_live:
                if self._stop.is_set():
                    continue
                with self.scheduler.not_empty:
                    while (not self._stop.is_set() and not sched.can_admit()
                           and not sched.queue):
                        self.scheduler.not_empty.wait(self._poll_interval_s)
                continue
            # -- one decode step over the active slot prefix ----------------
            active = len(slots_live)
            b = pipe.bucketize(active)
            tokens = jnp.asarray(last_tok[:b])
            step_pos = jnp.asarray(pos[:b])
            t0 = time.perf_counter()
            try:
                if self.execution == "cluster":
                    logits, nxt, new_cache = pipe.run_decode_step_cluster(
                        self.cluster, tokens, cache, step_pos,
                        model=self.model,
                    )
                else:
                    logits, nxt, new_cache = pipe.run_decode_step_direct(
                        tokens, cache, step_pos, self.worker_ids
                    )
                nxt = np.asarray(jax.block_until_ready(nxt))
            except Exception as err:  # ClusterDegraded, kernel failure, ...
                # mid-step failure leaves cache/coded state inconsistent for
                # every rider: fail them all rather than serve wrong tokens
                fail_all(err)
                cache = pipe.init_slot_cache(self.slots)
                continue
            cache = new_cache
            self.decode_steps += 1
            self.decode_time_s += time.perf_counter() - t0
            self.tokens_generated += active
            # -- record tokens; retire finished requests (reverse order so
            # compaction swaps never disturb lower unprocessed slots) -------
            pos[:active] += 1
            last_tok[:active] = nxt[:active]
            for i in range(active):
                slot = slots_live[i]
                slot.tokens.append(int(nxt[i]))
                slot.remaining -= 1
            for i in range(active - 1, -1, -1):
                if slots_live[i].remaining == 0:
                    finish_slot(i)
        if not self._drain:
            self.scheduler.cancel_all(RuntimeError("server shut down"))

    def _admit(self, batch: ScheduledBatch, cache, slots_live, last_tok, pos,
               outstanding, finish_slot) -> None:
        """Prefill one admitted group and seat it in contiguous free slots.

        ONE jitted full-stack prefill serves the whole (bucket-padded)
        group; per-row first tokens come from each row's own last prompt
        position.  Rows are seated at ``[row0, row0 + real)`` — contiguous
        by the prefix invariant — so the K/V scatter is one dynamic-slice
        write per cache leaf."""
        pipe = self.pipeline
        rows = np.asarray(batch.x)
        real = batch.real
        t0 = time.perf_counter()
        prompts = jnp.asarray(rows[:, 2:2 + self.max_prompt])
        logits, ks, vs = pipe.prefill_prompt(prompts)
        row0 = len(slots_live)
        for c, lk, lv in zip(cache, ks, vs):
            c["k"] = pipe.slot_write(c["k"], lk[:real], row0)
            c["v"] = pipe.slot_write(c["v"], lv[:real], row0)
        plens = rows[:real, 0]
        first = np.asarray(jax.block_until_ready(jnp.argmax(
            logits[jnp.arange(real), jnp.asarray(plens) - 1], axis=-1
        ))).astype(np.int32)
        self.prefill_time_s += time.perf_counter() - t0
        self.tokens_generated += real
        outstanding[id(batch)] = real
        for r in range(real):
            slots_live.append(_Slot(batch.requests[r], batch,
                                    int(rows[r, 1]) - 1, int(first[r])))
            last_tok[row0 + r] = first[r]
            pos[row0 + r] = int(plens[r])
        # single-token requests are done at admission (prefill emitted
        # their one token); retire top-down so compaction stays safe
        for i in range(len(slots_live) - 1, row0 - 1, -1):
            if slots_live[i].remaining == 0:
                finish_slot(i)
