"""``CodedServer``: a continuous-batching serving engine over one resident
``CodedPipeline`` + ``FcdccCluster``.

The paper's deployment model (Sec. IV, Fig. 1) pre-stores coded filters on
the workers and streams inference through the coded cluster; this module
turns that into a *server*: concurrent callers ``submit()`` single images,
a background engine thread assembles them into bucketed batches and
advances in-flight batches one ConvL at a time through the cluster's
``run_pipeline_layer`` master/worker rounds, admitting late arrivals at
every layer boundary.

Two execution paths share the resident pipeline:

  * ``execution="cluster"`` — every layer is a full master/worker round
    (encode, dispatch n coded subtasks via the cluster's persistent
    per-worker pool, fastest-delta collect, decode).  Stragglers and dead
    workers behave exactly as in ``run_pipeline``; this is what
    ``benchmarks/exp6_serving.py`` measures.
  * ``execution="direct"`` — survivors are pre-picked from the straggler
    model (dead workers excluded, slowest gamma dropped) and the whole
    stack runs through ``CodedPipeline.run_prepared``: no host-side code
    prep between layers, so decode of layer *i* overlaps encode of layer
    *i+1* on the device queue.

Batch sizes are padded to the pipeline's ``bucket_sizes``, so jit compiles
one program per (layer, bucket) — ``warmup()`` pre-traces them all, and
``CodedPipeline.worker_program_traces`` stays bounded by the bucket count
no matter how request batch sizes vary.
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import CodedPipeline, build_cnn_pipeline
from repro.runtime import FcdccCluster, StragglerModel

from .metrics import MetricsCollector, RequestRecord, ServingStats
from .scheduler import RequestHandle, ScheduledBatch, Scheduler

__all__ = ["CodedServer"]

DEFAULT_BUCKETS = (1, 2, 4, 8)


class CodedServer:
    """Continuous-batching inference server over a resident coded pipeline.

    Owns one ``FcdccCluster`` (persistent per-worker pool, resident coded
    filters) and one engine thread.  ``submit()`` is thread-safe and
    returns a ``RequestHandle``; ``stats()`` aggregates per-request
    metrics.  Use as a context manager or call ``start()``/``shutdown()``.
    """

    def __init__(self, pipeline: CodedPipeline,
                 straggler: StragglerModel | None = None, *,
                 mode: str = "simulated", execution: str = "cluster",
                 bucket_sizes=None, max_inflight: int = 2,
                 poll_interval_s: float = 0.005):
        if execution not in ("cluster", "direct"):
            raise ValueError(f"unknown execution mode {execution!r}")
        if pipeline.bucket_sizes is None:
            pipeline.bucket_sizes = CodedPipeline.normalize_buckets(
                bucket_sizes if bucket_sizes is not None else DEFAULT_BUCKETS
            )
        elif bucket_sizes is not None and \
                CodedPipeline.normalize_buckets(bucket_sizes) \
                != pipeline.bucket_sizes:
            raise ValueError(
                f"pipeline already bucketed as {pipeline.bucket_sizes}, "
                f"got bucket_sizes={tuple(bucket_sizes)}"
            )
        self.pipeline = pipeline
        self.execution = execution
        spec0 = pipeline.specs[0]
        # the cluster runs the pipeline's own worker programs, so it must
        # share the pipeline's backend (lax / pallas) and interpret knob
        self.cluster = FcdccCluster(spec0.plan, straggler, mode=mode,
                                    backend=pipeline.backend,
                                    interpret=pipeline.interpret)
        self.cluster.load_pipeline(pipeline)
        self.scheduler = Scheduler(
            pipeline.pad_to_bucket,
            max_batch=pipeline.max_batch,
            max_inflight=max_inflight,
        )
        self.metrics = MetricsCollector()
        self._poll_interval_s = poll_interval_s
        self._stop = threading.Event()
        self._drain = True
        self._thread: threading.Thread | None = None
        self._prepared = None  # direct-mode survivor plan, built lazily
        c, h, w = spec0.geo.in_channels, spec0.geo.height, spec0.geo.width
        self._input_shape = (c, h, w)
        self._input_dtype = pipeline.coded_filters[0].dtype

    # -- construction helpers ----------------------------------------------
    @classmethod
    def from_cnn(cls, name: str, params: dict, n: int, *,
                 q: int | None = None, default_kab=None, input_hw=None,
                 straggler: StragglerModel | None = None,
                 mode: str = "simulated", execution: str = "cluster",
                 backend: str = "lax", interpret: bool = True,
                 bucket_sizes=None, max_inflight: int = 2) -> "CodedServer":
        """Compile a named CNN (``lenet5``/``alexnet``/``vgg16``) into a
        bucketed resident pipeline and wrap a server around it.

        ``backend="pallas"`` serves every bucketed batch program through the
        fused coded-worker Pallas kernel; ``interpret=False`` lowers those
        kernels to real TPU hardware instead of CPU emulation."""
        pipeline = build_cnn_pipeline(
            name, params, n, q=q, default_kab=default_kab, input_hw=input_hw,
            backend=backend, interpret=interpret,
            bucket_sizes=(bucket_sizes if bucket_sizes is not None
                          else DEFAULT_BUCKETS),
        )
        return cls(pipeline, straggler, mode=mode, execution=execution,
                   max_inflight=max_inflight)

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "CodedServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._engine_loop, name="coded-server-engine", daemon=True
        )
        self._thread.start()
        return self

    def shutdown(self, *, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop the engine.  ``drain=True`` (default) finishes queued and
        in-flight requests first; ``drain=False`` cancels them with a
        ``RuntimeError``.  Idempotent.

        If the engine thread is still alive after ``timeout``, ``_thread``
        is kept (so a retry joins it again instead of silently skipping)
        and all outstanding requests are failed with the ``TimeoutError``
        — callers blocked on ``result()`` surface the wedged engine
        instead of hanging until their own timeouts."""
        self._drain = drain
        self._stop.set()
        thread = self._thread
        if thread is not None:
            with self.scheduler.queue.not_empty:
                self.scheduler.queue.not_empty.notify_all()
            thread.join(timeout)
            if thread.is_alive():
                err = TimeoutError(f"engine thread not done after {timeout}s")
                self.scheduler.cancel_all(err)
                # release the worker pools even though the engine may still
                # be wedged on them: a never-retried shutdown must not leak
                # n executors, and the cluster re-creates pools lazily if
                # the engine ever resumes
                self.cluster.shutdown()
                raise err
            self._thread = None
            # a submit that passed the gate while the engine was exiting
            # enqueued onto a dead engine — fail it rather than strand it
            self.scheduler.cancel_all(RuntimeError("server shut down"))
        self.cluster.shutdown()

    def __enter__(self) -> "CodedServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- request path --------------------------------------------------------
    def submit(self, x) -> RequestHandle:
        """Enqueue one ``(C, H, W)`` image; returns a handle whose
        ``result()`` blocks for the decoded output.

        Inputs are cast to the pipeline dtype: a stray uint8/float16 request
        must not re-trace every (layer, bucket) program under a new dtype —
        the bounded-program contract is shape *and* dtype."""
        x = jnp.asarray(x, self._input_dtype)
        if tuple(x.shape) != self._input_shape:
            raise ValueError(
                f"request shape {tuple(x.shape)} != pipeline input "
                f"{self._input_shape}"
            )
        # _stop closes the gate the moment shutdown begins (also after a
        # timed-out shutdown, where _thread is deliberately kept): a late
        # submit must not enqueue onto an engine that will never serve it
        if self._thread is None or self._stop.is_set():
            raise RuntimeError("server not running; call start()")
        return self.scheduler.submit(x)

    def submit_many(self, xs) -> list[RequestHandle]:
        return [self.submit(x) for x in xs]

    def warmup(self) -> None:
        """Pre-trace every (layer, bucket) program by running one zero
        batch per bucket end-to-end.  After this, serving never jit-compiles
        (the bounded-program contract) and first-request latency is flat."""
        for bucket in self.pipeline.bucket_sizes:
            x = jnp.zeros((bucket,) + self._input_shape, self._input_dtype)
            if self.execution == "direct":
                jax.block_until_ready(
                    self.pipeline.run_prepared(x, self._direct_plan())
                )
            else:
                self.cluster.run_pipeline(x)

    def stats(self) -> ServingStats:
        return self.metrics.stats()

    # -- engine loop ---------------------------------------------------------
    def _engine_loop(self) -> None:
        sched = self.scheduler
        while True:
            if self._stop.is_set() and (not self._drain or not sched.has_work()):
                break
            # layer boundary: admit late arrivals until the queue is empty
            # or every inflight slot is filled — a single admit per
            # iteration would fill free capacity one layer-round late
            while sched.admit() is not None:
                pass
            batch = sched.next_batch()
            if batch is None:
                with sched.queue.not_empty:
                    if not len(sched.queue) and not self._stop.is_set():
                        sched.queue.not_empty.wait(self._poll_interval_s)
                continue
            try:
                self._advance(batch)
            except Exception as err:  # degraded cluster etc: fail the batch
                sched.retire(batch)
                for req in batch.requests:
                    req.finish(error=err)
        if not self._drain:
            self.scheduler.cancel_all(RuntimeError("server shut down"))

    def _advance(self, batch: ScheduledBatch) -> None:
        """Advance one batch — by one ConvL (cluster execution, so other
        batches and new arrivals interleave at layer boundaries) or through
        the whole prepared stack (direct execution)."""
        if self.execution == "direct":
            batch.x = jax.block_until_ready(
                self.pipeline.run_prepared(batch.x, self._direct_plan())
            )
            batch.layer_idx = len(self.pipeline.specs)
        else:
            batch.x, timing = self.cluster.run_pipeline_layer(
                batch.layer_idx, batch.x
            )
            batch.timings.append(timing)
            batch.layer_idx += 1
        if batch.layer_idx >= len(self.pipeline.specs):
            self._complete(batch)

    def _complete(self, batch: ScheduledBatch) -> None:
        self.scheduler.retire(batch)
        y = np.asarray(batch.x)
        for row, req in enumerate(batch.requests):
            req.finish(result=y[row])
            if req.error is not None:
                # a shutdown-timeout cancellation won the finish race: the
                # caller saw the error, so this request was not served —
                # keep it out of the served-request metrics
                continue
            self.metrics.record(RequestRecord(
                request_id=req.request_id,
                arrival_t=req.arrival_t,
                start_t=req.start_t,
                finish_t=req.finish_t,
                bucket=batch.bucket,
                batch_real=batch.real,
            ))

    # -- direct-mode survivor pre-pick ---------------------------------------
    def _direct_plan(self):
        """The ``prepare`` plan over pre-picked survivors: dead workers
        excluded, remaining sorted by injected delay (fastest first) so each
        layer decodes from the delta best.  Cached — every batch reuses it
        until the straggler model changes."""
        delays = self.cluster.straggler.delays
        key = tuple(np.asarray(delays).tolist())
        if self._prepared is None or self._prepared[0] != key:
            alive = [i for i in range(self.cluster.n)
                     if np.isfinite(delays[i])]
            alive.sort(key=lambda i: (delays[i], i))
            self._prepared = (key, self.pipeline.prepare(alive))
        return self._prepared[1]
