"""``CodedServer``: a continuous-batching, multi-model serving engine over
resident ``CodedPipeline``s sharing one ``FcdccCluster``.

The paper's deployment model (Sec. IV, Fig. 1) pre-stores coded filters on
the workers and streams inference through the coded cluster; this module
turns that into a *server*: concurrent callers ``submit()`` single images,
a background engine thread assembles them into bucketed batches and
advances in-flight batches one ConvL at a time through the cluster's
``run_pipeline_layer`` master/worker rounds, admitting late arrivals at
every layer boundary.

Several models share the one persistent worker pool: ``register_model``
loads each ``CodedPipeline`` (e.g. lenet5 + alexnet under different
``(k_a, k_b)`` plans) into its own cluster namespace — resident coded
filters and jit program caches never collide — and each model gets its own
scheduler (queue, buckets, in-flight capacity).  The engine picks work
fair-share: a rotating round-robin sweep across the models with in-flight
work, deepest batch first within a model, with equal-depth batches of one
model coalesced back into full buckets when capacity allows.  Constructing the server with a
single pipeline is the unchanged single-model API (one model named
``"default"``).

Two execution paths share the resident pipelines:

  * ``execution="cluster"`` — every layer is a full master/worker round
    (encode, dispatch n coded subtasks via the cluster's persistent
    per-worker pool, fastest-delta collect, decode).  Stragglers and dead
    workers behave exactly as in ``run_pipeline``; this is what
    ``benchmarks/exp6_serving.py`` and ``exp8_multimodel.py`` measure.
  * ``execution="direct"`` — survivors are pre-picked from the straggler
    model (dead workers excluded, slowest gamma dropped) and the whole
    stack runs through ``CodedPipeline.run_prepared``: no host-side code
    prep between layers, so decode of layer *i* overlaps encode of layer
    *i+1* on the device queue.

Batch sizes are padded to each pipeline's ``bucket_sizes``, so jit compiles
one program per (layer, bucket) — ``warmup()`` pre-traces them all, and the
trace count summed over models stays bounded by geometries x buckets no
matter how request batch sizes vary.

A pipeline built with ``fuse_transitions=True`` serves on the
partition-resident path: between ConvL boundaries a batch's state is the
next layer's coded input shares (decode only to the partition grid,
relu/pool per spatial partition with halo exchange, re-encode — one fused
transition program per (layer, bucket)), and the full activation tensor is
materialized only at the final layer.  Late admission is unchanged (new
batches enter at layer 0 with raw images) and coalescing merges
partition-space batches on their coded-share batch axis.
``register_model(..., weight=w)`` sets the integer fair share: the rotating
sweep grants a model up to ``w`` consecutive rounds per sweep position, so
a backlogged model waits at most the sum of the other models' weights.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import CodedPipeline, build_cnn_pipeline
from repro.runtime import FcdccCluster, PendingRound, StragglerModel

from .metrics import (MetricsCollector, OverlapStats, RequestRecord,
                      ServingStats)
from .scheduler import MultiScheduler, RequestHandle, ScheduledBatch

__all__ = ["CodedServer"]

DEFAULT_BUCKETS = (1, 2, 4, 8)


@dataclasses.dataclass
class _ModelState:
    """Engine-side view of one registered model.

    The name -> pipeline registry lives ONLY in the cluster
    (``FcdccCluster.pipelines``, written by ``load_pipeline``); this object
    holds the serving-side extras (the direct-mode survivor plan) and
    resolves ``pipeline`` through the cluster — so the engine and the
    cluster can never disagree about what is resident.  The fair-share
    weight likewise lives only in the ``MultiScheduler``."""

    name: str
    cluster: FcdccCluster
    # direct-mode survivor plan, built lazily  # guarded-by: engine-thread
    prepared: tuple | None = None

    @property
    def pipeline(self) -> CodedPipeline:
        return self.cluster.pipelines[self.name]


@dataclasses.dataclass
class _InFlightRound:
    """One dispatched-but-uncollected worker round in the engine's pipeline
    window.  Engine-private: only the engine thread creates, polls, and
    consumes these.  # guarded-by: engine-thread"""

    state: _ModelState
    batch: ScheduledBatch
    rnd: PendingRound
    dispatch_s: float  # master-side encode + submit time for this round


class CodedServer:
    """Continuous-batching inference server over resident coded pipelines.

    Owns one ``FcdccCluster`` (persistent per-worker pool shared by every
    registered model) and one engine thread.  ``submit()`` is thread-safe
    and returns a ``RequestHandle``; ``stats()`` aggregates per-request
    metrics (``stats(model=...)`` for one model).  Use as a context manager
    or call ``start()``/``shutdown()``.
    """

    def __init__(self, pipeline: CodedPipeline | None = None,
                 straggler: StragglerModel | None = None, *,
                 mode: str = "simulated", execution: str = "cluster",
                 bucket_sizes=None, max_inflight: int = 2,
                 pipeline_depth: int = 2,
                 poll_interval_s: float = 0.005, model: str = "default",
                 pool: str | None = None, devices=None):
        if execution not in ("cluster", "direct"):
            raise ValueError(f"unknown execution mode {execution!r}")
        if not isinstance(pipeline_depth, int) or pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be an integer >= 1, got {pipeline_depth!r}"
            )
        self.execution = execution
        # round-pipelining window: how many dispatched worker rounds (of
        # any model) may be in flight at once.  1 = the classic serial
        # dispatch -> collect loop; 2+ overlaps batch A's collect + fused
        # transition on the master with batch B's worker compute
        self.pipeline_depth = pipeline_depth
        self.mode = mode
        self.cluster: FcdccCluster | None = None
        # worker-pool preference for the shared cluster ("threads"/"device"/
        # None = auto): an explicit argument wins, else the first registered
        # pipeline's own preference rides along
        self._pool = pool
        self._devices = devices
        self._straggler = straggler
        self._default_buckets = bucket_sizes
        self._default_max_inflight = max_inflight
        # registry writes (register/unregister from caller threads) go
        # through the lock; the engine thread only reads via ``.get``
        self._registry_lock = threading.Lock()
        self.models: dict[str, _ModelState] = {}  # guarded-by: self._registry_lock
        self.scheduler = MultiScheduler()
        self.metrics = MetricsCollector()
        self._poll_interval_s = poll_interval_s
        self._stop = threading.Event()
        self._drain = True  # guarded-by: control-thread
        self._thread: threading.Thread | None = None  # guarded-by: control-thread
        if pipeline is not None:
            self.register_model(model, pipeline)

    # -- construction helpers ----------------------------------------------
    @classmethod
    def from_cnn(cls, name: str, params: dict, n: int, *,
                 q: int | None = None, default_kab=None, input_hw=None,
                 straggler: StragglerModel | None = None,
                 mode: str = "simulated", execution: str = "cluster",
                 backend: str = "lax", interpret: bool = True,
                 bucket_sizes=None, max_inflight: int = 2,
                 pipeline_depth: int = 2,
                 model: str | None = None,
                 fuse_transitions: bool = False,
                 pool: str | None = None, devices=None) -> "CodedServer":
        """Compile a named CNN (``lenet5``/``alexnet``/``vgg16``) into a
        bucketed resident pipeline and wrap a server around it; the model
        registers under ``model`` (default: the arch name).  Register more
        models afterwards with ``register_model``.

        ``backend="pallas"`` serves every bucketed batch program through the
        fused coded-worker Pallas kernel; ``interpret=False`` lowers those
        kernels to real TPU hardware instead of CPU emulation.
        ``fuse_transitions=True`` serves on the partition-resident path:
        batches advance between ConvL boundaries as coded partition shares,
        never materializing the full activation between layers."""
        pipeline = build_cnn_pipeline(
            name, params, n, q=q, default_kab=default_kab, input_hw=input_hw,
            backend=backend, interpret=interpret,
            bucket_sizes=(bucket_sizes if bucket_sizes is not None
                          else DEFAULT_BUCKETS),
            fuse_transitions=fuse_transitions,
            pool=pool, devices=devices,
        )
        return cls(pipeline, straggler, mode=mode, execution=execution,
                   max_inflight=max_inflight, pipeline_depth=pipeline_depth,
                   model=model if model is not None else name)

    # -- model registry ------------------------------------------------------
    def register_model(self, name: str, pipeline: CodedPipeline, *,
                       bucket_sizes=None, max_inflight: int | None = None,
                       weight: int = 1) -> None:
        """Load ``pipeline`` as model ``name`` onto the shared worker pool.

        The first registration creates the cluster (inheriting the
        pipeline's backend/interpret); later ones must target the same
        worker count and backend.  Each model gets its own scheduler
        (queue, buckets, in-flight capacity) — registration happens before
        ``start()``.  The pipeline registry itself is the cluster's
        ``pipelines`` mapping (one source of truth); ``self.models`` holds
        only the per-model serving state viewing it.

        ``weight`` is the integer fair share: the engine's rotating sweep
        grants the model up to ``weight`` consecutive layer rounds per
        sweep position, so under contention round counts converge to the
        weight ratio (a backlogged model waits at most the sum of the
        other models' weights between its rounds)."""
        if name in self.models:
            raise ValueError(f"model {name!r} already registered")
        if not isinstance(weight, int) or weight < 1:
            raise ValueError(f"weight must be an integer >= 1, got {weight!r}")
        # validate shared-pool compatibility BEFORE any mutation: a failed
        # registration must not leave the caller's pipeline re-bucketed
        if self.cluster is not None:
            if pipeline.n != self.cluster.n:
                raise ValueError(
                    f"model {name!r} targets n={pipeline.n}, shared pool "
                    f"has n={self.cluster.n}"
                )
            if (pipeline.backend, pipeline.interpret) != \
                    (self.cluster.backend, self.cluster.interpret):
                raise ValueError(
                    f"model {name!r} built for backend="
                    f"{pipeline.backend!r}/interpret={pipeline.interpret}, "
                    f"shared pool runs {self.cluster.backend!r}/"
                    f"interpret={self.cluster.interpret}"
                )
        buckets = bucket_sizes if bucket_sizes is not None \
            else self._default_buckets
        if pipeline.bucket_sizes is None:
            pipeline.bucket_sizes = CodedPipeline.normalize_buckets(
                buckets if buckets is not None else DEFAULT_BUCKETS
            )
        elif buckets is not None and \
                CodedPipeline.normalize_buckets(buckets) \
                != pipeline.bucket_sizes:
            raise ValueError(
                f"pipeline already bucketed as {pipeline.bucket_sizes}, "
                f"got bucket_sizes={tuple(buckets)}"
            )
        if self.cluster is None:
            # the cluster runs each pipeline's own worker programs, so it
            # must share the pipelines' backend (lax / pallas) and
            # interpret knob; the worker pool comes from the server's
            # explicit preference, else the pipeline's
            self.cluster = FcdccCluster(
                pipeline.specs[0].plan, self._straggler, mode=self.mode,
                backend=pipeline.backend, interpret=pipeline.interpret,
                pool=self._pool if self._pool is not None else pipeline.pool,
                devices=(self._devices if self._devices is not None
                         else pipeline.devices),
            )
        self.cluster.load_pipeline(pipeline, name)
        # publish order matters for LIVE registration (engine running):
        # the scheduler entry goes in LAST, after the pipeline is resident
        # and the serving state exists — the engine loop resolves work it
        # picked through ``self.models``/the cluster, so a model it can
        # pick must already be fully registered
        with self._registry_lock:
            self.models[name] = _ModelState(name, self.cluster)
        self.scheduler.add_model(
            name, pipeline.pad_to_bucket, max_batch=pipeline.max_batch,
            # the default in-flight capacity grows with the pipeline window:
            # fewer than ``pipeline_depth`` admissible batches could never
            # fill the window, silently serializing the rounds again
            max_inflight=(max_inflight if max_inflight is not None
                          else max(self._default_max_inflight,
                                   self.pipeline_depth)),
            weight=weight,
        )

    def unregister_model(self, name: str, *, drain: bool = True,
                         timeout: float = 60.0) -> None:
        """Remove model ``name`` from a (possibly live) server.

        Two-phase teardown so the engine never touches a half-removed
        model: first the model's scheduler is *closed* (new submits are
        refused while queued + in-flight requests finish — or, with
        ``drain=False``, are cancelled immediately), then it is *fenced*
        (its ``pad_to_bucket``/bucket bindings are never consulted again)
        and only then are the scheduler entry, serving state, resident
        filters, and device-pool filter shards torn down.  On timeout the
        model is left closed-but-registered and the ``TimeoutError``
        surfaces (retry or ``drain=False`` to force)."""
        if name not in self.models:
            raise ValueError(
                f"unknown model {name!r}; registered: {sorted(self.models)}"
            )
        sched = self.scheduler[name]
        sched.close()
        engine_live = self._thread is not None and not self._stop.is_set()
        if drain and engine_live:
            deadline = time.perf_counter() + timeout
            while sched.has_work():
                if time.perf_counter() > deadline:
                    raise TimeoutError(
                        f"model {name!r} still has in-flight work after "
                        f"{timeout}s; retry or unregister with drain=False"
                    )
                time.sleep(self._poll_interval_s)
        else:
            sched.cancel_all(RuntimeError(f"model {name!r} unregistered"))
        # fence BEFORE teardown: from here the engine can still hold a
        # reference to the scheduler from a stale snapshot, but every entry
        # point that would consult the model's bucket bindings refuses
        sched.fence()
        if not drain:  # cancel again: a request admitted during the close-
            sched.cancel_all(  # to-cancel window must not be stranded
                RuntimeError(f"model {name!r} unregistered"))
        self.scheduler.remove_model(name)
        with self._registry_lock:
            del self.models[name]
        self.cluster.unload_pipeline(name)

    def model_names(self) -> list[str]:
        return list(self.models)

    @property
    def pipeline(self) -> CodedPipeline:
        """The single registered pipeline (single-model back-compat view);
        ambiguous — and an error — once several models are registered."""
        if len(self.models) != 1:
            raise ValueError(
                f"{len(self.models)} models registered "
                f"({sorted(self.models)}); use models[name].pipeline"
            )
        return next(iter(self.models.values())).pipeline

    def _resolve(self, model: str | None) -> _ModelState:
        if not self.models:
            raise ValueError("no model registered; call register_model()")
        if model is None:
            if len(self.models) > 1:
                raise ValueError(
                    f"{len(self.models)} models registered "
                    f"({sorted(self.models)}); pass model="
                )
            return next(iter(self.models.values()))
        try:
            return self.models[model]
        except KeyError:
            raise ValueError(
                f"unknown model {model!r}; registered: {sorted(self.models)}"
            ) from None

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "CodedServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        if not self.models:
            raise RuntimeError("no model registered; call register_model()")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._engine_loop, name="coded-server-engine", daemon=True
        )
        self._thread.start()
        return self

    def shutdown(self, *, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop the engine.  ``drain=True`` (default) finishes queued and
        in-flight requests first; ``drain=False`` cancels them with a
        ``RuntimeError``.  Idempotent.

        If the engine thread is still alive after ``timeout``, ``_thread``
        is kept (so a retry joins it again instead of silently skipping)
        and all outstanding requests are failed with the ``TimeoutError``
        — callers blocked on ``result()`` surface the wedged engine
        instead of hanging until their own timeouts."""
        self._drain = drain
        self._stop.set()
        thread = self._thread
        if thread is not None:
            with self.scheduler.not_empty:
                self.scheduler.not_empty.notify_all()
            thread.join(timeout)
            if thread.is_alive():
                err = TimeoutError(f"engine thread not done after {timeout}s")
                self.scheduler.cancel_all(err)
                # release the worker pools even though the engine may still
                # be wedged on them: a never-retried shutdown must not leak
                # n executors, and the cluster re-creates pools lazily if
                # the engine ever resumes
                self.cluster.shutdown()
                raise err
            self._thread = None
            # a submit that passed the gate while the engine was exiting
            # enqueued onto a dead engine — fail it rather than strand it
            self.scheduler.cancel_all(RuntimeError("server shut down"))
        if self.cluster is not None:
            self.cluster.shutdown()

    def __enter__(self) -> "CodedServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- request path --------------------------------------------------------
    def submit(self, x, model: str | None = None) -> RequestHandle:
        """Enqueue one ``(C, H, W)`` image for ``model`` (optional while a
        single model is registered); returns a handle whose ``result()``
        blocks for the decoded output.

        Inputs are cast to the pipeline dtype: a stray uint8/float16 request
        must not re-trace every (layer, bucket) program under a new dtype —
        the bounded-program contract is shape *and* dtype."""
        state = self._resolve(model)
        pipe = state.pipeline
        x = jnp.asarray(x, pipe.input_dtype)
        if tuple(x.shape) != pipe.input_shape:
            raise ValueError(
                f"request shape {tuple(x.shape)} != model "
                f"{state.name!r} input {pipe.input_shape}"
            )
        # _stop closes the gate the moment shutdown begins (also after a
        # timed-out shutdown, where _thread is deliberately kept): a late
        # submit must not enqueue onto an engine that will never serve it
        if self._thread is None or self._stop.is_set():
            raise RuntimeError("server not running; call start()")
        return self.scheduler.submit(state.name, x)

    def submit_many(self, xs, model: str | None = None) -> list[RequestHandle]:
        return [self.submit(x, model) for x in xs]

    def warmup(self, model: str | None = None) -> None:
        """Pre-trace every (layer, bucket) program — of one model, or of
        every registered model (default) — by running one zero batch per
        bucket end-to-end.  After this, serving never jit-compiles (the
        bounded-program contract) and first-request latency is flat."""
        states = ([self._resolve(model)] if model is not None
                  else list(self.models.values()))
        for state in states:
            pipe = state.pipeline
            for bucket in pipe.bucket_sizes:
                x = jnp.zeros((bucket,) + pipe.input_shape, pipe.input_dtype)
                if self.execution == "direct":
                    jax.block_until_ready(
                        pipe.run_prepared(x, self._direct_plan(state))
                    )
                else:
                    self.cluster.run_pipeline(x, model=state.name)

    def stats(self, model: str | None = None) -> ServingStats:
        return self.metrics.stats(model)

    def per_model_stats(self) -> dict[str, ServingStats]:
        return self.metrics.per_model_stats()

    def overlap_stats(self, model: str | None = None) -> OverlapStats:
        """Per-phase round timings + pipelining efficiency (see
        ``OverlapStats``) — all models, or one model's rounds."""
        return self.metrics.overlap_stats(model)

    def wait_many(self, handles, timeout: float | None = 60.0, *,
                  slice_s: float = 0.05) -> bool:
        """Block until every handle is done (True) or ``timeout`` elapses
        (False — no request is cancelled, some may have finished).

        One shared condition (``MultiScheduler.completion``) serves every
        waiter with timeout-sliced waits, so a bounded pool of threads can
        park on many pending requests at once — the HTTP front-end's
        bounded handler pool gathers batched requests through here instead
        of dedicating one blocked thread per ``result()`` call."""
        deadline = (None if timeout is None
                    else time.perf_counter() + float(timeout))
        completion = self.scheduler.completion
        with completion:
            while True:
                if all(h.done() for h in handles):
                    return True
                wait_s = slice_s
                if deadline is not None:
                    left = deadline - time.perf_counter()
                    if left <= 0:
                        return False
                    wait_s = min(wait_s, left)
                completion.wait(wait_s)

    # -- engine loop ---------------------------------------------------------
    # reaper poll floor: first wait after a dispatch (backs off toward
    # ``poll_interval_s`` while nothing lands, resets per reap)
    _REAP_POLL_MIN_S = 50e-6

    def _engine_loop(self) -> None:
        sched = self.scheduler
        # the pipeline window: dispatched-but-uncollected worker rounds,
        # oldest first (collects happen in whatever order rounds finish)
        rounds: list[_InFlightRound] = []  # guarded-by: engine-thread
        busy_t0 = 0.0  # wall-clock start of the current busy span
        while True:
            if self._stop.is_set() and (
                not self._drain or (not rounds and not sched.has_work())
            ):
                # drain=False abandons in-flight rounds: their results are
                # never gathered and cancel_all below fails their requests
                break
            # layer boundary: admit late arrivals (all models, rotating)
            # until every queue is empty or every inflight slot is filled —
            # a single admit per iteration would fill free capacity one
            # layer-round late
            while sched.admit() is not None:
                pass
            # re-pack equal-depth fragments into full buckets (batches with
            # a round in flight are skipped — their state is mid-round)
            for name, merges in sched.coalesce().items():
                self.metrics.count_coalesced(name, merges)
            # dispatch phase: fill the window with fair-share picks, each
            # pick one layer round, so batch B's workers start before
            # batch A's collect
            while len(rounds) < self.pipeline_depth:
                picked = sched.next_batch()
                if picked is None:
                    break
                name, batch = picked
                state = self.models.get(name)
                if state is None:  # unregistered between pick and dispatch:
                    break          # its requests were cancelled by the
                                   # fence; re-snapshot from the loop top
                if not rounds:
                    busy_t0 = time.perf_counter()
                self._stamp_start(batch)
                if self.execution == "direct":
                    try:
                        self._advance(state, batch)
                    except Exception as err:  # degraded cluster etc.
                        self._fail_batch(name, batch, err)
                    break  # synchronous: back to admission, like depth 1
                t0 = time.perf_counter()
                try:
                    rnd = self.cluster.dispatch_pipeline_layer(
                        batch.layer_idx, batch.x, name
                    )
                except Exception as err:  # encode/submit failed
                    self._fail_batch(name, batch, err)
                    continue
                batch.dispatched = True
                rounds.append(_InFlightRound(
                    state, batch, rnd, time.perf_counter() - t0
                ))
                self.metrics.note_depth(len(rounds))
            if not rounds:
                if not self._stop.is_set():
                    with sched.not_empty:
                        if not sched.queued() and not self._stop.is_set():
                            sched.not_empty.wait(self._poll_interval_s)
                continue
            ent = self._poll_rounds(
                rounds, can_dispatch=len(rounds) < self.pipeline_depth
            )
            if ent is None:
                continue  # new dispatchable work, or stop without drain
            self._finish_round(ent)
            if not rounds:
                self.metrics.note_busy(time.perf_counter() - busy_t0)
        if not self._drain:
            self.scheduler.cancel_all(RuntimeError("server shut down"))

    def _stamp_start(self, batch: ScheduledBatch) -> None:
        """Queue-wait ends here: stamp ``start_t`` on every request seeing
        its first dispatch (later rounds of the same batch, and rows merged
        in by coalescing after their own first dispatch, keep theirs)."""
        now = time.perf_counter()
        for r in batch.requests:
            if np.isnan(r.start_t):
                r.start_t = now

    def _fail_batch(self, name: str, batch: ScheduledBatch,
                    err: BaseException) -> None:
        self.scheduler.retire(name, batch)
        for req in batch.requests:
            req.finish(error=err)

    def _poll_rounds(self, rounds: list, can_dispatch: bool):
        """Reap whichever in-flight round is ready first (removed from
        ``rounds`` and returned) — NOT FIFO: under mixed models/straggler
        draws a younger round can land before an older one.  Returns None
        to hand control back to the dispatch phase: a free window slot has
        dispatchable work, or shutdown-without-drain sheds the window.
        Waits on ``not_empty`` with exponential backoff so new submits
        interrupt the sleep immediately."""
        sched = self.scheduler
        wait_s = self._REAP_POLL_MIN_S
        while True:
            for k, ent in enumerate(rounds):
                if self.cluster.round_ready(ent.rnd):
                    return rounds.pop(k)
            if self._stop.is_set() and not self._drain:
                return None
            if can_dispatch and sched.dispatchable():
                return None
            with sched.not_empty:
                sched.not_empty.wait(wait_s)
            wait_s = min(wait_s * 2.0, self._poll_interval_s)

    def _finish_round(self, ent: "_InFlightRound") -> None:
        """The collect half of one pipelined round: gather + decode (or the
        fused transition), advance the batch one boundary, account the
        phase timings, and complete the batch when it ran its last layer.

        Everything is resolved through the ``PendingRound`` (pipeline
        captured at dispatch), so a model unregistered mid-flight still
        finishes cleanly — its requests were already cancelled by the
        fence, ``finish`` is first-writer-wins, and retire tolerates the
        missing scheduler."""
        state, batch, pipe = ent.state, ent.batch, ent.rnd.pipe
        t0 = time.perf_counter()
        try:
            y, timing = self.cluster.collect_pipeline_layer(ent.rnd)
        except Exception as err:  # degraded cluster etc: fail the batch
            self._fail_batch(state.name, batch, err)
            return
        t_reap = time.perf_counter() - t0
        batch.x = y
        batch.timings.append(timing)
        batch.layer_idx += 1
        # partition-resident pipelines carry coded shares between rounds —
        # the request batch sits on axis 2 of (n, ell_a, B, C, h_hat, Wp)
        # until the final merge, and coalescing/padding must slice that axis
        batch.batch_axis = (
            2 if pipe.fuse_transitions
            and 0 < batch.layer_idx < len(pipe.specs) else 0
        )
        batch.dispatched = False
        self.metrics.record_phases(
            state.name,
            dispatch_s=ent.dispatch_s,
            worker_s=timing.compute_s,
            collect_s=max(t_reap - timing.decode_s, 0.0),
            transition_s=timing.decode_s,
        )
        if batch.layer_idx >= len(pipe.specs):
            self._complete(state, batch)

    def _advance(self, state: _ModelState, batch: ScheduledBatch) -> None:
        """Advance one batch — by one ConvL (cluster execution, so other
        batches and new arrivals of any model interleave at layer
        boundaries) or through the whole prepared stack (direct)."""
        pipe = state.pipeline
        if self.execution == "direct":
            batch.x = jax.block_until_ready(
                pipe.run_prepared(batch.x, self._direct_plan(state))
            )
            batch.layer_idx = len(pipe.specs)
        else:
            batch.x, timing = self.cluster.run_pipeline_layer(
                batch.layer_idx, batch.x, state.name
            )
            batch.timings.append(timing)
            batch.layer_idx += 1
            # partition-resident pipelines carry coded shares between
            # rounds — the request batch sits on axis 2 of
            # (n, ell_a, B, C, h_hat, Wp) until the final merge, and
            # coalescing/padding must slice that axis
            batch.batch_axis = (
                2 if pipe.fuse_transitions
                and 0 < batch.layer_idx < len(pipe.specs) else 0
            )
        if batch.layer_idx >= len(pipe.specs):
            self._complete(state, batch)

    def _complete(self, state: _ModelState, batch: ScheduledBatch) -> None:
        self.scheduler.retire(state.name, batch)
        y = np.asarray(batch.x)
        for row, req in enumerate(batch.requests):
            req.finish(result=y[row])
            if req.error is not None:
                # a shutdown-timeout cancellation won the finish race: the
                # caller saw the error, so this request was not served —
                # keep it out of the served-request metrics
                continue
            self.metrics.record(RequestRecord(
                request_id=req.request_id,
                arrival_t=req.arrival_t,
                start_t=req.start_t,
                finish_t=req.finish_t,
                bucket=batch.bucket,
                batch_real=batch.real,
                model=state.name,
            ))

    # -- direct-mode survivor pre-pick ---------------------------------------
    def _direct_plan(self, state: _ModelState):
        """The ``prepare`` plan over pre-picked survivors: dead workers
        excluded, remaining sorted by injected delay (fastest first) so each
        layer decodes from the delta best.  Cached per model — every batch
        reuses it until the straggler model changes, or until the resident
        pipeline under this name is replaced (the cache holds the pipeline
        reference itself and compares by identity — not ``id()``, whose
        values CPython reuses after GC — so a plan prepared against old
        encode/decode matrices can never serve the replacement)."""
        delays = self.cluster.straggler.delays
        pipe = state.pipeline
        key = tuple(np.asarray(delays).tolist())
        if (state.prepared is None or state.prepared[0] is not pipe
                or state.prepared[1] != key):
            alive = [i for i in range(self.cluster.n)
                     if np.isfinite(delays[i])]
            alive.sort(key=lambda i: (delays[i], i))
            state.prepared = (pipe, key, pipe.prepare(alive))
        return state.prepared[2]
