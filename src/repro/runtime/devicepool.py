"""Worker pools behind ``FcdccCluster``'s submit/collect seam.

Two interchangeable executors for the n coded subtasks of one FCDCC
master/worker round:

  * ``ThreadWorkerPool`` (``pool="threads"``) — the original simulated
    cluster: one persistent single-thread executor per worker, every
    subtask computed on the *default* JAX device, stragglers injected as
    ``sleep()``s after the compute.  Deterministic, runs anywhere, and the
    only choice for ``mode="simulated"`` — but the n subtasks serialize on
    one device queue, so the paper's parallel decomposition never actually
    runs in parallel.
  * ``DeviceWorkerPool`` (``pool="device"``) — each worker pinned to a
    ``jax.Device`` from a 1-D worker mesh (``launch.mesh.make_worker_mesh``
    / ``sharding.worker_devices``): real TPU/GPU devices, or CPU host
    devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` so
    CI exercises it.  Coded filters are ``device_put`` once per worker and
    stay resident; the worker program is jitted *per device* (its own
    bounded trace cache, so the bounded-program contract is per-device);
    ``submit`` is pure async dispatch — all n subtasks enqueue on their own
    device queues with no per-call thread hop — and ``collect`` reaps the
    fastest delta via per-array readiness (``jax.Array.is_ready``),
    discarding late arrivals exactly like the thread pool.  Injected
    straggler delays are honored as *delayed dispatch* (a timer defers the
    enqueue by ``delays[i]`` — a simulated network/queueing delay ahead of
    the subtask), so the deterministic straggler tests and experiments run
    unchanged on the device pool; with zero delays the variance you measure
    is the real per-device one.

Both pools expose a non-blocking ``ready(pending, delta)`` next to the
blocking ``collect``: the serving engine keeps several master/worker
rounds in flight (round pipelining) and reaps whichever finishes first
instead of FIFO-blocking on the oldest.  ``ready`` never mutates the
pending batch — a True just means the immediately following ``collect``
will return without waiting.  The device pool's collect polls with
exponential backoff (``_POLL_MIN`` up to ``_POLL_MAX``, reset on
progress) so a master blocked on a long worker round stops burning a
core; pass an explicit ``poll_interval_s`` for a fixed period (tests).

Both pools share the ``PendingBatch`` in-flight handle and the
inf = dead / nan = discarded / finite = measured ``worker_times``
convention, so ``LayerTiming`` semantics are pool-independent.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait

import jax
import numpy as np

__all__ = [
    "ClusterDegraded", "DeviceWorkerPool", "PendingBatch", "StragglerModel",
    "ThreadWorkerPool", "make_pool", "resolve_pool",
]


class ClusterDegraded(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerModel:
    """Per-worker latency injection (seconds added to compute time)."""

    delays: np.ndarray  # (n,) extra seconds; np.inf = dead worker

    @staticmethod
    def none(n: int) -> "StragglerModel":
        return StragglerModel(np.zeros(n))

    @staticmethod
    def fixed(n: int, stragglers: int, delay: float, seed: int = 0) -> "StragglerModel":
        rng = np.random.default_rng(seed)
        d = np.zeros(n)
        idx = rng.choice(n, size=stragglers, replace=False)
        d[idx] = delay
        return StragglerModel(d)

    @staticmethod
    def random_uniform(n: int, p: float, delay: float, seed: int = 0) -> "StragglerModel":
        rng = np.random.default_rng(seed)
        return StragglerModel(np.where(rng.random(n) < p, delay, 0.0))


@dataclasses.dataclass
class PendingBatch:
    """In-flight coded dispatch: n submitted subtasks awaiting ``collect``.

    ``futures`` holds the per-worker futures (threads mode); ``results``
    holds the precomputed outputs (simulated mode) or the asynchronously
    dispatched device arrays (device pool — filled in under ``lock`` as
    timer-deferred stragglers dispatch).  ``worker_times`` is live — workers
    write into it as they finish — so ``collect`` snapshots it before
    returning.  ``expected`` (device pool) is the set of live workers whose
    result will eventually appear."""

    futures: dict
    results: dict  # guarded-by: self.lock
    worker_times: list  # guarded-by: single-writer-slots
    t_start: float
    expected: set | None = None
    lock: threading.Lock | None = None


def resolve_pool(pool: str | None, mode: str, devices=None) -> str:
    """The pool-selection rule shared by every entry point.

    Explicit ``"threads"``/``"device"`` is honored (``"device"`` requires
    ``mode="threads"`` — the simulated clock has no device queues to race).
    ``None`` auto-selects: the device pool whenever real parallelism is
    available (``mode="threads"`` and more than one addressable device, or
    an explicit device list), else the thread pool — so a plain 1-device
    host keeps today's behavior and an ``XLA_FLAGS`` multi-device host (or
    a real accelerator slice) gets device parallelism without a flag."""
    if pool is None:
        if mode == "threads" and (
            devices is not None or len(jax.devices()) > 1
        ):
            return "device"
        return "threads"
    if pool not in ("threads", "device"):
        raise ValueError(f"unknown pool {pool!r}; use 'threads' or 'device'")
    if pool == "device" and mode != "threads":
        raise ValueError(
            f"pool='device' requires mode='threads', got mode={mode!r}"
        )
    return pool


def make_pool(pool: str, n: int, straggler: StragglerModel, *,
              mode: str = "threads", devices=None):
    if pool == "device":
        return DeviceWorkerPool(n, straggler, devices=devices)
    return ThreadWorkerPool(n, straggler, mode=mode)


class ThreadWorkerPool:
    """Persistent per-worker single-thread executors (and the simulated
    clock), computing on the default device.  One executor per worker: a
    straggler still sleeping on an abandoned subtask keeps *its own* node
    busy (its next subtask queues behind, like a real overloaded worker)
    without ever blocking the fast workers."""

    kind = "threads"

    def __init__(self, n: int, straggler: StragglerModel, *,
                 mode: str = "threads"):
        assert mode in ("threads", "simulated")
        self.n = n
        self.straggler = straggler
        self.mode = mode
        # lazy create (first submit) vs shutdown swap race from another
        # thread: both transitions go through the lock
        self._lifecycle_lock = threading.Lock()
        self._pools: list[ThreadPoolExecutor] | None = None  # guarded-by: self._lifecycle_lock

    # -- lifecycle ---------------------------------------------------------
    def _ensure_pools(self) -> list[ThreadPoolExecutor]:
        with self._lifecycle_lock:
            if self._pools is None:
                self._pools = [
                    ThreadPoolExecutor(
                        max_workers=1, thread_name_prefix=f"fcdcc-worker-{i}"
                    )
                    for i in range(self.n)
                ]
            return self._pools

    def shutdown(self) -> None:
        with self._lifecycle_lock:
            pools, self._pools = self._pools, None
        if pools:
            for ex in pools:
                ex.shutdown(wait=False, cancel_futures=True)

    # -- program/filter placement ------------------------------------------
    def program(self, key: tuple, raw, i: int, jit_cache: dict):
        """All workers share ONE jitted program on the default device (the
        cluster's cache); per-worker specialization is a device-pool thing."""
        fn = jit_cache.get(key)
        if fn is None:
            fn = jit_cache[key] = jax.jit(raw)
        return fn

    def resident_filters(self, name: str, ke):
        return ke  # single device: the master copy IS the resident copy

    def drop_filters(self, prefix: str) -> None:
        pass

    def gather(self, arr):
        return arr

    def warm(self, fn, xe, ke) -> None:
        """Compile outside the timed collect: one worker-0 call suffices —
        every worker runs the same program on the same device."""
        jax.block_until_ready(fn(0)(xe[0], _ke_of(ke, 0)))

    # -- dispatch / reap ---------------------------------------------------
    def submit(self, fn, xe, ke) -> PendingBatch:
        delays = self.straggler.delays
        worker_times = [
            float("inf") if not np.isfinite(delays[i]) else float("nan")
            for i in range(self.n)
        ]

        def work(i):
            if not np.isfinite(delays[i]):
                raise RuntimeError(f"worker {i} failed")
            t = time.perf_counter()
            out = jax.block_until_ready(fn(i)(xe[i], _ke_of(ke, i)))
            dt = time.perf_counter() - t
            if self.mode == "threads" and delays[i] > 0:
                time.sleep(delays[i])
            worker_times[i] = dt + delays[i]
            return i, out

        t_start = time.perf_counter()
        futures: dict[int, Future] = {}
        results: dict[int, object] = {}
        if self.mode == "threads":
            pools = self._ensure_pools()
            futures = {i: pools[i].submit(work, i) for i in range(self.n)}
        else:  # simulated clock: compute all live workers synchronously
            for i in range(self.n):
                if np.isfinite(delays[i]):
                    _, out = work(i)
                    results[i] = out
        return PendingBatch(futures, results, worker_times, t_start)

    def ready(self, pending: PendingBatch, delta: int) -> bool:
        """Non-blocking: would ``collect`` return without waiting?  True
        once delta subtasks finished cleanly — or once *every* future is
        done (possibly with failures), so a degraded round reports ready
        and lets ``collect`` raise ``ClusterDegraded`` instead of the
        engine polling it forever."""
        if self.mode != "threads":
            return True  # simulated: results were computed at submit time
        done = [f for f in pending.futures.values() if f.done()]
        ok = sum(1 for f in done if f.exception() is None)
        return ok >= delta or len(done) == len(pending.futures)

    def collect(self, pending: PendingBatch, delta: int):
        results = dict(pending.results)
        if self.mode == "threads":
            results = {}
            outstanding = set(pending.futures.values())
            while len(results) < delta and outstanding:
                done, outstanding = wait(outstanding, return_when=FIRST_COMPLETED)
                for f in done:
                    try:
                        i, out = f.result()
                        results[i] = out
                    except RuntimeError:
                        pass
            t_compute = time.perf_counter() - pending.t_start
            for f in outstanding:  # abandon stragglers, don't join them
                f.cancel()
        else:  # completion time = max simulated clock over the chosen delta
            order = sorted(results, key=lambda i: pending.worker_times[i])
            results = {i: results[i] for i in order[:delta]}
            t_compute = (
                max(pending.worker_times[i] for i in results)
                if results else float("inf")
            )
        return results, list(pending.worker_times), t_compute


class DeviceWorkerPool:
    """n coded workers pinned one-per-``jax.Device`` (round-robin when the
    mesh is smaller), with per-device resident filters and per-device jit
    caches.  See the module docstring for the dispatch/reap model."""

    kind = "device"

    # adaptive collect-poll bounds: start near the old fixed 50µs period
    # (well under one subtask), back off exponentially toward 1ms while
    # nothing lands so a master parked on a long worker round stops
    # burning a core, reset on every reaped result
    _POLL_MIN = 5e-6
    _POLL_MAX = 1e-3

    def __init__(self, n: int, straggler: StragglerModel, *, devices=None,
                 mesh=None, poll_interval_s: float | None = None):
        from repro.launch.mesh import make_worker_mesh
        from repro.sharding import worker_devices

        self.n = n
        self.straggler = straggler
        self.mesh = mesh if mesh is not None else make_worker_mesh(n, devices)
        self.devices = worker_devices(self.mesh, n)  # len n (round-robin)
        # decode runs on the master device: where the default jit places it
        self.master = jax.devices()[0]
        # None = adaptive exponential backoff; a number = fixed period
        # (kept as the deterministic override for tests)
        self._poll_interval_s = poll_interval_s
        # per-(program key, device) jit cache: a separate jax.jit object per
        # device keeps trace accounting per device (one shared jit would
        # pool every device's specializations in one opaque cache), so the
        # engine thread (hot path get-or-create) and caller threads
        # (load/unload placement) share these registries
        self._state_lock = threading.RLock()
        # bounded-program contract can be asserted device by device
        self._programs: dict[tuple, object] = {}  # guarded-by: self._state_lock
        # resident filter shards: name -> (master ke ref, [per-device shard])
        # — keyed by the cluster's namespaced layer name, invalidated by
        # master-array identity so re-encoded filters are re-placed
        self._filters: dict[str, tuple] = {}  # guarded-by: self._state_lock
        self._timers: set[threading.Timer] = set()  # guarded-by: self._timer_lock
        self._timer_lock = threading.Lock()

    # -- lifecycle ---------------------------------------------------------
    def shutdown(self) -> None:
        """Cancel undelivered delayed dispatches and drop device-resident
        state (programs and filter shards re-materialize lazily on reuse)."""
        with self._timer_lock:
            timers, self._timers = set(self._timers), set()
        for t in timers:
            t.cancel()
        with self._state_lock:
            self._programs.clear()
            self._filters.clear()

    # -- program/filter placement ------------------------------------------
    def program(self, key: tuple, raw, i: int, jit_cache: dict = None):
        dev = self.devices[i]
        with self._state_lock:
            fn = self._programs.get((key, dev))
            if fn is None:
                fn = self._programs[(key, dev)] = jax.jit(raw)
            return fn

    def program_traces(self) -> dict:
        """Per-device jit-trace counts ``{device: traces}`` — the device
        pool's half of the bounded-program contract."""
        out: dict = {}
        with self._state_lock:
            programs = dict(self._programs)
        for (_, dev), fn in programs.items():
            out[dev] = out.get(dev, 0) + fn._cache_size()
        return out

    def resident_filters(self, name: str, ke) -> list:
        """The per-device shard list for coded filters ``ke`` under the
        namespaced layer ``name`` — placed once (the paper's pre-stored
        filters), reused until ``ke`` is a different array."""
        with self._state_lock:
            ent = self._filters.get(name)
            if ent is None or ent[0] is not ke:
                shards = [jax.device_put(ke[i], self.devices[i])
                          for i in range(self.n)]
                for s in shards:
                    s.block_until_ready()
                ent = self._filters[name] = (ke, shards)
            return ent[1]

    def drop_filters(self, prefix: str) -> None:
        with self._state_lock:
            for name in [k for k in self._filters if k.startswith(prefix)]:
                del self._filters[name]

    def gather(self, arr):
        """One surviving shard to the master device (decode gathers only
        the fastest delta — discarded shards never move)."""
        return jax.device_put(arr, self.master)

    def warm(self, fn, xe, ke) -> None:
        """Compile the worker program on every live device (per-device jit
        caches) outside the timed collect."""
        outs = []
        for i in range(self.n):
            if np.isfinite(self.straggler.delays[i]):
                outs.append(fn(i)(
                    jax.device_put(xe[i], self.devices[i]), _ke_of(ke, i)
                ))
        for o in outs:
            o.block_until_ready()

    # -- dispatch / reap ---------------------------------------------------
    def submit(self, fn, xe, ke) -> PendingBatch:
        delays = self.straggler.delays
        worker_times = [
            float("inf") if not np.isfinite(delays[i]) else float("nan")
            for i in range(self.n)
        ]
        results: dict[int, object] = {}
        lock = threading.Lock()
        t_start = time.perf_counter()
        pending = PendingBatch({}, results, worker_times, t_start,
                               expected=set(), lock=lock)

        def dispatch(i):
            # async: enqueues on device i's queue and returns immediately
            out = fn(i)(jax.device_put(xe[i], self.devices[i]), _ke_of(ke, i))
            with lock:
                results[i] = out

        for i in range(self.n):
            if not np.isfinite(delays[i]):
                continue  # dead worker: never dispatched
            pending.expected.add(i)
            if delays[i] > 0:
                # injected straggler = delayed dispatch (simulated network/
                # queueing delay ahead of the subtask)
                self._defer(float(delays[i]), i, dispatch)
            else:
                dispatch(i)
        return pending

    def _defer(self, delay: float, i: int, dispatch) -> None:
        def run():
            try:
                dispatch(i)
            finally:
                with self._timer_lock:
                    self._timers.discard(timer)

        timer = threading.Timer(delay, run)
        timer.daemon = True
        with self._timer_lock:
            self._timers.add(timer)
        timer.start()

    def ready(self, pending: PendingBatch, delta: int) -> bool:
        """Non-blocking: are ``delta`` (or all expected, for degraded
        rounds) results resident and ready to reap right now?"""
        need = min(delta, len(pending.expected))
        with pending.lock:
            avail = list(pending.results.values())
        return sum(1 for a in avail if a.is_ready()) >= need

    def collect(self, pending: PendingBatch, delta: int):
        """Poll per-array readiness until the fastest ``delta`` devices have
        delivered; later arrivals are discarded (their device finishes the
        subtask, naturally backpressuring its own next dispatch, but the
        array is never gathered).  The poll period backs off exponentially
        while no result lands and resets on progress (or stays fixed when
        an explicit ``poll_interval_s`` was given)."""
        need = min(delta, len(pending.expected))
        reaped: dict[int, object] = {}
        sleep_s = self._POLL_MIN
        while len(reaped) < need:
            with pending.lock:
                avail = {i: a for i, a in pending.results.items()
                         if i not in reaped}
            progressed = False
            for i, a in avail.items():
                if a.is_ready():
                    reaped[i] = a
                    pending.worker_times[i] = \
                        time.perf_counter() - pending.t_start
                    progressed = True
                    if len(reaped) >= delta:
                        break
            if len(reaped) >= need:
                break
            if progressed:
                sleep_s = self._POLL_MIN
            elif self._poll_interval_s is not None:
                time.sleep(self._poll_interval_s)
            else:
                time.sleep(sleep_s)
                sleep_s = min(sleep_s * 2, self._POLL_MAX)
        t_compute = time.perf_counter() - pending.t_start
        return reaped, list(pending.worker_times), t_compute


def _ke_of(ke, i: int):
    """Worker i's filter shard: list = pre-placed per-device shards
    (device pool resident filters), array = indexed master copy."""
    return ke[i]
