"""Simulated master/worker cluster for FCDCC.

Mirrors the paper's mpi4py methodology on one host: a thread pool of n
workers, per-worker injected delays (``sleep()``-style stragglers, as in
Experiment 4), random unavailability, and hard failures.  The master
collects the *fastest delta* results and decodes immediately — later
arrivals are discarded, exactly like the paper's asynchronous collection.

The cluster is **persistent**: jitted worker programs and encoded filters
are cached across calls, so repeated ``run_layer``s (and every layer of a
``run_pipeline``) pay encode+jit once — the paper's deployment model where
coded filters are pre-stored on the workers.

Entry points:
  * ``run_layer`` — one FCDCC ConvL end-to-end with timing breakdown
    (encode / upload / compute / download / decode), simulated-clock mode
    for deterministic tests and real-thread mode for wall-clock numbers.
  * ``load_pipeline`` / ``run_pipeline`` — stream a whole CNN ConvL stack
    (a ``repro.core.pipeline.CodedPipeline`` with resident coded filters)
    through the cluster for batched ``(B, C, H, W)`` inputs, returning the
    output plus per-layer ``LayerTiming``.
  * elastic recovery: if more than gamma workers fail outright, the master
    re-plans with a smaller (k_a, k_b) grid (fewer subtasks) and re-runs —
    the framework-level restart path.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait

import jax
import numpy as np

from repro.core.fcdcc import CodedConv2d, FcdccPlan
from repro.core.partition import ConvGeometry
from repro.core.pipeline import CodedPipeline


@dataclasses.dataclass
class StragglerModel:
    """Per-worker latency injection (seconds added to compute time)."""

    delays: np.ndarray  # (n,) extra seconds; np.inf = dead worker

    @staticmethod
    def none(n: int) -> "StragglerModel":
        return StragglerModel(np.zeros(n))

    @staticmethod
    def fixed(n: int, stragglers: int, delay: float, seed: int = 0) -> "StragglerModel":
        rng = np.random.default_rng(seed)
        d = np.zeros(n)
        idx = rng.choice(n, size=stragglers, replace=False)
        d[idx] = delay
        return StragglerModel(d)

    @staticmethod
    def random_uniform(n: int, p: float, delay: float, seed: int = 0) -> "StragglerModel":
        rng = np.random.default_rng(seed)
        return StragglerModel(np.where(rng.random(n) < p, delay, 0.0))


@dataclasses.dataclass
class LayerTiming:
    encode_s: float
    compute_s: float  # master-visible completion time of the delta-th result
    decode_s: float
    worker_compute_s: list
    used_workers: list
    name: str = ""

    @property
    def total_s(self):
        return self.encode_s + self.compute_s + self.decode_s


class FcdccCluster:
    """n simulated workers executing coded conv subtasks.

    Persistent state across calls: jitted worker programs (keyed by the
    worker-program signature), per-layer ``CodedConv2d`` instances, and
    resident coded filters (from ``preload_filters`` or ``load_pipeline``).
    """

    def __init__(self, plan: FcdccPlan, straggler: StragglerModel | None = None,
                 mode: str = "threads", backend: str = "lax"):
        assert mode in ("threads", "simulated")
        self.plan = plan
        self.straggler = straggler or StragglerModel.none(plan.n)
        self.mode = mode
        self.backend = backend
        # persistent caches ------------------------------------------------
        self._coded_layers: dict[tuple, CodedConv2d] = {}
        self._programs: dict[tuple, object] = {}
        # resident coded filters: one entry per layer name (re-planning a
        # layer replaces its entry rather than accumulating), guarded by the
        # filter-code key so filters encoded under one code never serve a
        # different plan's decode.  Entry: (code_key, coded_filters, src).
        self._resident: dict[str, tuple] = {}
        self.pipeline: CodedPipeline | None = None

    @property
    def n(self) -> int:
        return self.plan.n

    # -- persistent program/filter caches ---------------------------------
    def coded_layer(self, geo: ConvGeometry, plan: FcdccPlan | None = None) -> CodedConv2d:
        plan = plan or self.plan
        key = (plan, geo)
        layer = self._coded_layers.get(key)
        if layer is None:
            layer = self._coded_layers[key] = CodedConv2d(
                plan, geo, backend=self.backend
            )
        return layer

    def worker_program(self, layer: CodedConv2d):
        """Jitted one-worker program, shared by layers with the same
        signature (re-jit across ``run_layer`` calls eliminated)."""
        key = (layer.plan.ell_a, layer.plan.ell_b, layer.geo.stride)
        fn = self._programs.get(key)
        if fn is None:
            fn = self._programs[key] = jax.jit(layer.worker_compute)
        return fn

    @staticmethod
    def _filter_code_key(plan: FcdccPlan, geo: ConvGeometry) -> tuple:
        """The parts of (plan, geo) that determine ``encode_filters`` output.
        Coded filters are input-resolution independent, so H/W/stride/padding
        are deliberately excluded — one preload serves any input size."""
        return (plan, geo.in_channels, geo.out_channels,
                geo.kernel_h, geo.kernel_w)

    def preload_filters(self, name: str, geo: ConvGeometry, k,
                        plan: FcdccPlan | None = None):
        """Encode ``k`` once and keep the coded filters resident under
        ``name`` (the deployment case: filters pre-stored on workers)."""
        plan = plan or self.plan
        layer = self.coded_layer(geo, plan)
        ke = jax.block_until_ready(layer.encode_filters(k))
        self._resident[name] = (self._filter_code_key(plan, geo), ke, k)
        return ke

    def load_pipeline(self, pipeline: CodedPipeline) -> None:
        """Adopt a compiled ``CodedPipeline``: its (already encoded, exactly
        once) coded filters become resident on this cluster's workers."""
        if pipeline.n != self.n:
            raise ValueError(f"pipeline targets n={pipeline.n}, cluster has n={self.n}")
        self.pipeline = pipeline
        for spec, ke in zip(pipeline.specs, pipeline.coded_filters):
            key = self._filter_code_key(spec.plan, spec.geo)
            self._resident[spec.name] = (key, ke, pipeline)

    # -- fastest-delta collection ------------------------------------------
    def _collect(self, compute_one, xe, ke, n: int, delta: int):
        """Dispatch n coded subtasks, return (results, worker_times, t_compute)
        with exactly the fastest delta results kept (master discards the
        rest, as in the paper's asynchronous collection)."""
        worker_times = [0.0] * n
        results: dict[int, object] = {}

        def work(i):
            if not np.isfinite(self.straggler.delays[i]):
                raise RuntimeError(f"worker {i} failed")
            t = time.perf_counter()
            out = jax.block_until_ready(compute_one(xe[i], ke[i]))
            dt = time.perf_counter() - t
            if self.mode == "threads" and self.straggler.delays[i] > 0:
                time.sleep(self.straggler.delays[i])
            worker_times[i] = dt + self.straggler.delays[i]
            return i, out

        t1 = time.perf_counter()
        if self.mode == "threads":
            ex = ThreadPoolExecutor(max_workers=n)
            futs = {ex.submit(work, i) for i in range(n)}
            pending = set(futs)
            while len(results) < delta and pending:
                done, pending = wait(pending, return_when=FIRST_COMPLETED)
                for f in done:
                    try:
                        i, out = f.result()
                        results[i] = out
                    except RuntimeError:
                        pass
            # fastest-delta collected; do NOT join stragglers (the paper's
            # asynchronous master discards them)
            t_compute = time.perf_counter() - t1
            ex.shutdown(wait=False, cancel_futures=True)
        else:  # simulated clock: compute all, completion = max over chosen
            for i in range(n):
                if np.isfinite(self.straggler.delays[i]):
                    _, out = work(i)
                    results[i] = out
            order = sorted(results, key=lambda i: worker_times[i])
            results = {i: results[i] for i in order[:delta]}
            t_compute = max(worker_times[i] for i in results) if results else float("inf")

        if len(results) < delta:
            raise ClusterDegraded(
                f"only {len(results)} of delta={delta} results; "
                f"gamma={n - delta} exceeded"
            )
        return results, worker_times, t_compute

    # -- one ConvL ----------------------------------------------------------
    def run_layer(self, geo: ConvGeometry, x, k=None, *, coded_filters=None,
                  layer_name: str | None = None,
                  plan: FcdccPlan | None = None) -> tuple:
        """Returns (y, LayerTiming).  ``x`` may be ``(C, H, W)`` or a
        ``(B, C, H, W)`` batch.  Filters come from, in priority order:
        ``coded_filters`` (pre-encoded), the resident store under
        ``layer_name``, or ``k`` (encoded now and — when ``layer_name`` is
        given — cached resident for next time)."""
        plan = plan or self.plan
        layer = self.coded_layer(geo, plan)
        n, delta = plan.n, plan.delta

        t0 = time.perf_counter()
        xe = jax.block_until_ready(layer.encode_inputs(x))
        ke = coded_filters
        code_key = self._filter_code_key(plan, geo)
        if ke is None and layer_name is not None:
            # resident hit only under the same filter-code key AND when the
            # caller passed no weights or the *same* weights object the cache
            # was built from — a plan change or new weights under an old name
            # re-encode rather than silently decoding against filters coded
            # with the wrong matrices
            ent = self._resident.get(layer_name)
            if ent is not None and ent[0] == code_key and (
                k is None or ent[2] is k
            ):
                ke = ent[1]
        if ke is None:
            if k is None:
                raise ValueError("need k, coded_filters, or resident layer_name")
            ke = jax.block_until_ready(layer.encode_filters(k))
            if layer_name is not None:
                self._resident[layer_name] = (code_key, ke, k)
        t_encode = time.perf_counter() - t0

        compute = self.worker_program(layer)
        # warm the kernel once so per-worker timings measure steady state
        # (cached: a no-op re-run after the first call with these shapes)
        jax.block_until_ready(compute(xe[0], ke[0]))

        results, worker_times, t_compute = self._collect(compute, xe, ke, n, delta)

        ids = list(results)[:delta]
        outs = np.stack([np.asarray(results[i]) for i in ids], axis=0)
        t2 = time.perf_counter()
        y = jax.block_until_ready(layer.decode(ids, jax.numpy.asarray(outs)))
        t_decode = time.perf_counter() - t2
        return y, LayerTiming(t_encode, t_compute, t_decode, worker_times, ids,
                              layer_name or "")

    # -- whole network ------------------------------------------------------
    def run_pipeline(self, x, pipeline: CodedPipeline | None = None) -> tuple:
        """Stream a batched ``(B, C, H, W)`` input (or one ``(C, H, W)``
        image) through every ConvL of the loaded pipeline.

        Each layer runs the full master/worker round on the cluster —
        encode inputs, dispatch n coded subtasks against the *resident*
        coded filters, keep the fastest delta, decode + relu + pool — and
        contributes one ``LayerTiming``.  Returns ``(y, [LayerTiming])``.
        """
        if pipeline is not None:
            self.load_pipeline(pipeline)
        pipe = self.pipeline
        if pipe is None:
            raise ValueError("no pipeline loaded; call load_pipeline() first")

        squeeze = x.ndim == 3
        if squeeze:
            x = x[None]
        timings = []
        for idx, spec in enumerate(pipe.specs):
            delta = spec.plan.delta
            # the pipeline's own filters, not the name-keyed store: a later
            # preload/run_layer under a colliding layer name must not swap
            # in foreign filters under this pipeline's decode
            ke = pipe.coded_filters[idx]

            t0 = time.perf_counter()
            xe = jax.block_until_ready(pipe.encoder(idx)(x))
            t_encode = time.perf_counter() - t0

            compute = pipe.worker_program(idx, over_workers=False)
            jax.block_until_ready(compute(xe[0], ke[0]))  # steady-state warm
            results, worker_times, t_compute = self._collect(
                compute, xe, ke, self.n, delta
            )

            ids = list(results)[:delta]
            outs = np.stack([np.asarray(results[i]) for i in ids], axis=0)
            t2 = time.perf_counter()
            x = jax.block_until_ready(
                pipe.decoder(idx, tuple(ids))(jax.numpy.asarray(outs))
            )
            t_decode = time.perf_counter() - t2
            timings.append(
                LayerTiming(t_encode, t_compute, t_decode, worker_times, ids,
                            spec.name)
            )
        return (x[0] if squeeze else x), timings


class ClusterDegraded(RuntimeError):
    pass


def run_layer_elastic(plan: FcdccPlan, geo: ConvGeometry, x, k,
                      straggler: StragglerModel, mode="simulated", max_retries=2):
    """Elastic recovery: on ClusterDegraded, shrink the subtask grid
    (halve k_a or k_b -> smaller delta) and retry on the surviving workers."""
    attempt_plan = plan
    for attempt in range(max_retries + 1):
        cluster = FcdccCluster(attempt_plan, straggler, mode=mode)
        try:
            y, timing = cluster.run_layer(geo, x, k)
            return y, timing, attempt_plan
        except ClusterDegraded:
            k_a, k_b = attempt_plan.k_a, attempt_plan.k_b
            if k_a >= k_b and k_a > 1:
                k_a = max(k_a // 2, 1)
            elif k_b > 1:
                k_b = max(k_b // 2, 1)
            else:
                raise
            attempt_plan = FcdccPlan(n=plan.n, k_a=k_a, k_b=k_b)
    raise ClusterDegraded("elastic retries exhausted")
