"""Simulated master/worker cluster for FCDCC.

Mirrors the paper's mpi4py methodology on one host: a thread pool of n
workers, per-worker injected delays (``sleep()``-style stragglers, as in
Experiment 4), random unavailability, and hard failures.  The master
collects the *fastest delta* results and decodes immediately — later
arrivals are discarded, exactly like the paper's asynchronous collection.

Also provides:
  * ``run_layer`` — one FCDCC ConvL end-to-end with timing breakdown
    (encode / upload / compute / download / decode), simulated-clock mode
    for deterministic tests and real-thread mode for wall-clock numbers.
  * elastic recovery: if more than gamma workers fail outright, the master
    re-plans with a smaller (k_a, k_b) grid (fewer subtasks) and re-runs —
    the framework-level restart path.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait

import jax
import numpy as np

from repro.core.fcdcc import CodedConv2d, FcdccPlan
from repro.core.partition import ConvGeometry


@dataclasses.dataclass
class StragglerModel:
    """Per-worker latency injection (seconds added to compute time)."""

    delays: np.ndarray  # (n,) extra seconds; np.inf = dead worker

    @staticmethod
    def none(n: int) -> "StragglerModel":
        return StragglerModel(np.zeros(n))

    @staticmethod
    def fixed(n: int, stragglers: int, delay: float, seed: int = 0) -> "StragglerModel":
        rng = np.random.default_rng(seed)
        d = np.zeros(n)
        idx = rng.choice(n, size=stragglers, replace=False)
        d[idx] = delay
        return StragglerModel(d)

    @staticmethod
    def random_uniform(n: int, p: float, delay: float, seed: int = 0) -> "StragglerModel":
        rng = np.random.default_rng(seed)
        return StragglerModel(np.where(rng.random(n) < p, delay, 0.0))


@dataclasses.dataclass
class LayerTiming:
    encode_s: float
    compute_s: float  # master-visible completion time of the delta-th result
    decode_s: float
    worker_compute_s: list
    used_workers: list

    @property
    def total_s(self):
        return self.encode_s + self.compute_s + self.decode_s


class FcdccCluster:
    """n simulated workers executing coded conv subtasks."""

    def __init__(self, plan: FcdccPlan, straggler: StragglerModel | None = None,
                 mode: str = "threads", backend: str = "lax"):
        assert mode in ("threads", "simulated")
        self.plan = plan
        self.straggler = straggler or StragglerModel.none(plan.n)
        self.mode = mode
        self.backend = backend

    def run_layer(self, geo: ConvGeometry, x, k, *, coded_filters=None) -> tuple:
        """Returns (y, LayerTiming).  ``coded_filters`` may be pre-encoded
        (the deployment case where filters are resident on workers)."""
        layer = CodedConv2d(self.plan, geo, backend=self.backend)
        n, delta = self.plan.n, self.plan.delta

        t0 = time.perf_counter()
        xe = jax.block_until_ready(layer.encode_inputs(x))
        ke = coded_filters
        if ke is None:
            ke = jax.block_until_ready(layer.encode_filters(k))
        t_encode = time.perf_counter() - t0

        compute = jax.jit(layer.worker_compute)
        # warm the kernel once so per-worker timings measure steady state
        jax.block_until_ready(compute(xe[0], ke[0]))

        worker_times = [0.0] * n
        results: dict[int, np.ndarray] = {}

        def work(i):
            if not np.isfinite(self.straggler.delays[i]):
                raise RuntimeError(f"worker {i} failed")
            t = time.perf_counter()
            out = jax.block_until_ready(compute(xe[i], ke[i]))
            dt = time.perf_counter() - t
            if self.mode == "threads" and self.straggler.delays[i] > 0:
                time.sleep(self.straggler.delays[i])
            worker_times[i] = dt + self.straggler.delays[i]
            return i, out

        t1 = time.perf_counter()
        if self.mode == "threads":
            ex = ThreadPoolExecutor(max_workers=n)
            futs = {ex.submit(work, i) for i in range(n)}
            pending = set(futs)
            while len(results) < delta and pending:
                done, pending = wait(pending, return_when=FIRST_COMPLETED)
                for f in done:
                    try:
                        i, out = f.result()
                        results[i] = out
                    except RuntimeError:
                        pass
            # fastest-delta collected; do NOT join stragglers (the paper's
            # asynchronous master discards them)
            t_compute = time.perf_counter() - t1
            ex.shutdown(wait=False, cancel_futures=True)
        else:  # simulated clock: compute all, completion = max over chosen
            for i in range(n):
                if np.isfinite(self.straggler.delays[i]):
                    _, out = work(i)
                    results[i] = out
            order = sorted(results, key=lambda i: worker_times[i])
            results = {i: results[i] for i in order[:delta]}
            t_compute = max(worker_times[i] for i in results) if results else float("inf")

        if len(results) < delta:
            raise ClusterDegraded(
                f"only {len(results)} of delta={delta} results; "
                f"gamma={self.plan.gamma} exceeded"
            )

        ids = list(results)[:delta]
        outs = np.stack([np.asarray(results[i]) for i in ids], axis=0)
        t2 = time.perf_counter()
        y = jax.block_until_ready(layer.decode(ids, jax.numpy.asarray(outs)))
        t_decode = time.perf_counter() - t2
        return y, LayerTiming(t_encode, t_compute, t_decode, worker_times, ids)


class ClusterDegraded(RuntimeError):
    pass


def run_layer_elastic(plan: FcdccPlan, geo: ConvGeometry, x, k,
                      straggler: StragglerModel, mode="simulated", max_retries=2):
    """Elastic recovery: on ClusterDegraded, shrink the subtask grid
    (halve k_a or k_b -> smaller delta) and retry on the surviving workers."""
    attempt_plan = plan
    for attempt in range(max_retries + 1):
        cluster = FcdccCluster(attempt_plan, straggler, mode=mode)
        try:
            y, timing = cluster.run_layer(geo, x, k)
            return y, timing, attempt_plan
        except ClusterDegraded:
            k_a, k_b = attempt_plan.k_a, attempt_plan.k_b
            if k_a >= k_b and k_a > 1:
                k_a = max(k_a // 2, 1)
            elif k_b > 1:
                k_b = max(k_b // 2, 1)
            else:
                raise
            attempt_plan = FcdccPlan(n=plan.n, k_a=k_a, k_b=k_b)
    raise ClusterDegraded("elastic retries exhausted")
