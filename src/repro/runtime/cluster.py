"""Master/worker cluster for FCDCC.

Mirrors the paper's mpi4py methodology on one host: n coded workers,
per-worker injected delays (``sleep()``-style stragglers, as in
Experiment 4), random unavailability, and hard failures.  The master
collects the *fastest delta* results and decodes immediately — later
arrivals are discarded, exactly like the paper's asynchronous collection.

Workers execute behind a pool seam (``repro.runtime.devicepool``):

  * ``pool="threads"`` — one persistent single-thread executor per worker
    on the default device (the deterministic injected-straggler mode, and
    the only executor for ``mode="simulated"``);
  * ``pool="device"`` — each worker pinned to its own ``jax.Device`` from a
    1-D worker mesh (``launch.mesh.make_worker_mesh``): coded filters
    ``device_put`` once per worker and resident, worker programs jitted per
    device, ``submit`` = pure async dispatch onto the device queues,
    ``collect`` = a per-array-readiness reaper keeping the fastest delta.
    Default whenever real parallelism is available (``mode="threads"`` on a
    multi-device host — e.g. ``XLA_FLAGS=--xla_force_host_platform_device_
    count=8`` — or real TPU/GPU devices).

The cluster is **persistent**: jitted worker programs and encoded filters
are cached across calls, so repeated ``run_layer``s (and every layer of a
``run_pipeline``) pay encode+jit once — the paper's deployment model where
coded filters are pre-stored on the workers.  The worker pool is persistent
too (``shutdown()`` releases it), so a straggler still busy with a
discarded subtask naturally backpressures *its own* node's next subtask —
exactly the behaviour of a real busy worker — while fast workers are never
blocked.

Entry points:
  * ``run_layer`` — one FCDCC ConvL end-to-end with timing breakdown
    (encode / upload / compute / download / decode), simulated-clock mode
    for deterministic tests and real-thread mode for wall-clock numbers.
  * ``submit`` / ``collect`` — the asynchronous master: dispatch n coded
    subtasks without blocking, then reap the fastest delta later.  The
    serving engine (``repro.serving``) uses this split to interleave
    layers of different in-flight request batches on one executor.
  * ``load_pipeline`` / ``run_pipeline`` / ``run_pipeline_layer`` — stream
    a whole CNN ConvL stack (a ``repro.core.pipeline.CodedPipeline`` with
    resident coded filters) through the cluster for batched
    ``(B, C, H, W)`` inputs, returning the output plus per-layer
    ``LayerTiming``.  Pipelines are *namespaced*: several models (e.g.
    lenet5 + alexnet under different ``(k_a, k_b)`` plans) stay resident
    on one shared worker pool at once — ``load_pipeline(pipe, name)`` to
    register, ``unload_pipeline(name)`` to evict, ``model=`` on the run
    entry points to select.  Resident filters and jit program caches are
    keyed per namespace, so two pipelines with colliding layer names can
    never serve each other's filters or programs.
  * elastic recovery: if more than gamma workers fail outright, the master
    re-plans with a smaller (k_a, k_b) grid (fewer subtasks) and re-runs —
    the framework-level restart path.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fcdcc import CodedConv2d, FcdccPlan
from repro.core.partition import ConvGeometry
from repro.core.pipeline import CodedPipeline

from .devicepool import (  # re-exported for back-compat  # noqa: F401
    ClusterDegraded,
    DeviceWorkerPool,
    PendingBatch,
    StragglerModel,
    ThreadWorkerPool,
    make_pool,
    resolve_pool,
)


@dataclasses.dataclass
class LayerTiming:
    encode_s: float
    compute_s: float  # master-visible completion time of the delta-th result
    decode_s: float
    # per-worker seconds: finite = measured, inf = dead worker, nan =
    # discarded before finishing (aggregate with ``finished_worker_s``)
    worker_compute_s: list
    used_workers: list
    name: str = ""

    @property
    def total_s(self):
        return self.encode_s + self.compute_s + self.decode_s

    @property
    def finished_worker_s(self) -> list:
        """Times of workers that actually finished — the only ones safe to
        average (dead = inf and discarded = nan slots are excluded)."""
        return [t for t in self.worker_compute_s if np.isfinite(t)]


@dataclasses.dataclass
class PendingRound:
    """One dispatched pipeline-layer round awaiting its collect half.

    Returned by ``dispatch_pipeline_layer`` and consumed by
    ``collect_pipeline_layer``/``round_ready``.  It captures everything the
    collect half needs — the pipeline object itself, not its registry name
    — so finishing an in-flight round stays safe even if the model is
    unregistered (``unload_pipeline``) between dispatch and collect."""

    idx: int
    pipe: CodedPipeline
    spec: object  # the layer's LayerProgramSpec
    pending: PendingBatch
    t_encode: float
    fused_mid: bool  # fused pipeline, non-final layer: transition, no decode


class FcdccCluster:
    """n workers executing coded conv subtasks behind the pool seam.

    Persistent state across calls: jitted worker programs (keyed by the
    worker-program signature — per device under ``pool="device"``),
    per-layer ``CodedConv2d`` instances, and resident coded filters (from
    ``preload_filters`` or ``load_pipeline``; per-device shards under the
    device pool).
    """

    def __init__(self, plan: FcdccPlan, straggler: StragglerModel | None = None,
                 mode: str = "threads", backend: str = "lax",
                 interpret: bool = True, pool: str | None = None,
                 devices=None):
        assert mode in ("threads", "simulated")
        self.plan = plan
        self.straggler = straggler or StragglerModel.none(plan.n)
        self.mode = mode
        self.backend = backend
        # pallas-only: True emulates worker kernels on CPU, False -> real TPU
        self.interpret = interpret
        # worker pool selection (see devicepool.resolve_pool): None picks
        # the device pool whenever real parallelism is available
        self.pool = resolve_pool(pool, mode, devices)
        self._devices = devices
        # one reentrant lock over pool creation and every persistent cache:
        # the engine thread and caller threads (load/unload/preload) hit
        # these concurrently, and the lazy pool build must not run twice
        self._registry_lock = threading.RLock()
        # built lazily on first dispatch/placement
        self._pool_obj = None  # guarded-by: self._registry_lock
        # persistent caches ------------------------------------------------
        self._coded_layers: dict[tuple, CodedConv2d] = {}  # guarded-by: self._registry_lock
        self._programs: dict[tuple, object] = {}  # guarded-by: self._registry_lock
        # resident coded filters: one entry per layer name (re-planning a
        # layer replaces its entry rather than accumulating), guarded by the
        # filter-code key so filters encoded under one code never serve a
        # different plan's decode.  Entry: (code_key, coded_filters, src).
        # Pipeline layers live under "model/layer" namespaced keys so two
        # models with the same layer names never collide.
        self._resident: dict[str, tuple] = {}  # guarded-by: self._registry_lock
        # registered pipelines by model name (insertion-ordered: the first
        # one is the default for single-model callers)
        self.pipelines: dict[str, CodedPipeline] = {}  # guarded-by: self._registry_lock
        # worker-program signatures already run once (compile happened
        # outside a timed collect); keyed by (program key, operand shapes)
        self._warmed: set[tuple] = set()  # guarded-by: self._registry_lock

    @property
    def n(self) -> int:
        return self.plan.n

    # -- persistent worker pool --------------------------------------------
    def _pool_impl(self):
        with self._registry_lock:
            if self._pool_obj is None:
                self._pool_obj = make_pool(
                    self.pool, self.n, self.straggler, mode=self.mode,
                    devices=self._devices,
                )
            return self._pool_obj

    @property
    def worker_devices(self) -> list | None:
        """Per-worker device pinning (device pool), else None."""
        impl = self._pool_impl()
        return list(impl.devices) if impl.kind == "device" else None

    @property
    def _pools(self):
        """The threads pool's executors (None for the device pool or before
        first dispatch / after shutdown) — kept for callers that assert
        pool lifecycle."""
        impl = self._pool_obj
        return impl._pools if impl is not None and impl.kind == "threads" \
            else None

    def _ensure_pools(self):
        """Back-compat: materialize the threads pool's executors."""
        impl = self._pool_impl()
        if impl.kind != "threads":
            raise RuntimeError("cluster runs the device pool; no thread "
                               "executors to materialize")
        return impl._ensure_pools()

    def shutdown(self) -> None:
        """Release the worker pool (idempotent; the cluster can be used
        again afterwards — executors and device-resident state are
        re-created lazily)."""
        with self._registry_lock:
            pool = self._pool_obj
        if pool is not None:
            pool.shutdown()

    def __del__(self):  # best-effort: interpreter teardown may race us
        try:
            self.shutdown()
        except Exception:
            pass

    def __enter__(self) -> "FcdccCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- persistent program/filter caches ---------------------------------
    def coded_layer(self, geo: ConvGeometry, plan: FcdccPlan | None = None) -> CodedConv2d:
        plan = plan or self.plan
        key = (plan, geo)
        with self._registry_lock:
            layer = self._coded_layers.get(key)
            if layer is None:
                layer = self._coded_layers[key] = CodedConv2d(
                    plan, geo, backend=self.backend, interpret=self.interpret
                )
            return layer

    def worker_program(self, layer: CodedConv2d):
        """Jitted one-worker program on the master device, shared by layers
        with the same signature (re-jit across ``run_layer`` calls
        eliminated).  The device pool compiles its own per-device twins of
        the same callable (``DeviceWorkerPool.program``)."""
        key = (layer.plan.ell_a, layer.plan.ell_b, layer.geo.stride)
        with self._registry_lock:
            fn = self._programs.get(key)
            if fn is None:
                fn = self._programs[key] = jax.jit(layer.worker_compute)
            return fn

    @staticmethod
    def _filter_code_key(plan: FcdccPlan, geo: ConvGeometry) -> tuple:
        """The parts of (plan, geo) that determine ``encode_filters`` output.
        Coded filters are input-resolution independent, so H/W/stride/padding
        are deliberately excluded — one preload serves any input size."""
        return (plan, geo.in_channels, geo.out_channels,
                geo.kernel_h, geo.kernel_w)

    def preload_filters(self, name: str, geo: ConvGeometry, k,
                        plan: FcdccPlan | None = None):
        """Encode ``k`` once and keep the coded filters resident under
        ``name`` (the deployment case: filters pre-stored on workers)."""
        plan = plan or self.plan
        layer = self.coded_layer(geo, plan)
        ke = jax.block_until_ready(layer.encode_filters(k))
        with self._registry_lock:
            self._resident[name] = (self._filter_code_key(plan, geo), ke, k)
        return ke

    def load_pipeline(self, pipeline: CodedPipeline,
                      name: str = "default") -> None:
        """Adopt a compiled ``CodedPipeline`` under the model namespace
        ``name``: its (already encoded, exactly once) coded filters become
        resident on this cluster's workers as ``"{name}/{layer}"`` entries —
        under the device pool, sharded ``device_put`` once per worker
        device.  Several pipelines coexist on the one shared pool;
        re-registering a name replaces its pipeline and resident filters."""
        if pipeline.n != self.n:
            raise ValueError(f"pipeline targets n={pipeline.n}, cluster has n={self.n}")
        # replacing a model drops ALL of its old entries first: a v2 with
        # fewer layers must not leave v1 filters reachable under the name.
        # The whole swap runs under the registry lock so the engine never
        # observes a model with v1 filters gone but v2 not yet resident.
        prefix = f"{name}/"
        with self._registry_lock:
            for stale in [k for k in self._resident if k.startswith(prefix)]:
                del self._resident[stale]
            impl = self._pool_impl()
            impl.drop_filters(prefix)
            self.pipelines[name] = pipeline
            for spec, ke in zip(pipeline.specs, pipeline.coded_filters):
                key = self._filter_code_key(spec.plan, spec.geo)
                self._resident[f"{name}/{spec.name}"] = (key, ke, pipeline)
                # device pool: scatter the filter shards to their workers
                # now, at load time — the paper's pre-stored deployment — so
                # the serving hot path never pays the placement
                impl.resident_filters(f"{name}/{spec.name}", ke)

    def unload_pipeline(self, name: str) -> None:
        """Evict model ``name``: its pipeline registration, resident
        filters, and (device pool) per-device filter shards.  Jitted worker
        programs stay cached — they are keyed by program signature, shared
        across models, and a re-registration would re-trace them anyway."""
        with self._registry_lock:
            if name not in self.pipelines:
                raise ValueError(
                    f"unknown model {name!r}; loaded: {sorted(self.pipelines)}"
                )
            del self.pipelines[name]
            prefix = f"{name}/"
            for stale in [k for k in self._resident if k.startswith(prefix)]:
                del self._resident[stale]
            self._pool_impl().drop_filters(prefix)

    @property
    def pipeline(self) -> CodedPipeline | None:
        """The default (first-registered) pipeline, for single-model
        callers; None when nothing is loaded."""
        return next(iter(self.pipelines.values()), None)

    def get_pipeline(self, model: str | None = None) -> CodedPipeline:
        """Resolve a registered pipeline.  ``model=None`` means "the only
        one" — ambiguous (and an error) once several models are loaded."""
        if not self.pipelines:
            raise ValueError("no pipeline loaded; call load_pipeline() first")
        if model is None:
            if len(self.pipelines) > 1:
                raise ValueError(
                    f"{len(self.pipelines)} pipelines loaded "
                    f"({sorted(self.pipelines)}); pass model="
                )
            return next(iter(self.pipelines.values()))
        try:
            return self.pipelines[model]
        except KeyError:
            raise ValueError(
                f"unknown model {model!r}; loaded: {sorted(self.pipelines)}"
            ) from None

    def _model_name(self, model: str | None, pipe: CodedPipeline) -> str:
        if model is not None:
            return model
        for nm, p in self.pipelines.items():
            if p is pipe:
                return nm
        return "default"

    # -- fastest-delta collection ------------------------------------------
    def submit(self, compute_one, xe, ke) -> PendingBatch:
        """Dispatch n coded subtasks without waiting (the asynchronous
        master's send phase).  The thread pool submits one subtask per
        worker onto its persistent per-worker executors (simulated mode
        computes every live worker's result now and lets ``collect`` pick
        by simulated clock); the device pool async-dispatches each subtask
        onto its worker's own device queue.  Pair with ``collect``;
        ``run_layer``/``run_pipeline`` do.

        ``worker_times`` starts as inf for dead workers and nan for live
        ones; a worker overwrites its slot only when it finishes.  A
        ``collect`` snapshot therefore reads inf = dead, nan = discarded
        before finishing, finite = measured — a dead node can never be
        mistaken for the fastest one."""
        return self._pool_impl().submit(lambda i: compute_one, xe, ke)

    def collect(self, pending: PendingBatch, delta: int, *,
                block: bool = True):
        """Reap the fastest ``delta`` results of a ``submit``; returns
        ``(results, worker_times, t_compute)``.  Later arrivals are
        discarded, exactly like the paper's asynchronous collection —
        straggler subtasks are never joined (their own node stays busy
        finishing them, nobody waits).  ``worker_times`` is a snapshot:
        stragglers finishing after return write into the live list, not
        the one handed back.

        ``block=False`` is the reaper form: return ``None`` immediately
        when the round is not ready yet (the serving engine uses this to
        reap whichever of several in-flight rounds finishes first)."""
        impl = self._pool_impl()
        if not block and not impl.ready(pending, delta):
            return None
        results, worker_times, t_compute = impl.collect(pending, delta)
        if len(results) < delta:
            raise ClusterDegraded(
                f"only {len(results)} of delta={delta} results; "
                f"gamma={self.n - delta} exceeded"
            )
        return results, worker_times, t_compute

    def _collect(self, compute_one, xe, ke, n: int, delta: int):
        """Submit + collect in one blocking call (the pre-serving API)."""
        assert n == self.n, (n, self.n)
        return self.collect(self.submit(compute_one, xe, ke), delta)

    def _gather_outs(self, results: dict, delta: int):
        """The surviving-shard gather feeding decode: the fastest delta
        worker outputs (sorted by worker id — any delta-subset decodes
        exactly, and a canonical order keeps the decode bit-stable across
        pools and completion orders), stacked on the master device.  Under
        the device pool each surviving shard is ``device_put`` from its
        worker device (discarded shards never move); the thread pool's
        results already live there."""
        impl = self._pool_impl()
        ids = sorted(results)[:delta]
        outs = jnp.stack([impl.gather(results[i]) for i in ids], axis=0)
        return ids, outs

    # -- one ConvL ----------------------------------------------------------
    def run_layer(self, geo: ConvGeometry, x, k=None, *, coded_filters=None,
                  layer_name: str | None = None,
                  plan: FcdccPlan | None = None) -> tuple:
        """Returns (y, LayerTiming).  ``x`` may be ``(C, H, W)`` or a
        ``(B, C, H, W)`` batch.  Filters come from, in priority order:
        ``coded_filters`` (pre-encoded), the resident store under
        ``layer_name``, or ``k`` (encoded now and — when ``layer_name`` is
        given — cached resident for next time)."""
        plan = plan or self.plan
        layer = self.coded_layer(geo, plan)
        n, delta = plan.n, plan.delta

        t0 = time.perf_counter()
        xe = jax.block_until_ready(layer.encode_inputs(x))
        ke = coded_filters
        code_key = self._filter_code_key(plan, geo)
        if ke is None and layer_name is not None:
            # resident hit only under the same filter-code key AND when the
            # caller passed no weights or the *same* weights object the cache
            # was built from — a plan change or new weights under an old name
            # re-encode rather than silently decoding against filters coded
            # with the wrong matrices
            ent = self._resident.get(layer_name)
            if ent is not None and ent[0] == code_key and (
                k is None or ent[2] is k
            ):
                ke = ent[1]
        if ke is None:
            if k is None:
                raise ValueError("need k, coded_filters, or resident layer_name")
            ke = jax.block_until_ready(layer.encode_filters(k))
            if layer_name is not None:
                with self._registry_lock:
                    self._resident[layer_name] = (code_key, ke, k)
        t_encode = time.perf_counter() - t0

        impl = self._pool_impl()
        pkey = (layer.plan.ell_a, layer.plan.ell_b, layer.geo.stride)
        fn = lambda i: impl.program(pkey, layer.worker_compute, i,  # noqa: E731
                                    self._programs)
        if impl.kind == "device":
            # filter shards live on the worker devices (identity-cached)
            ke = impl.resident_filters(layer_name or "__layer", ke)
        # warm the kernel on first sight of these shapes so per-worker
        # timings measure steady state (skipped once warmed — re-running
        # would execute a whole discarded subtask, not a cache no-op)
        wkey = (self.pool,) + pkey + (tuple(xe.shape), tuple(_ke_of(ke, 0).shape))
        if wkey not in self._warmed:
            impl.warm(fn, xe, ke)  # outside the lock: warm may compile
            with self._registry_lock:
                self._warmed.add(wkey)

        pending = impl.submit(fn, xe, ke)
        results, worker_times, t_compute = self.collect(pending, delta)

        ids, outs = self._gather_outs(results, delta)
        t2 = time.perf_counter()
        y = jax.block_until_ready(layer.decode(ids, outs))
        t_decode = time.perf_counter() - t2
        return y, LayerTiming(t_encode, t_compute, t_decode, worker_times, ids,
                              layer_name or "")

    # -- whole network ------------------------------------------------------
    def dispatch_pipeline_layer(self, idx: int, x,
                                model: str | None = None) -> PendingRound:
        """The send half of one pipeline-layer round: encode the batched
        input (or adopt the previous fused round's coded shares), warm the
        worker program on first sight of these shapes, and async-dispatch
        the n coded subtasks.  Returns a ``PendingRound`` for
        ``round_ready``/``collect_pipeline_layer``.

        The serving engine calls this for batch B *before* collecting
        batch A, so A's master-side collect/decode/transition overlaps B's
        worker compute (round pipelining).  Dispatch order is the only
        thing pipelining changes — each round's arithmetic (and therefore
        its fp32 bits, for a given survivor subset) is untouched."""
        pipe = self.get_pipeline(model)
        spec = pipe.specs[idx]
        fused = pipe.fuse_transitions
        last = idx == len(pipe.specs) - 1
        # the pipeline's own filters, not the name-keyed store: a later
        # preload/run_layer under a colliding layer name must not swap
        # in foreign filters under this pipeline's decode
        ke = pipe.coded_filters[idx]

        t0 = time.perf_counter()
        if fused and idx > 0:
            xe = x  # coded shares from the previous round's transition
            t_encode = 0.0
        else:
            xe = jax.block_until_ready(pipe.encoder(idx)(x))
            t_encode = time.perf_counter() - t0

        impl = self._pool_impl()
        fn = lambda i: impl.program(  # noqa: E731
            spec.program_key, pipe.layers[idx].worker_compute, i,
            pipe._cluster_programs,
        )
        if impl.kind == "device":
            name = self._model_name(model, pipe)
            ke = impl.resident_filters(f"{name}/{spec.name}", ke)
        # first sight of these shapes: compile outside the timed collect so
        # per-worker timings measure steady state.  Once warmed it's skipped
        # — the serving hot path must not pay a discarded subtask per layer.
        wkey = (self.pool, spec.program_key, tuple(xe.shape),
                tuple(_ke_of(ke, 0).shape))
        if wkey not in self._warmed:
            impl.warm(fn, xe, ke)  # outside the lock: warm may compile
            with self._registry_lock:
                self._warmed.add(wkey)
        pending = impl.submit(fn, xe, ke)
        return PendingRound(idx, pipe, spec, pending, t_encode,
                            fused_mid=fused and not last)

    def round_ready(self, rnd: PendingRound) -> bool:
        """Non-blocking: would ``collect_pipeline_layer(rnd)`` return
        without waiting on the pool?"""
        return self._pool_impl().ready(rnd.pending, rnd.spec.plan.delta)

    def collect_pipeline_layer(self, rnd: PendingRound) -> tuple:
        """The reap half: keep the fastest delta of the dispatched round,
        then decode + relu + pool (or the fused partition-resident
        transition).  Returns ``(y, LayerTiming)``."""
        pipe, spec = rnd.pipe, rnd.spec
        delta = spec.plan.delta
        results, worker_times, t_compute = self.collect(rnd.pending, delta)

        ids, outs = self._gather_outs(results, delta)
        t2 = time.perf_counter()
        if rnd.fused_mid:
            # partition-resident transition straight into the next layer's
            # coded shares for ALL n workers (the next collect again keeps
            # whichever delta finish first); the all-n encode columns are a
            # per-layer constant resident on device
            d = jnp.asarray(pipe.decode_matrix(rnd.idx, tuple(ids)))
            y = jax.block_until_ready(
                pipe.transition_fn(rnd.idx)(
                    outs, d, pipe.encode_columns_all(rnd.idx + 1),
                )
            )
        else:
            y = jax.block_until_ready(
                pipe.decoder(rnd.idx, tuple(ids))(outs)
            )
        t_decode = time.perf_counter() - t2
        return y, LayerTiming(rnd.t_encode, t_compute, t_decode, worker_times,
                              ids, spec.name)

    def run_pipeline_layer(self, idx: int, x, model: str | None = None) -> tuple:
        """One ConvL of a loaded pipeline as a full master/worker round:
        encode inputs, dispatch n coded subtasks against the *resident*
        coded filters, keep the fastest delta, decode + relu + pool.
        Returns ``(y, LayerTiming)`` for the batched ``(B, C, H, W)`` input.

        This is the layer-granular step the serving engine interleaves
        across concurrent request batches — of all registered models —
        (``repro.serving.CodedServer`` admits new arrivals exactly at these
        layer boundaries, and with ``pipeline_depth > 1`` keeps several
        such rounds in flight via the dispatch/collect halves above).
        ``model`` selects the pipeline namespace.

        With a ``fuse_transitions`` pipeline the state carried between
        rounds is *partition-resident*: layer 0 takes the raw
        ``(B, C, H, W)`` batch and encodes it; every non-final round
        returns the next layer's coded input shares
        ``(n, ell_a, B, C, h_hat, Wp)`` (the fastest-delta outputs are
        decoded only to the partition grid, relu/pool run per partition
        with halo exchange, and the re-encode targets all n workers so the
        next round again keeps the fastest delta); only the final round
        merges to the full tensor.  ``x`` for ``idx > 0`` must then be the
        shares returned by the previous round.  The transition replaces the
        separate encode step, so ``encode_s`` is folded into ``decode_s``
        for those rounds.
        """
        return self.collect_pipeline_layer(
            self.dispatch_pipeline_layer(idx, x, model)
        )

    def run_pipeline(self, x, pipeline: CodedPipeline | None = None,
                     model: str | None = None) -> tuple:
        """Stream a batched ``(B, C, H, W)`` input (or one ``(C, H, W)``
        image) through every ConvL of a loaded pipeline (``model`` selects
        the namespace; passing ``pipeline`` registers it first).

        Each layer is one ``run_pipeline_layer`` master/worker round and
        contributes one ``LayerTiming``.  Returns ``(y, [LayerTiming])``.
        """
        if pipeline is not None:
            # an explicitly passed pipeline is never ambiguous: it runs
            # under its own (or the default) namespace even when other
            # models are already resident
            model = model if model is not None else "default"
            self.load_pipeline(pipeline, model)
        pipe = self.get_pipeline(model)

        squeeze = x.ndim == 3
        if squeeze:
            x = x[None]
        timings = []
        for idx in range(len(pipe.specs)):
            x, timing = self.run_pipeline_layer(idx, x, model)
            timings.append(timing)
        return (x[0] if squeeze else x), timings


def _ke_of(ke, i: int):
    """Worker i's filter shard (list = per-device shards, array = master)."""
    return ke[i]


def run_layer_elastic(plan: FcdccPlan, geo: ConvGeometry, x, k,
                      straggler: StragglerModel, mode="simulated",
                      max_retries=2, pool: str | None = None, devices=None):
    """Elastic recovery: on ClusterDegraded, shrink the subtask grid
    (halve k_a or k_b -> smaller delta) and retry on the surviving workers.
    ``pool``/``devices`` select the worker pool for every attempt (the
    re-plan keeps running on the surviving devices)."""
    attempt_plan = plan
    for attempt in range(max_retries + 1):
        # context-managed: each attempt's n single-thread executors are
        # released on exit instead of leaking until interpreter teardown
        with FcdccCluster(attempt_plan, straggler, mode=mode, pool=pool,
                          devices=devices) as cluster:
            try:
                y, timing = cluster.run_layer(geo, x, k)
                return y, timing, attempt_plan
            except ClusterDegraded:
                k_a, k_b = attempt_plan.k_a, attempt_plan.k_b
                if k_a >= k_b and k_a > 1:
                    k_a = max(k_a // 2, 1)
                elif k_b > 1:
                    k_b = max(k_b // 2, 1)
                else:
                    raise
                attempt_plan = FcdccPlan(n=plan.n, k_a=k_a, k_b=k_b)
    raise ClusterDegraded("elastic retries exhausted")
