from .cluster import (
    ClusterDegraded,
    FcdccCluster,
    LayerTiming,
    PendingBatch,
    PendingRound,
    StragglerModel,
    run_layer_elastic,
)
from .devicepool import (
    DeviceWorkerPool,
    ThreadWorkerPool,
    make_pool,
    resolve_pool,
)
