from .cluster import (
    ClusterDegraded,
    FcdccCluster,
    LayerTiming,
    PendingBatch,
    StragglerModel,
    run_layer_elastic,
)
