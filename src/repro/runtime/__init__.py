from .cluster import (
    ClusterDegraded,
    FcdccCluster,
    LayerTiming,
    StragglerModel,
    run_layer_elastic,
)
