from .synthetic import DataConfig, SyntheticTokens
