"""Deterministic synthetic token pipeline.

Properties a production loader needs and this one has:
  * deterministic as a function of (seed, step, shard) — restart-safe,
  * shard-aware: each data-parallel rank draws only its slice,
  * stateless resume: checkpoint stores just the step counter,
  * host-side numpy generation (cheap), device put with the right sharding.

The "dataset" is a Zipf-ish categorical over the vocab with a linear
next-token structure so loss decreases when models actually learn.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticTokens:
    def __init__(self, cfg: DataConfig, num_shards: int = 1, shard: int = 0):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.num_shards = num_shards
        self.shard = shard
        # fixed Zipf-ish marginal + deterministic bigram shift structure
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.shard])
        )

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Returns {"tokens", "labels"} of shape (local_batch, seq_len)."""
        rng = self._rng(step)
        b = self.cfg.global_batch // self.num_shards
        s = self.cfg.seq_len
        toks = rng.choice(self.cfg.vocab, size=(b, s + 1), p=self.probs).astype(
            np.int32
        )
        # inject learnable structure: every other token is prev+1 mod V
        toks[:, 1::2] = (toks[:, 0:-1:2] + 1) % self.cfg.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
