"""Baseline coded / uncoded schemes the paper compares against.

* ``VandermondeAxisCode`` — classical real polynomial codes [Yu et al. '17]:
  evaluation points on the real line; condition number grows exponentially
  in n (the instability the paper demonstrates in Fig. 3/4).
* ``chebyshev_points`` variant — Fahim–Cadambe-style numerically-stable
  polynomial coding via Chebyshev evaluation points (better than raw real
  points, still exponential asymptotically, per Fig. 4).
* Uncoded model-parallel splits of Table II (spatial / out-channel /
  in-channel partitioning) with no straggler resilience.

The polynomial codes reuse the same NSCTC encode/decode machinery via the
AxisCode protocol (ell = 1: one coded X and one coded K per worker, a single
conv per worker, recovery threshold delta = k_a * k_b).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PolyAxisCode",
    "make_poly_codes",
    "poly_recovery_matrix",
    "uncoded_spatial",
    "uncoded_out_channel",
    "uncoded_in_channel",
]


@dataclasses.dataclass(frozen=True)
class PolyAxisCode:
    """Polynomial (Vandermonde) code along one axis. ell == 1."""

    k: int
    n: int
    ell: int
    base: int
    matrix: np.ndarray  # (k, n)

    def worker_columns(self, i: int) -> np.ndarray:
        return self.matrix[:, i : i + 1]


def real_points(n: int) -> np.ndarray:
    """Evaluation points used by the classical real polynomial code."""
    return np.linspace(-1.0, 1.0, n)


def chebyshev_points(n: int) -> np.ndarray:
    """Fahim–Cadambe-style Chebyshev points cos((2j+1)pi/2n)."""
    j = np.arange(n)
    return np.cos((2 * j + 1) * np.pi / (2 * n))


def make_poly_codes(k_a: int, k_b: int, n: int, points: np.ndarray):
    """A[a, j] = x_j^a ; B[b, j] = x_j^{b*k_a} — distinct joint degrees."""
    a = np.stack([points**d for d in range(k_a)], axis=0)
    b = np.stack([points ** (d * k_a) for d in range(k_b)], axis=0)
    return (
        PolyAxisCode(k=k_a, n=n, ell=1, base=1, matrix=a),
        PolyAxisCode(k=k_b, n=n, ell=1, base=k_a, matrix=b),
    )


def poly_recovery_matrix(a: PolyAxisCode, b: PolyAxisCode, workers) -> np.ndarray:
    cols = [np.kron(a.matrix[:, i], b.matrix[:, i]) for i in workers]
    e = np.stack(cols, axis=1)
    assert e.shape == (a.k * b.k, a.k * b.k), e.shape
    return e


# ---------------------------------------------------------------------------
# Uncoded model-parallel baselines (Table II) — no straggler resilience.
# ---------------------------------------------------------------------------


def _conv(x, k, stride, padding):
    y = jax.lax.conv_general_dilated(
        x[None],
        k,
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y[0]


def uncoded_spatial(x, k, stride, padding, k_a):
    """Spatial partitioning [42]: k_a workers, concat along H'."""
    from .partition import ConvGeometry, apcp_partition

    geo = ConvGeometry(
        in_channels=x.shape[0],
        out_channels=k.shape[0],
        height=x.shape[1],
        width=x.shape[2],
        kernel_h=k.shape[2],
        kernel_w=k.shape[3],
        stride=stride,
        padding=padding,
        k_a=k_a,
        k_b=1,
    )
    parts = apcp_partition(x, geo)  # (k_a, C, h_hat, Wp)
    outs = jax.vmap(lambda xp: _conv(xp, k, stride, 0))(parts)
    y = jnp.concatenate([outs[i] for i in range(k_a)], axis=1)
    return y[:, : geo.out_h, :]


def uncoded_out_channel(x, k, stride, padding, k_b):
    """Output-channel partitioning [43]: k_b workers, concat along N."""
    n = k.shape[0]
    assert n % k_b == 0
    parts = k.reshape(k_b, n // k_b, *k.shape[1:])
    outs = jax.vmap(lambda kp: _conv(x, kp, stride, padding))(parts)
    return jnp.concatenate([outs[i] for i in range(k_b)], axis=0)


def uncoded_in_channel(x, k, stride, padding, k_c):
    """Input-channel partitioning [44]: k_c workers, SUM merge."""
    c = x.shape[0]
    assert c % k_c == 0
    xs = x.reshape(k_c, c // k_c, *x.shape[1:])
    ks = k.reshape(k.shape[0], k_c, c // k_c, *k.shape[2:]).swapaxes(0, 1)
    outs = jax.vmap(lambda xp, kp: _conv(xp, kp, stride, padding))(xs, ks)
    return jnp.sum(outs, axis=0)
