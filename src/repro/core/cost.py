"""FCDCC cost model and optimal (k_A, k_B) selection (Sec. IV-E, Thm. 1).

All volumes are tensor-entry / MAC counts (eqs. 50-55); costs weight them by
(lambda_comm, lambda_comp, lambda_store).
"""
from __future__ import annotations

import dataclasses
import math

from .partition import ConvGeometry

__all__ = ["CostWeights", "CostBreakdown", "cost_breakdown", "optimal_partition"]


@dataclasses.dataclass(frozen=True)
class CostWeights:
    comm: float = 0.09  # AWS S3 egress $/GB ratio used by the paper (Exp. 5)
    store: float = 0.023
    comp: float = 0.0


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    v_comm_up: float
    v_comm_down: float
    m_comp: float
    v_store: float
    c_comm: float
    c_comp: float
    c_store: float

    @property
    def total(self) -> float:
        return self.c_comm + self.c_comp + self.c_store


def cost_breakdown(geo: ConvGeometry, k_a: int, k_b: int, w: CostWeights) -> CostBreakdown:
    """Per-node volumes & costs for a (k_a, k_b) split (eqs. 50-55).

    Uses ell=2 on every coded axis as in the paper's formulas (the factors 4
    and 2 below are ell_a*ell_b and ell_b); degenerate axes (k=1) reduce the
    per-worker copy count accordingly.
    """
    q = k_a * k_b
    c, n_out = geo.in_channels, geo.out_channels
    hp, wp = geo.padded_h, geo.padded_w
    ho, wo = geo.out_h, geo.out_w

    # Paper's eqs. (50)-(54) verbatim (the constant 4 = ell_a*ell_b coded
    # copies; 2 = ell_b coded filter partitions).  Constants do not change
    # the argmin structure but we keep them to reproduce Table IV exactly.
    v_up = 4 * c * hp * wp / k_a
    v_down = 4 * n_out * ho * wo / q
    m_comp = (
        4 * c * n_out * geo.height * geo.width * geo.kernel_h * geo.kernel_w
        / (geo.stride**2 * q)
    )
    v_store = 2 * n_out * c * geo.kernel_h * geo.kernel_w / k_b

    return CostBreakdown(
        v_comm_up=v_up,
        v_comm_down=v_down,
        m_comp=m_comp,
        v_store=v_store,
        c_comm=w.comm * (v_up + v_down),
        c_comp=w.comp * m_comp,
        c_store=w.store * v_store,
    )


def _feasible_factors(q: int) -> list[tuple[int, int]]:
    """(k_a, k_b) with k_a*k_b = Q and each in S = {1} U 2Z+."""
    out = []
    for k_a in range(1, q + 1):
        if q % k_a:
            continue
        k_b = q // k_a
        ok = lambda k: k == 1 or k % 2 == 0
        if ok(k_a) and ok(k_b):
            out.append((k_a, k_b))
    return out


def optimal_partition(
    geo: ConvGeometry, q: int, w: CostWeights = CostWeights()
) -> tuple[tuple[int, int], float, dict[tuple[int, int], float]]:
    """Exact discrete optimum over S x S with k_a*k_b = Q, plus the
    continuous Theorem-1 estimate for reference.

    Returns ``((k_a*, k_b*), U*, {feasible -> U})``.
    """
    landscape = {
        kk: cost_breakdown(geo, kk[0], kk[1], w).total for kk in _feasible_factors(q)
    }
    best = min(landscape, key=landscape.get)
    return best, landscape[best], landscape


def continuous_optimum(geo: ConvGeometry, q: int, w: CostWeights = CostWeights()) -> float:
    """Theorem 1's closed form k_A* = sqrt(a2/a1)."""
    a1 = w.store * 2 * geo.out_channels * geo.in_channels * geo.kernel_h * geo.kernel_w / q
    a2 = w.comm * 4 * geo.in_channels * geo.padded_h * geo.padded_w
    return math.sqrt(a2 / a1) if a1 > 0 else float("inf")
