"""FCDCC: the end-to-end coded distributed convolution layer (Sec. IV).

Pipeline (Fig. 1):
  APCP(X) -> encode with A      KCCP(K) -> encode with B   (master)
  worker i: 4 pairwise convs of its 2 coded inputs x 2 coded filters
  master: pick any delta workers, invert E, decode, merge.

Two execution paths share the same math:
  * ``run_simulated`` — vmap over the worker axis on one device; straggler
    subsets selected explicitly (used by tests/benchmarks and by the
    master/worker runtime in ``repro.runtime``).
  * ``run_sharded`` — ``shard_map`` over a mesh "workers" axis: each device
    computes its coded subtask, coded outputs are all-gathered (they are
    Q/n-sized each, so this is the paper's "download" phase as an ICI
    collective) and decoded identically on every shard.

Both paths are batch-native: ``x`` may be ``(C, H, W)`` or ``(B, C, H, W)``;
a whole batch flows through one coded program (the batch rides inside each
worker's subtask, so the code/decode algebra is unchanged).  This is what
``repro.core.pipeline.CodedPipeline`` builds on to stream multi-layer CNNs
through a persistent coded cluster.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .crme import CrmeAxisCode, make_axis_codes, next_odd, recovery_matrix
from .nsctc import decode_blocks, encode_tensor_list, group_by_worker
from .partition import (
    ConvGeometry,
    apcp_partition,
    block_output_shape,
    kccp_partition,
    merge_output,
)

__all__ = ["FcdccPlan", "CodedConv2d"]


@dataclasses.dataclass(frozen=True)
class FcdccPlan:
    """Static plan: worker count, partition factors, derived code params."""

    n: int
    k_a: int
    k_b: int
    q: int | None = None

    def __post_init__(self):
        make_axis_codes(self.k_a, self.k_b, self.n, self.q)  # validate

    @property
    def codes(self) -> tuple[CrmeAxisCode, CrmeAxisCode]:
        return make_axis_codes(self.k_a, self.k_b, self.n, self.q)

    @property
    def ell_a(self) -> int:
        return 1 if self.k_a == 1 else 2

    @property
    def ell_b(self) -> int:
        return 1 if self.k_b == 1 else 2

    @property
    def delta(self) -> int:
        """Recovery threshold (eq. of Sec. II-A, with degenerate-axis rule)."""
        return (self.k_a * self.k_b) // (self.ell_a * self.ell_b)

    @property
    def gamma(self) -> int:
        return self.n - self.delta


def _conv_valid(x, k, stride, backend="lax", interpret=True):
    """VALID conv of one coded block pair: x ([B,]C,H,W) * k (N,C,KH,KW)."""
    batched = x.ndim == 4
    if backend == "pallas":
        from repro.kernels.conv2d.ops import conv2d_im2col

        if batched:
            return jax.vmap(
                lambda xi: conv2d_im2col(xi, k, stride, interpret=interpret)
            )(x)
        return conv2d_im2col(x, k, stride, interpret=interpret)
    y = jax.lax.conv_general_dilated(
        x if batched else x[None],
        k,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y if batched else y[0]


class CodedConv2d:
    """One FCDCC-coded convolution layer.

    ``plan`` fixes (n, k_a, k_b); ``geo`` fixes the conv geometry. The filter
    is encoded once (``encode_filters``) and cached — matching the paper's
    deployment where coded filters are pre-stored on workers.
    """

    def __init__(self, plan: FcdccPlan, geo: ConvGeometry, backend: str = "lax",
                 fused_worker: bool = True, interpret: bool = True):
        if geo.k_a != plan.k_a or geo.k_b != plan.k_b:
            geo = dataclasses.replace(geo, k_a=plan.k_a, k_b=plan.k_b)
        self.plan = plan
        self.geo = geo
        self.backend = backend
        self.fused_worker = fused_worker
        # pallas-only: True emulates kernels on CPU, False lowers to real TPU
        self.interpret = interpret
        self.a_code, self.b_code = plan.codes
        # instrumentation: CodedPipeline/tests assert encode-once semantics
        self.filter_encode_calls = 0
        self.input_encode_calls = 0

    # -- master side: encode ---------------------------------------------
    def encode_inputs(self, x: jnp.ndarray, matrix=None) -> jnp.ndarray:
        """([B,]C,H,W) -> coded inputs (n, ell_a, [B,] C, h_hat, W+2p).

        ``matrix`` overrides the A-code encoding matrix — pass a column
        subset (``(k_a, ell_a*m)``, possibly a traced array) to encode only
        m selected workers' shares instead of all n.
        """
        self.input_encode_calls += 1
        parts = apcp_partition(x, self.geo)
        coded = encode_tensor_list(
            parts, self.a_code.matrix if matrix is None else matrix
        )
        return group_by_worker(coded, self.a_code.ell)

    def encode_from_partitions(self, parts: jnp.ndarray, matrix=None) -> jnp.ndarray:
        """Encode pre-sliced APCP parts ``(k_a, [B,] C, h_hat, W+2p)``.

        The partition-resident transition path: layer *i+1*'s parts are
        assembled directly from layer *i*'s decoded partitions
        (``repro.core.partition.partition_transition``), so the
        ``apcp_partition`` step of ``encode_inputs`` is skipped.  ``matrix``
        as in ``encode_inputs``.
        """
        self.input_encode_calls += 1
        assert parts.shape[0] == self.plan.k_a, (parts.shape, self.plan)
        coded = encode_tensor_list(
            parts, self.a_code.matrix if matrix is None else matrix
        )
        return group_by_worker(coded, self.a_code.ell)

    def encode_filters(self, k: jnp.ndarray) -> jnp.ndarray:
        """(N,C,KH,KW) -> coded filters (n, ell_b, N/k_b, C, KH, KW)."""
        self.filter_encode_calls += 1
        parts = kccp_partition(k, self.geo)
        coded = encode_tensor_list(parts, self.b_code.matrix)
        return group_by_worker(coded, self.b_code.ell)

    # -- worker side -------------------------------------------------------
    def worker_compute(self, xe_i: jnp.ndarray, ke_i: jnp.ndarray) -> jnp.ndarray:
        """Coded subtask of one worker (Algorithm 4 lines 6-11).

        ``xe_i``: (ell_a, [B,] C, h_hat, Wp); ``ke_i``: (ell_b, N/k_b, C, KH, KW).
        Returns (ell_a*ell_b, [B,] N/k_b, H'/k_a, W'), slot ``ell_b*b1 + b2``.

        §Perf (beyond paper): the ell_a*ell_b pairwise convolutions are
        fused into ONE batched conv — coded inputs (x the request batch) as
        the batch dim, coded filters concatenated along output channels — a
        single bigger GEMM instead of 4 small ones (set ``fused_worker=False``
        for the paper-literal loop).  Both backends take the fused path:
        ``lax`` as one ``conv_general_dilated``, ``pallas`` as one im2col +
        one MXU-tiled GEMM (``coded_worker_pallas``).
        """
        if not self.fused_worker:
            outs = []
            for b1 in range(self.plan.ell_a):
                for b2 in range(self.plan.ell_b):
                    outs.append(
                        _conv_valid(xe_i[b1], ke_i[b2], self.geo.stride,
                                    self.backend, self.interpret)
                    )
            return jnp.stack(outs, axis=0)
        if self.backend == "pallas":
            from repro.kernels.conv2d.ops import coded_worker

            return coded_worker(xe_i, ke_i, self.geo.stride,
                                interpret=self.interpret)
        ea, eb = self.plan.ell_a, self.plan.ell_b
        nb = ke_i.shape[1]
        k_cat = ke_i.reshape((eb * nb,) + ke_i.shape[2:])
        batched = xe_i.ndim == 5
        b = xe_i.shape[1] if batched else 1
        xin = xe_i.reshape((ea * b,) + xe_i.shape[-3:]) if batched else xe_i
        y = jax.lax.conv_general_dilated(
            xin,
            k_cat,
            window_strides=(self.geo.stride, self.geo.stride),
            padding="VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )  # (ell_a[*B], ell_b*nb, H', W')
        if not batched:
            return y.reshape((ea * eb, nb) + y.shape[2:])
        y = y.reshape((ea, b, eb, nb) + y.shape[2:])
        return jnp.transpose(y, (0, 2, 1, 3, 4, 5)).reshape(
            (ea * eb, b, nb) + y.shape[4:]
        )

    # -- master side: decode ------------------------------------------------
    def decode_to_partitions(self, worker_ids, outputs: jnp.ndarray) -> jnp.ndarray:
        """Any-delta decode to the partition grid — merge deliberately
        skipped.

        ``outputs``: (delta, ell2, *block) with block
        ``([B,] N/k_b, H'/k_a, W')``.  Returns the A-major
        ``(k_a*k_b, *block)`` grid — the partition-resident transition path
        (``CodedPipeline`` with ``fuse_transitions=True``) threads this
        straight into the next layer's re-encode without ever assembling
        the full ``([B,] N, H', W')`` tensor.
        """
        blocks = decode_blocks(
            self.a_code,
            self.b_code,
            worker_ids,
            outputs,
            outputs.shape[2:],
        )
        assert blocks.shape[-3:] == block_output_shape(self.geo)
        return blocks

    def decode(self, worker_ids, outputs: jnp.ndarray) -> jnp.ndarray:
        """Any-delta decode + merge.

        ``outputs``: (delta, ell2, *block) where block is
        ``([B,] N/k_b, H'/k_a, W')`` — the batch dim (if any) just rides
        inside the decoded rows.
        """
        blocks = self.decode_to_partitions(worker_ids, outputs)
        return merge_output(blocks, self.geo)

    # -- end-to-end paths ----------------------------------------------------
    def run_simulated(self, x, k, worker_ids=None):
        """Single-device end-to-end run; ``worker_ids`` are the survivors."""
        ids = list(range(self.plan.delta)) if worker_ids is None else list(worker_ids)
        xe = self.encode_inputs(x)
        ke = self.encode_filters(k)
        idx = jnp.asarray(ids)
        outs = jax.vmap(self.worker_compute)(xe[idx], ke[idx])
        return self.decode(ids, outs)

    def run_sharded(self, mesh, axis: str, x, k, worker_ids=None):
        """SPMD path: workers = mesh axis ``axis`` (size must equal plan.n).

        Every shard computes its coded subtask; the coded outputs (each
        ``1/delta`` of Y) are all-gathered and decoded redundantly. Straggler
        resilience on a pod maps to *any-delta-of-n slices suffice*: the
        decode uses the statically chosen ``worker_ids`` subset, so losing
        up to gamma shards' results still reconstructs Y exactly.
        """
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        n = self.plan.n
        assert mesh.shape[axis] == n, (mesh.shape, axis, n)
        ids = list(range(self.plan.delta)) if worker_ids is None else list(worker_ids)
        e = recovery_matrix(self.a_code, self.b_code, ids)
        d = jnp.asarray(np.linalg.inv(e.T))
        sel = jnp.asarray(ids)

        xe = self.encode_inputs(x)  # (n, ell_a, ...)
        ke = self.encode_filters(k)  # (n, ell_b, ...)

        def shard_fn(xe_s, ke_s):
            # xe_s: (1, ell_a, ...) local slice
            out = self.worker_compute(xe_s[0], ke_s[0])[None]  # (1, ell2, ...)
            allout = jax.lax.all_gather(out, axis, axis=0, tiled=True)
            coded = allout[sel]  # (delta, ell2, *block) — block may be batched
            rows = coded.reshape(self.plan.k_a * self.plan.k_b, -1)
            true_rows = d.astype(rows.dtype) @ rows
            blocks = true_rows.reshape(
                (self.plan.k_a * self.plan.k_b,) + coded.shape[2:]
            )
            return merge_output(blocks, self.geo)

        fn = shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(P(axis), P(axis)),
            out_specs=P(),  # decoded output replicated
            check_rep=False,
        )
        return fn(xe, ke)
