"""FCDCC core: CRME codes, NSCTC encode/decode, APCP/KCCP, cost model."""
from .crme import (
    CrmeAxisCode,
    condition_number,
    joint_columns,
    make_axis_codes,
    next_odd,
    recovery_matrix,
    rotation_matrix,
)
from .partition import ConvGeometry, apcp_partition, kccp_partition, merge_output
from .fcdcc import CodedConv2d, FcdccPlan
from .cost import CostWeights, cost_breakdown, optimal_partition
from .pipeline import (
    CodedLayerSpec,
    CodedPipeline,
    build_cnn_pipeline,
    plan_layers,
)
