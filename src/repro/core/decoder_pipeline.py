"""Coded LM decode serving: the ``CodedDecoderPipeline``.

The FCDCC machinery treats a ConvL as ``coded inputs x resident coded
filters``; a transformer decode step is the same shape of problem four
times per layer — the qkv / attention-output / gate-up / down projections
are GEMMs ``x (B, d_in) @ W (d_in, d_out)`` whose weights are static for
the lifetime of the model.  This module compiles a GQA decoder stack into
per-layer coded GEMM *rounds* against the same cluster seam CNNs use
(``FcdccCluster.load_pipeline`` / ``dispatch_pipeline_layer`` /
``collect_pipeline_layer``), so one coded worker pool serves CNN ConvL
rounds and LM decode rounds concurrently:

  * weights are column-partitioned (``k_b`` parts of the output axis) and
    CRME-encoded **once** at construction — the resident-coded-filter
    store, exactly like ConvL filters;
  * the token activation is broadcast to every worker (``k_a = 1``: the
    degenerate replication axis — decode batches are small and the master
    keeps the KV cache, so input partitioning buys nothing);
  * every worker computes ``ell_b`` skinny GEMMs per round; the master
    decodes the fastest ``delta`` workers' outputs with a ``(Q, Q)``
    inverse passed as a *runtime argument*, so timing-dependent survivor
    subsets never retrace (the same contract as ``CodedPipeline``);
  * everything between the GEMM rounds — embedding, RMS norms, RoPE +
    causal attention over the master-resident KV slot cache, SiLU gating,
    residual adds, unembed/argmax — runs master-side as small jitted glue
    programs with weights as runtime arguments.

``UncodedPlan`` is the straggler-bound baseline: the same worker pool and
worker program, weights split ``n`` ways with no redundancy, identity
decode — every round must wait for ALL ``n`` workers, so one straggler
bounds the token rate (what exp13 measures coded decode against).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .crme import recovery_matrix
from .fcdcc import FcdccPlan
from .nsctc import encode_tensor_list, group_by_worker
from .pipeline import ProgramCell

__all__ = [
    "GemmGeometry",
    "GemmRoundSpec",
    "UncodedPlan",
    "CodedDecoderPipeline",
    "build_lm_decoder_pipeline",
]


@dataclasses.dataclass(frozen=True)
class UncodedPlan:
    """Uncoded column-split baseline: worker ``i`` holds the ``i``-th of
    ``n`` weight column blocks, decode is the identity gather — so the
    recovery threshold is all ``n`` workers (``gamma = 0``).  Duck-types
    the ``FcdccPlan`` attributes the cluster/pipeline seams consult."""

    n: int

    @property
    def k_a(self) -> int:
        return 1

    @property
    def k_b(self) -> int:
        return self.n

    @property
    def ell_a(self) -> int:
        return 1

    @property
    def ell_b(self) -> int:
        return 1

    @property
    def delta(self) -> int:
        return self.n

    @property
    def gamma(self) -> int:
        return 0


@dataclasses.dataclass(frozen=True)
class GemmGeometry:
    """Geometry of one decoder GEMM round, shaped like the ``ConvGeometry``
    attributes ``FcdccCluster._filter_code_key`` consults (a 1x1 "conv"
    of ``in_channels -> out_channels``), so coded GEMM weights live in the
    same resident-filter registry as ConvL filters."""

    in_channels: int
    out_channels: int
    kernel_h: int = 1
    kernel_w: int = 1


@dataclasses.dataclass(frozen=True)
class GemmRoundSpec:
    """One coded GEMM round of a decoder layer (static plan + geometry).

    ``kind``: ``qkv`` / ``wo`` / ``gateup`` / ``down``.  ``program_key``
    carries the backend so an LM pipeline never collides with a ConvL
    program (ConvL keys are int tuples) in a shared device pool."""

    name: str
    kind: str
    layer: int
    plan: object  # FcdccPlan | UncodedPlan
    geo: GemmGeometry
    backend: str = "lax"

    @property
    def program_key(self) -> tuple:
        return ("gemm", self.backend, self.plan.ell_a, self.plan.ell_b)


class _GemmRound:
    """Per-round holder mirroring ``CodedPipeline.layers[idx]`` — the
    cluster seam reads ``.worker_compute`` off it."""

    def __init__(self, worker_compute):
        self.worker_compute = worker_compute


def _make_worker_compute(backend: str, interpret: bool):
    """The ONE plan-agnostic coded GEMM worker program.

    ``xe_i``: (ell_a=1, B, d_in) — the broadcast activation share;
    ``ke_i``: (ell_b, d_in, ob) — the worker's resident coded weight
    columns.  Returns (ell_a*ell_b, B, ob), slot ``ell_b*b1 + b2``.

    Every round of every layer shares this function under one
    ``program_key``: the thread pool caches ONE ``jax.jit`` per key, so
    the callable must be plan-agnostic — jit's shape cache handles the
    per-geometry/per-bucket specialization (the bounded-trace contract).
    """
    if backend == "pallas":
        from repro.kernels.matmul.ops import matmul

        def worker_compute(xe_i, ke_i):
            eb, d_in, ob = ke_i.shape
            # one MXU GEMM for all ell_b coded column blocks
            kcat = jnp.transpose(ke_i, (1, 0, 2)).reshape(d_in, eb * ob)
            y = matmul(xe_i[0], kcat, interpret=interpret)
            return jnp.transpose(y.reshape(y.shape[0], eb, ob), (1, 0, 2))

        return worker_compute

    def worker_compute(xe_i, ke_i):
        y = jnp.einsum("abd,cdo->acbo", xe_i, ke_i)
        return y.reshape((-1,) + y.shape[2:])

    return worker_compute


class CodedDecoderPipeline:
    """A GQA decoder stack compiled into coded GEMM rounds on one cluster.

    Construction encodes every round's weights exactly once (asserted by
    ``weight_encode_calls``).  A decode step runs ``4 * layers`` worker
    rounds through ``run_round`` — either the threaded/device cluster
    (``run_decode_step_cluster``) or the single-process vmapped path with
    forced survivor subsets (``run_decode_step_direct``) — with the KV
    cache, norms, RoPE/attention, activations, and unembed kept
    master-side.  Per-request state lives in *slot caches*: row ``i`` of
    every layer's (slots, max_len, hkv, hd) K/V cache belongs to request
    slot ``i``, written at its own position each step (continuous
    batching advances every active slot by one token per step).
    """

    def __init__(self, cfg, params, plan, *, backend: str = "lax",
                 interpret: bool = True,
                 bucket_sizes: Sequence[int] | None = None,
                 max_len: int | None = None):
        if cfg.attn != "gqa":
            raise ValueError(f"coded decode supports attn='gqa', got {cfg.attn!r}")
        if cfg.moe is not None:
            raise ValueError("coded decode does not support MoE layers")
        if plan.k_a != 1:
            raise ValueError(
                f"decoder rounds broadcast the activation: need k_a=1, got "
                f"k_a={plan.k_a}"
            )
        self.cfg = cfg
        self.plan = plan
        self.n = plan.n
        self.backend = backend
        self.interpret = interpret
        self.pool = None
        self.devices = None
        self.fuse_transitions = False  # GEMM rounds have no fused transitions
        self.max_len = int(max_len if max_len is not None else cfg.max_seq)
        self.bucket_sizes: tuple[int, ...] | None = (
            self.normalize_buckets(bucket_sizes) if bucket_sizes else None
        )

        # master-side params: full tree (prefill) + per-layer glue weights
        params = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32), params)
        self.params = params
        h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        self.qkv_dim = (h + 2 * hkv) * hd
        lp = params["dense_layers"]
        self.glue_w: list[dict] = []
        for l in range(cfg.layers):
            g = {"ln_attn": lp["ln_attn"][l], "ln_ffn": lp["ln_ffn"][l]}
            if cfg.qk_norm:
                g["q_ln"], g["k_ln"] = lp["q_ln"][l], lp["k_ln"][l]
            if cfg.sandwich_norms:
                g["ln_attn_post"] = lp["ln_attn_post"][l]
                g["ln_ffn_post"] = lp["ln_ffn_post"][l]
            self.glue_w.append(g)
        self.embed_table = params["embed"]
        self.ln_f = params["ln_f"]
        self.head = (params["embed"].T if cfg.tie_embeddings
                     else params["lm_head"])

        # compile the round specs and encode weights exactly once ---------
        self.weight_encode_calls = 0
        compute = _make_worker_compute(backend, interpret)
        self.specs: list[GemmRoundSpec] = []
        self.layers: list[_GemmRound] = []
        self.coded_filters: list[jnp.ndarray] = []
        self._windows = _decoder_windows(cfg)
        for l in range(cfg.layers):
            rounds = [
                ("qkv", jnp.concatenate(
                    [lp["wq"][l], lp["wk"][l], lp["wv"][l]], axis=1)),
                ("wo", lp["wo"][l]),
                ("gateup", jnp.concatenate(
                    [lp["w_gate"][l], lp["w_up"][l]], axis=1)),
                ("down", lp["w_down"][l]),
            ]
            for kind, w in rounds:
                d_in, d_out = int(w.shape[0]), int(w.shape[1])
                if d_out % plan.k_b:
                    raise ValueError(
                        f"round L{l:02d}.{kind}: d_out={d_out} not divisible "
                        f"by k_b={plan.k_b}"
                    )
                spec = GemmRoundSpec(
                    f"L{l:02d}.{kind}", kind, l, plan,
                    GemmGeometry(d_in, d_out), backend,
                )
                self.specs.append(spec)
                self.layers.append(_GemmRound(compute))
                self.coded_filters.append(self._encode_weights(w))

        # program caches --------------------------------------------------
        self._encoder_fn = None
        self._decoder = None
        self._cluster_programs: dict[tuple, callable] = {}  # per-worker call
        self._batch_programs: dict[tuple, callable] = {}  # vmapped over workers
        self._glue: dict = {}
        self._attn_fns: dict = {}
        self._prefill_fn = None

    # -- weight encoding (once, at construction) ---------------------------
    def _encode_weights(self, w: jnp.ndarray) -> jnp.ndarray:
        """(d_in, d_out) -> resident coded columns (n, ell_b, d_in, ob)."""
        self.weight_encode_calls += 1
        plan = self.plan
        d_in, d_out = w.shape
        ob = d_out // plan.k_b
        parts = w.reshape(d_in, plan.k_b, ob).swapaxes(0, 1)  # (k_b, d_in, ob)
        if isinstance(plan, UncodedPlan):
            matrix = np.eye(plan.n)  # worker i holds column block i
        else:
            matrix = plan.codes[1].matrix  # B-code, (k_b, ell_b*n)
        coded = encode_tensor_list(parts, matrix)
        return group_by_worker(coded, plan.ell_b)

    # -- bucketing (same contract as CodedPipeline) ------------------------
    @staticmethod
    def normalize_buckets(bucket_sizes: Sequence[int]) -> tuple[int, ...]:
        buckets = tuple(sorted(set(int(b) for b in bucket_sizes)))
        if not buckets or buckets[0] < 1:
            raise ValueError(f"bucket sizes must be >= 1, got {bucket_sizes}")
        return buckets

    @property
    def max_batch(self) -> int | None:
        return self.bucket_sizes[-1] if self.bucket_sizes else None

    def bucketize(self, batch: int) -> int:
        if self.bucket_sizes is None:
            return batch
        for b in self.bucket_sizes:
            if b >= batch:
                return b
        raise ValueError(
            f"batch {batch} exceeds the largest bucket {self.bucket_sizes[-1]}"
        )

    def pad_to_bucket(self, x: jnp.ndarray, axis: int = 0) -> tuple[jnp.ndarray, int]:
        b = x.shape[axis]
        bucket = self.bucketize(b)
        if bucket == b:
            return x, b
        pad_shape = x.shape[:axis] + (bucket - b,) + x.shape[axis + 1:]
        return jnp.concatenate([x, jnp.zeros(pad_shape, x.dtype)], axis=axis), b

    # -- introspection -----------------------------------------------------
    @property
    def num_geometries(self) -> int:
        """Distinct (program key, GEMM geometry) pairs: 4 for a homogeneous
        decoder stack no matter how many layers."""
        return len({(s.program_key, s.geo) for s in self.specs})

    @property
    def num_transitions(self) -> int:
        return 0

    @property
    def program_trace_bound(self) -> int:
        buckets = len(self.bucket_sizes) if self.bucket_sizes else 1
        return self.num_geometries * buckets

    @property
    def num_rounds_per_step(self) -> int:
        return len(self.specs)

    def layer_delta(self, idx: int) -> int:
        return self.specs[idx].plan.delta

    def layer_worker_ids(self, idx: int, worker_ids=None) -> tuple[int, ...]:
        delta = self.layer_delta(idx)
        avail = list(range(self.n)) if worker_ids is None else list(worker_ids)
        if len(avail) < delta:
            raise ValueError(
                f"round {self.specs[idx].name} needs delta={delta} workers, "
                f"got {len(avail)}"
            )
        return tuple(avail[:delta])

    # -- coded program caches (the CodedPipeline duck-type surface) --------
    def encoder(self, idx: int):
        """k_a=1 'encoding' is a broadcast: every worker receives the whole
        (B, d_in) activation as its single coded share.  One jitted program
        serves every round (shape specialization is jit's job); nothing is
        baked but the worker count."""
        if self._encoder_fn is None:
            n = self.n
            self._encoder_fn = jax.jit(
                lambda x: jnp.broadcast_to(x[None, None], (n, 1) + x.shape)
            )
        return self._encoder_fn

    def worker_program(self, idx: int, *, over_workers: bool = True):
        cache = self._batch_programs if over_workers else self._cluster_programs
        key = self.specs[idx].program_key
        fn = cache.get(key)
        if fn is None:
            compute = self.layers[idx].worker_compute
            fn = cache[key] = jax.jit(
                jax.vmap(compute) if over_workers else compute
            )
        return fn

    def decode_matrix(self, idx: int, worker_ids: tuple[int, ...]) -> np.ndarray:
        """The (Q, Q) decode inverse for the given survivor subset (host
        side).  Uncoded rounds accept only the full worker set and decode
        with the identity — sorted-id gather order IS column-block order."""
        plan = self.specs[idx].plan
        if isinstance(plan, UncodedPlan):
            ids = tuple(sorted(worker_ids))
            if ids != tuple(range(plan.n)):
                raise ValueError(
                    f"uncoded round needs all {plan.n} workers, got {ids}"
                )
            return np.eye(plan.n)
        a_code, b_code = plan.codes
        e = recovery_matrix(a_code, b_code, list(worker_ids))
        return np.linalg.inv(e.T)

    def decoder_fn(self, idx: int):
        """One jitted decode program for EVERY round: the (Q, Q) inverse is
        a runtime argument, and with k_a=1 the decoded blocks are plain
        column blocks, so decode+concat is round-geometry-agnostic."""
        if self._decoder is None:
            def dec(outs, d):
                # outs (delta, ell2, B, ob) sorted by worker id
                q = outs.shape[0] * outs.shape[1]
                rows = outs.reshape(q, -1)
                true_rows = d.astype(rows.dtype) @ rows
                blocks = true_rows.reshape((q,) + outs.shape[2:])
                return jnp.transpose(blocks, (1, 0, 2)).reshape(
                    outs.shape[2], q * outs.shape[3]
                )

            self._decoder = jax.jit(dec)
        return self._decoder

    def decoder(self, idx: int, worker_ids: tuple[int, ...]):
        fn = self.decoder_fn(idx)
        d = jnp.asarray(self.decode_matrix(idx, worker_ids))
        return lambda outs: fn(outs, d)

    # -- master-side glue programs -----------------------------------------
    def _glue_fn(self, name: str):
        fn = self._glue.get(name)
        if fn is not None:
            return fn
        cfg = self.cfg
        if name == "embed":
            scale = math.sqrt(cfg.d_model)

            def raw(table, tokens):
                x = table[tokens]
                if cfg.embed_scale:
                    x = x * jnp.asarray(scale, x.dtype)
                return x
        elif name == "norm":
            from repro.models.common import rms_norm

            def raw(x, gamma):
                return rms_norm(x, gamma)
        elif name == "add":
            def raw(x, y):
                return x + y
        elif name == "act":
            act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu

            def raw(gu):
                g, u = jnp.split(gu, 2, axis=-1)
                return act(g.astype(jnp.float32)).astype(u.dtype) * u
        elif name == "finish":
            from repro.models.common import rms_norm, softcap

            def raw(x, gamma, head):
                logits = (rms_norm(x, gamma) @ head).astype(jnp.float32)
                if cfg.logit_softcap is not None:
                    logits = softcap(logits, cfg.logit_softcap)
                return logits, jnp.argmax(logits, axis=-1).astype(jnp.int32)
        elif name == "slot_write":
            def raw(c, new, row):
                return jax.lax.dynamic_update_slice_in_dim(c, new, row, axis=0)
        elif name == "slot_take":
            def raw(c, row):
                return jax.lax.dynamic_slice_in_dim(c, row, 1, axis=0)
        else:
            raise KeyError(name)
        fn = self._glue[name] = jax.jit(raw)
        return fn

    def attn_fn(self, layer: int):
        """The jitted decode-attention glue for ``layer`` (programs shared
        across layers with the same sliding window): split the coded qkv
        round's output, RoPE at each row's own position, write K/V into
        row ``i``'s cache slot at position ``pos[i]`` (per-row iota
        select), attend causally over the slot cache, return the merged
        head context plus the updated full slot caches."""
        window = self._windows[layer]
        fn = self._attn_fns.get(window)
        if fn is not None:
            return fn
        cfg = self.cfg
        from repro.models.common import rms_norm
        from repro.models.transformer import _attend

        h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

        def raw(qkv, ck, cv, pos, *ln):
            b = qkv.shape[0]
            q, k, v = jnp.split(qkv, [h * hd, (h + hkv) * hd], axis=-1)
            q = q.reshape(b, 1, h, hd)
            k = k.reshape(b, 1, hkv, hd)
            v = v.reshape(b, 1, hkv, hd)
            if cfg.qk_norm:
                q = rms_norm(q, ln[0])
                k = rms_norm(k, ln[1])
            from repro.models.common import apply_rope, rope_inv_freq

            rope = rope_inv_freq(hd, cfg.rope_base)
            q = apply_rope(q, rope, pos[:, None])
            k = apply_rope(k, rope, pos[:, None])
            max_len = ck.shape[1]
            idx = jnp.arange(max_len, dtype=jnp.int32)
            sel = (idx[None, :] == pos[:, None])[:, :, None, None]
            ckb = jnp.where(sel, k, ck[:b])
            cvb = jnp.where(sel, v, cv[:b])
            ck = jax.lax.dynamic_update_slice_in_dim(ck, ckb, 0, axis=0)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, cvb, 0, axis=0)
            k_pos = jnp.broadcast_to(idx[None, :], (b, max_len))
            # causal mask k_pos <= pos hides not-yet-written slots
            ctx = _attend(q, ckb, cvb, pos[:, None], k_pos, cfg, window)
            return ctx.reshape(b, h * hd), ck, cv

        fn = self._attn_fns[window] = jax.jit(raw)
        return fn

    # -- KV slot cache ------------------------------------------------------
    def init_slot_cache(self, slots: int) -> list[dict]:
        """Per-layer K/V slot caches: row ``i`` belongs to request slot
        ``i`` for its whole lifetime (prefill-scattered in, advanced one
        position per decode step, recycled on completion)."""
        cfg = self.cfg
        shape = (slots, self.max_len, cfg.n_kv_heads, cfg.head_dim)
        return [
            {"k": jnp.zeros(shape, jnp.float32),
             "v": jnp.zeros(shape, jnp.float32)}
            for _ in range(cfg.layers)
        ]

    def slot_write(self, cache_leaf, new, row: int):
        """Write ``new`` (G, max_len, hkv, hd) into rows [row, row+G)."""
        return self._glue_fn("slot_write")(cache_leaf, new, jnp.int32(row))

    def slot_take(self, cache_leaf, row: int):
        """Read one slot row (1, max_len, hkv, hd) at ``row``."""
        return self._glue_fn("slot_take")(cache_leaf, jnp.int32(row))

    def prefill_prompt(self, prompts: jnp.ndarray):
        """Batched cache-filling prefill for a group of admitted prompts:
        ONE jitted full-stack pass (``models.transformer.prefill``) on the
        master — prompt positions never go through worker rounds.  Returns
        ``(logits (G, P, V), ks, vs)`` with ks/vs ``(L, G, max_len, hkv,
        hd)`` ready to scatter into the slot caches."""
        if self._prefill_fn is None:
            from repro.models import transformer as lm

            cfg, max_len = self.cfg, self.max_len

            def raw(params, tokens):
                cache = lm.init_cache(cfg, tokens.shape[0], max_len,
                                      jnp.float32)
                logits, filled = lm.prefill(params, cfg, cache, tokens)
                return logits, filled["dense"]["k"], filled["dense"]["v"]

            self._prefill_fn = jax.jit(raw)
        return self._prefill_fn(self.params, prompts)

    # -- decode-step drivers -------------------------------------------------
    def _decode_step(self, tokens, cache, pos, run_round):
        """One decode step over the first ``B = len(tokens)`` cache slots.

        ``tokens`` (B,) int32, ``pos`` (B,) int32 (each row's next
        position), ``cache`` the full slot-cache list (slots >= B).  Every
        projection GEMM goes through ``run_round(idx, x)``; everything
        else is master-side glue.  Returns (logits (B, V), next_tokens
        (B,), new_cache)."""
        cfg = self.cfg
        norm = self._glue_fn("norm")
        add = self._glue_fn("add")
        x = self._glue_fn("embed")(self.embed_table, tokens)
        new_cache = []
        for l in range(cfg.layers):
            g = self.glue_w[l]
            base = 4 * l
            qkv = run_round(base + 0, norm(x, g["ln_attn"]))
            ln = (g["q_ln"], g["k_ln"]) if cfg.qk_norm else ()
            ctx, ck, cv = self.attn_fn(l)(
                qkv, cache[l]["k"], cache[l]["v"], pos, *ln
            )
            new_cache.append({"k": ck, "v": cv})
            attn_out = run_round(base + 1, ctx)
            if cfg.sandwich_norms:
                attn_out = norm(attn_out, g["ln_attn_post"])
            x = add(x, attn_out)
            gu = run_round(base + 2, norm(x, g["ln_ffn"]))
            ffn_out = run_round(base + 3, self._glue_fn("act")(gu))
            if cfg.sandwich_norms:
                ffn_out = norm(ffn_out, g["ln_ffn_post"])
            x = add(x, ffn_out)
        logits, next_tokens = self._glue_fn("finish")(x, self.ln_f, self.head)
        return logits, next_tokens, new_cache

    def run_round_direct(self, idx: int, x, worker_ids=None):
        """One coded GEMM round on the single-process vmapped path with an
        explicitly forced survivor subset (tests/benchmarks)."""
        ids = tuple(sorted(self.layer_worker_ids(idx, worker_ids)))
        xe = self.encoder(idx)(x)
        sel = jnp.asarray(ids)
        outs = self.worker_program(idx)(xe[sel], self.coded_filters[idx][sel])
        return self.decoder(idx, ids)(outs)

    def run_decode_step_direct(self, tokens, cache, pos, worker_ids=None):
        """Full decode step, every round decoded from the forced subset."""
        return self._decode_step(
            tokens, cache, pos,
            lambda idx, x: self.run_round_direct(idx, x, worker_ids),
        )

    def run_decode_step_cluster(self, cluster, tokens, cache, pos, *,
                                model: str = "lm", timings: list | None = None):
        """Full decode step through the master/worker runtime: each round
        dispatches n coded subtasks via ``dispatch_pipeline_layer`` and
        reaps the fastest delta via ``collect_pipeline_layer`` (stragglers
        beyond gamma are simply never waited for)."""
        def run_round(idx, x):
            rnd = cluster.dispatch_pipeline_layer(idx, x, model)
            y, timing = cluster.collect_pipeline_layer(rnd)
            if timings is not None:
                timings.append(timing)
            return y

        return self._decode_step(tokens, cache, pos, run_round)

    # -- shape-space enumeration -------------------------------------------
    def program_space(self, bucket_sizes: Sequence[int] | None = None, *,
                      modes: Sequence[str] = ("direct", "cluster")):
        """Enumerate every program cell a decode step can launch, in shape
        space.  Coded-round cells mirror ``CodedPipeline.program_space``
        (worker cells are what the bounded-trace proof counts); the
        master-side glue programs are yielded as ``glue`` cells under the
        ``master`` pseudo-mode so the jaxpr contracts (no baked coding
        matrices, no f64, no host callbacks) cover them too."""
        buckets = (self.normalize_buckets(bucket_sizes) if bucket_sizes
                   else (self.bucket_sizes or (1,)))
        cfg = self.cfg
        f32 = jnp.float32
        sds = jax.ShapeDtypeStruct
        geoms = set()
        for mode in modes:
            if mode not in ("direct", "cluster"):
                raise ValueError(f"unknown mode {mode!r}")
            for bucket in buckets:
                for idx, spec in enumerate(self.specs):
                    key = (mode, bucket, spec.program_key, spec.geo)
                    if key in geoms:
                        continue  # repeated layer geometry: same programs
                    geoms.add(key)
                    plan = spec.plan
                    d_in = spec.geo.in_channels
                    ob = spec.geo.out_channels // plan.k_b
                    delta, ea, eb = plan.delta, plan.ell_a, plan.ell_b
                    q = plan.k_a * plan.k_b

                    def cid(kind):
                        return f"{spec.name}[b={bucket}]/{kind}:{mode}"

                    x = sds((bucket, d_in), f32)
                    yield ProgramCell(
                        cid("encoder"), "encoder", mode, idx, bucket,
                        ("bcast",), self.encoder(idx), (x,))
                    if mode == "direct":
                        yield ProgramCell(
                            cid("worker"), "worker", mode, idx, bucket,
                            spec.program_key, self.worker_program(idx),
                            (sds((delta, ea, bucket, d_in), f32),
                             sds((delta, eb, d_in, ob), f32)))
                    else:
                        yield ProgramCell(
                            cid("worker"), "worker", mode, idx, bucket,
                            spec.program_key,
                            self.worker_program(idx, over_workers=False),
                            (sds((ea, bucket, d_in), f32),
                             sds((eb, d_in, ob), f32)))
                    yield ProgramCell(
                        cid("decoder"), "decoder", mode, idx, bucket,
                        ("dec",), self.decoder_fn(idx),
                        (sds((delta, ea * eb, bucket, ob), f32),
                         sds((q, q), f32)))
        # master-side glue (mode-independent; checked, never trace-counted)
        d, v = cfg.d_model, cfg.vocab
        h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        i32 = jnp.int32
        for bucket in buckets:
            def gid(kind):
                return f"glue.{kind}[b={bucket}]:master"

            cells = [
                ("embed", (sds((v, d), f32), sds((bucket,), i32))),
                ("norm", (sds((bucket, d), f32), sds((d,), f32))),
                ("add", (sds((bucket, d), f32), sds((bucket, d), f32))),
                ("act", (sds((bucket, 2 * cfg.d_ff), f32),)),
                ("finish", (sds((bucket, d), f32), sds((d,), f32),
                            sds((d, v), f32))),
            ]
            for kind, args in cells:
                yield ProgramCell(
                    gid(kind), "glue", "master", 0, bucket, (kind,),
                    self._glue_fn(kind), args)
            cache_sds = sds((bucket, self.max_len, hkv, hd), f32)
            ln = ((sds((hd,), f32), sds((hd,), f32)) if cfg.qk_norm else ())
            for window in sorted(set(self._windows), key=repr):
                layer = self._windows.index(window)
                yield ProgramCell(
                    f"glue.attn[w={window},b={bucket}]:master", "glue",
                    "master", layer, bucket, ("attn", window),
                    self.attn_fn(layer),
                    (sds((bucket, self.qkv_dim), f32), cache_sds, cache_sds,
                     sds((bucket,), i32)) + ln)


def _decoder_windows(cfg) -> list:
    from repro.models.transformer import _layer_windows

    return list(_layer_windows(cfg, cfg.layers))


def build_lm_decoder_pipeline(
    cfg,
    params,
    n: int,
    *,
    k_b: int | None = None,
    plan=None,
    backend: str = "lax",
    interpret: bool = True,
    bucket_sizes: Sequence[int] | None = None,
    max_len: int | None = None,
) -> CodedDecoderPipeline:
    """Compile a GQA ``LMConfig`` + f32 params into a coded decoder
    pipeline.  Pass ``k_b`` (even) for a CRME-coded plan with recovery
    threshold ``k_b/2``, or ``plan=UncodedPlan(n)`` for the straggler-bound
    uncoded baseline; ``plan`` wins when both are given."""
    if plan is None:
        if k_b is None:
            raise ValueError("need k_b or plan")
        plan = FcdccPlan(n=n, k_a=1, k_b=k_b)
    if plan.n != n:
        raise ValueError(f"plan targets n={plan.n}, requested n={n}")
    return CodedDecoderPipeline(
        cfg, params, plan, backend=backend, interpret=interpret,
        bucket_sizes=bucket_sizes, max_len=max_len,
    )
