"""Numerically Stable Coded Tensor Convolution (Sec. III).

Tensor-list x matrix encoding (eq. 18), per-worker pairwise convolution
subtasks (eq. 20/38), and decode-from-any-delta-workers (eq. 23/45).

The code matrices are abstracted behind the light ``AxisCode`` protocol
(``.k``, ``.ell``, ``.matrix``) so the same machinery runs CRME (the paper's
scheme) and the real-Vandermonde / Chebyshev baselines in
``core/baselines.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .crme import recovery_matrix

__all__ = [
    "encode_tensor_list",
    "worker_outputs_to_matrix",
    "decode_solve",
    "decode_blocks",
]


def encode_tensor_list(parts: jnp.ndarray, matrix: np.ndarray) -> jnp.ndarray:
    """``parts``: ``(k, *block)``; ``matrix``: ``(k, ell*n)``.

    Returns the coded tensor list ``(n, ell, *block)`` (worker-major) — the
    tensor-list x matrix product of eq. (18) with the per-worker grouping of
    eq. (31)/(36).
    """
    k = parts.shape[0]
    assert matrix.shape[0] == k, (parts.shape, matrix.shape)
    m = jnp.asarray(matrix, dtype=parts.dtype)
    return jnp.einsum("k...,kc->c...", parts, m)


def group_by_worker(coded: jnp.ndarray, ell: int) -> jnp.ndarray:
    """``(ell*n, *block)`` -> ``(n, ell, *block)``."""
    total = coded.shape[0]
    assert total % ell == 0
    return coded.reshape((total // ell, ell) + coded.shape[1:])


def worker_outputs_to_matrix(outputs: jnp.ndarray) -> jnp.ndarray:
    """``(delta, ell2, *block)`` -> ``(delta*ell2, F)`` flattened rows."""
    d, e2 = outputs.shape[:2]
    return outputs.reshape(d * e2, -1)


def decode_solve(e: np.ndarray, coded_rows: jnp.ndarray) -> jnp.ndarray:
    """Solve ``E^T @ Y_true = Y_coded`` for the true block rows.

    ``e``: recovery matrix ``(Q, Q)`` (numpy, float64 — factorized at trace
    time); ``coded_rows``: ``(Q, F)``.  The inverse is taken in float64 on
    the host (it is a tiny Q x Q constant of the program) and applied as a
    single GEMM — the numerically-stable CRME structure is what keeps this
    inversion well-conditioned.
    """
    d = np.linalg.inv(e.T)  # (Q, Q) float64 host-side
    dm = jnp.asarray(d, dtype=coded_rows.dtype)
    return dm @ coded_rows


def decode_blocks(
    a_code,
    b_code,
    worker_ids,
    outputs: jnp.ndarray,
    block_shape: tuple[int, ...],
) -> jnp.ndarray:
    """Full decode: coded worker outputs -> true T_C blocks.

    ``outputs``: ``(delta, ell_a*ell_b, *block_shape)`` stacked in the same
    order as ``worker_ids``.  Returns ``(k_a*k_b, *block_shape)`` ordered
    A-major (``a * k_b + b``).
    """
    e = recovery_matrix(a_code, b_code, worker_ids)
    rows = worker_outputs_to_matrix(outputs)
    true_rows = decode_solve(e, rows)
    q = a_code.k * b_code.k
    return true_rows.reshape((q,) + tuple(block_shape))
