"""CodedLinear: FCDCC applied to dense (1x1-conv) layers.

This is the bridge claimed in DESIGN.md §4 between the paper's ConvL
scheme and the transformer zoo: a linear layer ``Y = X W`` is the
K_H = K_W = s = 1 case of the convolution —

  * KCCP partitions W along its OUTPUT dim into k_b coded parts,
  * APCP degenerates to disjoint row (token) partitioning of X into k_a
    parts (no overlap because the "kernel" is 1x1 with stride 1),

and the identical CRME encode / any-delta decode applies.  This is how the
framework codes FFN/projection layers of the assigned LM architectures
against stragglers (inference-time model parallelism with redundancy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .crme import make_axis_codes, recovery_matrix
from .fcdcc import FcdccPlan
from .nsctc import encode_tensor_list, group_by_worker

__all__ = ["CodedLinear"]


class CodedLinear:
    """Straggler-coded ``Y = X @ W``.

    ``X``: (T, d_in) split into k_a row blocks; ``W``: (d_in, d_out) split
    into k_b column blocks.  Each of n workers multiplies its ell_a coded
    row blocks with its ell_b coded column blocks; any delta workers
    reconstruct Y exactly.
    """

    def __init__(self, plan: FcdccPlan, t: int, d_in: int, d_out: int):
        self.plan = plan
        self.a_code, self.b_code = plan.codes
        assert t % plan.k_a == 0, (t, plan.k_a)
        assert d_out % plan.k_b == 0, (d_out, plan.k_b)
        self.t, self.d_in, self.d_out = t, d_in, d_out
        self.tb = t // plan.k_a
        self.ob = d_out // plan.k_b
        self.weight_encode_calls = 0
        self._we_src = None  # identity key of the cached coded weights
        self._we = None
        self._decode_cache: dict = {}  # survivor subset -> Q x Q inverse

    # -- master ---------------------------------------------------------
    def encode_inputs(self, x: jnp.ndarray) -> jnp.ndarray:
        parts = x.reshape(self.plan.k_a, self.tb, self.d_in)
        coded = encode_tensor_list(parts, self.a_code.matrix)
        return group_by_worker(coded, self.a_code.ell)  # (n, ell_a, tb, d_in)

    def encode_weights(self, w: jnp.ndarray) -> jnp.ndarray:
        self.weight_encode_calls += 1
        parts = w.reshape(self.d_in, self.plan.k_b, self.ob).swapaxes(0, 1)
        coded = encode_tensor_list(parts, self.b_code.matrix)
        return group_by_worker(coded, self.b_code.ell)  # (n, ell_b, d_in, ob)

    # -- worker -----------------------------------------------------------
    def worker_compute(self, xe_i, we_i):
        """(ell_a, tb, d_in) x (ell_b, d_in, ob) -> (ell_a*ell_b, tb, ob)."""
        y = jnp.einsum("atd,bdo->abto", xe_i, we_i)
        return y.reshape(
            self.plan.ell_a * self.plan.ell_b, self.tb, self.ob
        )

    # -- master: decode ---------------------------------------------------
    def decode_matrix(self, worker_ids) -> np.ndarray:
        """Host-side Q x Q decode inverse for a survivor subset, cached per
        subset.  Callers on a hot path compute this once per observed
        subset and pass it to ``decode`` as a runtime argument."""
        key = tuple(worker_ids)
        d = self._decode_cache.get(key)
        if d is None:
            e = recovery_matrix(self.a_code, self.b_code, list(key))
            d = self._decode_cache[key] = np.linalg.inv(e.T).astype(
                np.float32)
        return d

    def decode(self, worker_ids, outputs, decode_inverse=None):
        """Reconstruct Y from the fastest delta workers' outputs.

        ``decode_inverse`` is the Q x Q inverse as a *runtime* array: inside
        a jitted caller the survivor subset then never retraces (and the
        per-call host ``recovery_matrix`` + ``np.linalg.inv`` round trip is
        gone).  When omitted it is looked up from the per-subset cache.
        """
        if decode_inverse is None:
            decode_inverse = self.decode_matrix(worker_ids)
        d = jnp.asarray(decode_inverse, outputs.dtype)
        q = self.plan.k_a * self.plan.k_b
        rows = outputs.reshape(q, -1)
        blocks = (d @ rows).reshape(q, self.tb, self.ob)
        grid = blocks.reshape(self.plan.k_a, self.plan.k_b, self.tb, self.ob)
        return jnp.transpose(grid, (0, 2, 1, 3)).reshape(self.t, self.d_out)

    def encoded_weights(self, w: jnp.ndarray) -> jnp.ndarray:
        """Encode-once cache keyed on the weight array's identity: repeated
        calls with the same resident W reuse the coded copy."""
        if self._we_src is not w:
            self._we = self.encode_weights(w)
            self._we_src = w
        return self._we

    def run_simulated(self, x, w, worker_ids=None, decode_inverse=None):
        ids = list(range(self.plan.delta)) if worker_ids is None else list(worker_ids)
        xe = self.encode_inputs(x)
        we = self.encoded_weights(w)
        idx = jnp.asarray(ids)
        outs = jax.vmap(self.worker_compute)(xe[idx], we[idx])
        return self.decode(ids, outs, decode_inverse)
