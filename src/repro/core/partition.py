"""APCP / KCCP tensor partitioning (Sec. IV-A/B) and merge (Sec. IV-D).

Pure shape algebra + slicing; the coding lives in ``nsctc.py``.  Everything
here is jit-safe (static shapes derived from a ``ConvGeometry``).

``apcp_partition`` and ``merge_output`` are batch-native: inputs may carry a
leading batch dimension (``(B, C, H, W)`` / blocks ``(Q, B, N/k_b, ., .)``)
so a whole request batch streams through one coded program — the single-image
``(C, H, W)`` form keeps working unchanged.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = ["ConvGeometry", "apcp_partition", "kccp_partition", "merge_output"]


@dataclasses.dataclass(frozen=True)
class ConvGeometry:
    """Static geometry of one coded convolution layer."""

    in_channels: int
    out_channels: int
    height: int  # un-padded input H
    width: int
    kernel_h: int
    kernel_w: int
    stride: int = 1
    padding: int = 0
    k_a: int = 1
    k_b: int = 1

    # ---- derived quantities -------------------------------------------------
    @property
    def padded_h(self) -> int:
        return self.height + 2 * self.padding

    @property
    def padded_w(self) -> int:
        return self.width + 2 * self.padding

    @property
    def out_h(self) -> int:
        return (self.padded_h - self.kernel_h) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.padded_w - self.kernel_w) // self.stride + 1

    @property
    def out_h_padded(self) -> int:
        """H' rounded up to a multiple of k_a (zero-pad rule, Sec. IV-A1)."""
        return -(-self.out_h // self.k_a) * self.k_a

    @property
    def out_h_block(self) -> int:
        return self.out_h_padded // self.k_a

    @property
    def h_hat(self) -> int:
        """Adaptive-padded slice height, eq. (24)."""
        return (self.out_h_block - 1) * self.stride + self.kernel_h

    @property
    def s_hat(self) -> int:
        """Slice stride (start-index step), eq. (25)."""
        return self.out_h_block * self.stride

    @property
    def in_h_needed(self) -> int:
        """Padded input height required so every slice is in-bounds."""
        return (self.k_a - 1) * self.s_hat + self.h_hat

    @property
    def out_c_padded(self) -> int:
        return -(-self.out_channels // self.k_b) * self.k_b

    @property
    def out_c_block(self) -> int:
        return self.out_c_padded // self.k_b


def apcp_partition(x: jnp.ndarray, geo: ConvGeometry) -> jnp.ndarray:
    """Adaptive-Padding Partitioning (Algorithm 2, lines 1-8).

    ``x``: un-padded input ``(C, H, W)`` or batched ``(B, C, H, W)``.
    Applies the layer's conv padding plus the bottom zero-pad that rounds H'
    up to a multiple of ``k_a``, then slices ``k_a`` overlapping subtensors
    of height ``h_hat`` at stride ``s_hat``.  Returns
    ``(k_a, [B,] C, h_hat, W + 2p)``.
    """
    c, h, w = x.shape[-3:]
    assert (c, h, w) == (geo.in_channels, geo.height, geo.width), (
        (c, h, w),
        geo,
    )
    p = geo.padding
    bottom = max(geo.in_h_needed - (h + 2 * p), 0)
    pad = ((0, 0),) * (x.ndim - 2) + ((p, p + bottom), (p, p))
    x = jnp.pad(x, pad)
    parts = [
        x[..., i * geo.s_hat : i * geo.s_hat + geo.h_hat, :]
        for i in range(geo.k_a)
    ]
    return jnp.stack(parts, axis=0)


def kccp_partition(k: jnp.ndarray, geo: ConvGeometry) -> jnp.ndarray:
    """Kernel-Channel Partitioning (Algorithm 3, lines 1-6).

    ``k``: filter ``(N, C, K_H, K_W)`` -> ``(k_b, N/k_b, C, K_H, K_W)``
    (N zero-padded up to a multiple of ``k_b`` if needed).
    """
    n, c, kh, kw = k.shape
    assert (n, c, kh, kw) == (
        geo.out_channels,
        geo.in_channels,
        geo.kernel_h,
        geo.kernel_w,
    )
    pad = geo.out_c_padded - n
    if pad:
        k = jnp.pad(k, ((0, pad), (0, 0), (0, 0), (0, 0)))
    return k.reshape(geo.k_b, geo.out_c_block, c, kh, kw)


def merge_output(blocks: jnp.ndarray, geo: ConvGeometry) -> jnp.ndarray:
    """Assemble decoded blocks into Y (Algorithm 5, steps 5-6).

    ``blocks``: ``(k_a*k_b, [B,] N/k_b, H'/k_a, W')`` ordered A-major
    (``index = a * k_b + b``, matching the T_C layout of eq. 13).
    Returns ``([B,] N, H', W')`` with channel/height padding stripped.
    """
    q = geo.k_a * geo.k_b
    assert blocks.shape[0] == q and blocks.shape[-3:] == (
        geo.out_c_block,
        geo.out_h_block,
        geo.out_w,
    ), (blocks.shape, geo)
    if blocks.ndim == 4:
        grid = blocks.reshape(
            geo.k_a, geo.k_b, geo.out_c_block, geo.out_h_block, geo.out_w
        )
        # -> (k_b, N/k_b, k_a, H'/k_a, W') -> (N_padded, H'_padded, W')
        y = jnp.transpose(grid, (1, 2, 0, 3, 4)).reshape(
            geo.out_c_padded, geo.out_h_padded, geo.out_w
        )
        return y[: geo.out_channels, : geo.out_h, :]
    b = blocks.shape[1]
    grid = blocks.reshape(
        geo.k_a, geo.k_b, b, geo.out_c_block, geo.out_h_block, geo.out_w
    )
    # -> (B, k_b, N/k_b, k_a, H'/k_a, W') -> (B, N_padded, H'_padded, W')
    y = jnp.transpose(grid, (2, 1, 3, 0, 4, 5)).reshape(
        b, geo.out_c_padded, geo.out_h_padded, geo.out_w
    )
    return y[:, : geo.out_channels, : geo.out_h, :]


def block_output_shape(geo: ConvGeometry) -> tuple[int, int, int]:
    return (geo.out_c_block, geo.out_h_block, geo.out_w)


def np_reference_conv(x: np.ndarray, k: np.ndarray, stride: int, padding: int):
    """Tiny O(N*C*H*W*KH*KW) NumPy oracle of eq. (1) for tests."""
    c, h, w = x.shape
    n, c2, kh, kw = k.shape
    assert c == c2
    xp = np.pad(x, ((0, 0), (padding, padding), (padding, padding)))
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    y = np.zeros((n, ho, wo), dtype=np.result_type(x, k))
    for o in range(n):
        for i in range(ho):
            for j in range(wo):
                patch = xp[:, i * stride : i * stride + kh, j * stride : j * stride + kw]
                y[o, i, j] = np.sum(patch * k[o])
    return y
