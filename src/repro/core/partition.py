"""APCP / KCCP tensor partitioning (Sec. IV-A/B) and merge (Sec. IV-D).

Pure shape algebra + slicing; the coding lives in ``nsctc.py``.  Everything
here is jit-safe (static shapes derived from a ``ConvGeometry``).

``apcp_partition`` and ``merge_output`` are batch-native: inputs may carry a
leading batch dimension (``(B, C, H, W)`` / blocks ``(Q, B, N/k_b, ., .)``)
so a whole request batch streams through one coded program — the single-image
``(C, H, W)`` form keeps working unchanged.

Partition-resident transitions (beyond paper): because decode is linear and
the APCP/KCCP grid tiles the output tensor, the inter-layer
decode -> relu -> pool -> re-encode round trip never needs the merged
``(B, C, H, W)`` tensor.  The helpers at the bottom of this module keep the
activation in partition space end to end: ``partition_channel_merge``
rejoins only the KCCP channel groups (the next ConvL convolves over the full
channel axis, so channels must rejoin; the spatial axis stays partitioned),
``partition_relu_pool`` applies ReLU + max-pool per spatial partition with
halo rows exchanged between adjacent partitions (``gather_partition_rows``),
and ``partition_apcp_slices`` re-slices the pooled partitions straight into
the next layer's adaptive-padded APCP parts.  ``partition_transition``
composes them; ``repro.core.pipeline.CodedPipeline`` jit-compiles one such
transition program per (layer, bucket).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ConvGeometry",
    "apcp_partition",
    "kccp_partition",
    "merge_output",
    "partition_channel_merge",
    "partition_pool_bounds",
    "gather_partition_rows",
    "partition_relu_pool",
    "partition_apcp_slices",
    "partition_transition",
]


@dataclasses.dataclass(frozen=True)
class ConvGeometry:
    """Static geometry of one coded convolution layer."""

    in_channels: int
    out_channels: int
    height: int  # un-padded input H
    width: int
    kernel_h: int
    kernel_w: int
    stride: int = 1
    padding: int = 0
    k_a: int = 1
    k_b: int = 1

    # ---- derived quantities -------------------------------------------------
    @property
    def padded_h(self) -> int:
        return self.height + 2 * self.padding

    @property
    def padded_w(self) -> int:
        return self.width + 2 * self.padding

    @property
    def out_h(self) -> int:
        return (self.padded_h - self.kernel_h) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.padded_w - self.kernel_w) // self.stride + 1

    @property
    def out_h_padded(self) -> int:
        """H' rounded up to a multiple of k_a (zero-pad rule, Sec. IV-A1)."""
        return -(-self.out_h // self.k_a) * self.k_a

    @property
    def out_h_block(self) -> int:
        return self.out_h_padded // self.k_a

    @property
    def h_hat(self) -> int:
        """Adaptive-padded slice height, eq. (24)."""
        return (self.out_h_block - 1) * self.stride + self.kernel_h

    @property
    def s_hat(self) -> int:
        """Slice stride (start-index step), eq. (25)."""
        return self.out_h_block * self.stride

    @property
    def in_h_needed(self) -> int:
        """Padded input height required so every slice is in-bounds."""
        return (self.k_a - 1) * self.s_hat + self.h_hat

    @property
    def out_c_padded(self) -> int:
        return -(-self.out_channels // self.k_b) * self.k_b

    @property
    def out_c_block(self) -> int:
        return self.out_c_padded // self.k_b


def apcp_partition(x: jnp.ndarray, geo: ConvGeometry) -> jnp.ndarray:
    """Adaptive-Padding Partitioning (Algorithm 2, lines 1-8).

    ``x``: un-padded input ``(C, H, W)`` or batched ``(B, C, H, W)``.
    Applies the layer's conv padding plus the bottom zero-pad that rounds H'
    up to a multiple of ``k_a``, then slices ``k_a`` overlapping subtensors
    of height ``h_hat`` at stride ``s_hat``.  Returns
    ``(k_a, [B,] C, h_hat, W + 2p)``.
    """
    c, h, w = x.shape[-3:]
    assert (c, h, w) == (geo.in_channels, geo.height, geo.width), (
        (c, h, w),
        geo,
    )
    p = geo.padding
    bottom = max(geo.in_h_needed - (h + 2 * p), 0)
    pad = ((0, 0),) * (x.ndim - 2) + ((p, p + bottom), (p, p))
    x = jnp.pad(x, pad)
    parts = [
        x[..., i * geo.s_hat : i * geo.s_hat + geo.h_hat, :]
        for i in range(geo.k_a)
    ]
    return jnp.stack(parts, axis=0)


def kccp_partition(k: jnp.ndarray, geo: ConvGeometry) -> jnp.ndarray:
    """Kernel-Channel Partitioning (Algorithm 3, lines 1-6).

    ``k``: filter ``(N, C, K_H, K_W)`` -> ``(k_b, N/k_b, C, K_H, K_W)``
    (N zero-padded up to a multiple of ``k_b`` if needed).
    """
    n, c, kh, kw = k.shape
    assert (n, c, kh, kw) == (
        geo.out_channels,
        geo.in_channels,
        geo.kernel_h,
        geo.kernel_w,
    )
    pad = geo.out_c_padded - n
    if pad:
        k = jnp.pad(k, ((0, pad), (0, 0), (0, 0), (0, 0)))
    return k.reshape(geo.k_b, geo.out_c_block, c, kh, kw)


def merge_output(blocks: jnp.ndarray, geo: ConvGeometry) -> jnp.ndarray:
    """Assemble decoded blocks into Y (Algorithm 5, steps 5-6).

    ``blocks``: ``(k_a*k_b, [B,] N/k_b, H'/k_a, W')`` ordered A-major
    (``index = a * k_b + b``, matching the T_C layout of eq. 13).
    Returns ``([B,] N, H', W')`` with channel/height padding stripped.
    """
    q = geo.k_a * geo.k_b
    assert blocks.shape[0] == q and blocks.shape[-3:] == (
        geo.out_c_block,
        geo.out_h_block,
        geo.out_w,
    ), (blocks.shape, geo)
    if blocks.ndim == 4:
        grid = blocks.reshape(
            geo.k_a, geo.k_b, geo.out_c_block, geo.out_h_block, geo.out_w
        )
        # -> (k_b, N/k_b, k_a, H'/k_a, W') -> (N_padded, H'_padded, W')
        y = jnp.transpose(grid, (1, 2, 0, 3, 4)).reshape(
            geo.out_c_padded, geo.out_h_padded, geo.out_w
        )
        return y[: geo.out_channels, : geo.out_h, :]
    b = blocks.shape[1]
    grid = blocks.reshape(
        geo.k_a, geo.k_b, b, geo.out_c_block, geo.out_h_block, geo.out_w
    )
    # -> (B, k_b, N/k_b, k_a, H'/k_a, W') -> (B, N_padded, H'_padded, W')
    y = jnp.transpose(grid, (2, 1, 3, 0, 4, 5)).reshape(
        b, geo.out_c_padded, geo.out_h_padded, geo.out_w
    )
    return y[:, : geo.out_channels, : geo.out_h, :]


def block_output_shape(geo: ConvGeometry) -> tuple[int, int, int]:
    return (geo.out_c_block, geo.out_h_block, geo.out_w)


# -- partition-resident layer transitions ----------------------------------
def partition_channel_merge(blocks: jnp.ndarray, geo: ConvGeometry) -> jnp.ndarray:
    """Rejoin the KCCP channel groups of each spatial partition.

    ``blocks``: decoded grid ``(k_a*k_b, [B,] N/k_b, H'/k_a, W')`` ordered
    A-major.  The next ConvL convolves over the *full* channel axis, so the
    ``k_b`` channel groups must rejoin at every transition; the spatial axis
    stays partitioned.  Returns ``(k_a, [B,] N, H'/k_a, W')`` with the
    zero-padded channels of the last group stripped.
    """
    q = geo.k_a * geo.k_b
    assert blocks.shape[0] == q and blocks.shape[-3:] == (
        geo.out_c_block,
        geo.out_h_block,
        geo.out_w,
    ), (blocks.shape, geo)
    grid = blocks.reshape((geo.k_a, geo.k_b) + blocks.shape[1:])
    if blocks.ndim == 4:  # (k_a, k_b, nb, hb, Wo) -> (k_a, k_b*nb, hb, Wo)
        y = grid.reshape((geo.k_a, geo.out_c_padded) + blocks.shape[-2:])
        return y[:, : geo.out_channels]
    # batched: (k_a, k_b, B, nb, hb, Wo) -> (k_a, B, k_b*nb, hb, Wo)
    y = jnp.transpose(grid, (0, 2, 1, 3, 4, 5)).reshape(
        (geo.k_a, blocks.shape[1], geo.out_c_padded) + blocks.shape[-2:]
    )
    return y[:, :, : geo.out_channels]


def partition_pool_bounds(geo: ConvGeometry, pool: int) -> list[tuple[int, int]]:
    """Static pooled-row ownership of each spatial partition.

    Partition ``a`` owns the pooled rows whose ``pool``-row window *starts*
    inside its row range ``[a*hb, (a+1)*hb)`` — every valid pooled row is
    owned by exactly one partition, the ownership ranges are contiguous, and
    rows whose window would read past ``out_h`` (the merged relu_pool's
    floor-crop) are owned by nobody.  Returns ``[(lo, hi)] * k_a`` in pooled
    row coordinates.
    """
    hb = geo.out_h_block
    h_pool = geo.out_h // pool
    bounds = []
    for a in range(geo.k_a):
        lo = min(-(-(a * hb) // pool), h_pool)
        hi = min(-(-((a + 1) * hb) // pool), h_pool)
        bounds.append((lo, max(hi, lo)))
    return bounds


def gather_partition_rows(parts, r0: int, r1: int) -> jnp.ndarray:
    """Rows ``[r0, r1)`` of the virtual row-concatenation of the spatial
    partitions — the halo-exchange primitive.

    ``parts``: sequence of arrays with rows on axis -2 (ragged row counts
    allowed).  A window straddling a partition boundary reads its trailing
    rows from the following partition(s); everything is static slicing, so
    inside jit this lowers to pure data movement.
    """
    assert r0 <= r1, (r0, r1)
    segs = []
    off = 0
    for arr in parts:
        rows = arr.shape[-2]
        s0, s1 = max(r0 - off, 0), min(r1 - off, rows)
        if s0 < s1:
            segs.append(arr[..., s0:s1, :])
        off += rows
    got = sum(s.shape[-2] for s in segs)
    assert got == r1 - r0, f"rows [{r0}, {r1}) exceed the {off} stacked rows"
    if not segs:
        ref = parts[0]
        return jnp.zeros(ref.shape[:-2] + (0, ref.shape[-1]), ref.dtype)
    return segs[0] if len(segs) == 1 else jnp.concatenate(segs, axis=-2)


def partition_relu_pool(parts, geo: ConvGeometry, pool: int, *,
                        relu: bool = True):
    """ReLU + ``pool x pool`` max-pool per spatial partition, halos exchanged.

    ``parts``: the ``k_a`` full-channel spatial partitions
    ``([B,] C, hb, W')`` (e.g. from ``partition_channel_merge``).  Each
    partition pools exactly the rows it owns (``partition_pool_bounds``);
    windows straddling a boundary read halo rows from the neighbouring
    partition(s), and the invalid zero-pad rows at the bottom of the last
    partition are never touched.  ``relu=False`` skips the nonlinearity
    (the fused transition applies it earlier, in the decode epilogue).

    Returns ``(pooled_parts, bounds)`` — ragged lists in partition order;
    concatenating ``pooled_parts`` on the row axis reproduces
    ``relu_pool(merged)`` exactly (max/relu/slicing only, no float ops).
    """
    assert len(parts) == geo.k_a, (len(parts), geo.k_a)
    if relu:
        parts = [jax.nn.relu(p) for p in parts]
    bounds = partition_pool_bounds(geo, pool)
    if pool == 1:
        pooled = [gather_partition_rows(parts, lo, hi) for lo, hi in bounds]
        return pooled, bounds
    wo = parts[0].shape[-1]
    w2 = wo - wo % pool
    pooled = []
    for lo, hi in bounds:
        rows = gather_partition_rows(parts, lo * pool, hi * pool)[..., :w2]
        shape = rows.shape[:-2] + (hi - lo, pool, w2 // pool, pool)
        pooled.append(jnp.max(rows.reshape(shape), axis=(-3, -1)))
    return pooled, bounds


def partition_apcp_slices(pooled, geo_next: ConvGeometry) -> jnp.ndarray:
    """Re-slice pooled spatial partitions into the next layer's APCP parts.

    ``pooled``: partition-ordered row segments covering pooled rows
    ``[0, geo_next.height)`` (ragged heights fine).  Equivalent to
    ``apcp_partition`` on the merged tensor: slice ``a`` covers virtual
    padded rows ``[a*s_hat, a*s_hat + h_hat)`` where the virtual tensor is
    ``padding`` zero rows, the real pooled rows, then the conv padding plus
    the adaptive bottom zero-pad (Sec. IV-A1) — all assembled from the
    partitions without ever merging.  The conv width padding is applied
    once to the partitions up front (cheaper than padding each of the
    row-overlapping output slices).  Returns
    ``(k_a_next, [B,] C, h_hat, W + 2*padding)``.
    """
    h = geo_next.height
    assert sum(seg.shape[-2] for seg in pooled) == h, (
        [seg.shape for seg in pooled], geo_next,
    )
    assert pooled[0].shape[-1] == geo_next.width, (pooled[0].shape, geo_next)
    p = geo_next.padding
    if p:  # pad width once here, not once per overlapping slice
        wpad = ((0, 0),) * (pooled[0].ndim - 1) + ((p, p),)
        pooled = [jnp.pad(seg, wpad) for seg in pooled]
    ref = pooled[0]

    def zrows(n_rows):
        return jnp.zeros(ref.shape[:-2] + (n_rows, ref.shape[-1]), ref.dtype)

    out = []
    for a in range(geo_next.k_a):
        r0 = a * geo_next.s_hat - p
        r1 = r0 + geo_next.h_hat
        top = min(max(-r0, 0), geo_next.h_hat)  # rows above the real region
        s0, s1 = max(r0, 0), min(r1, h)
        mid = max(s1 - s0, 0)  # overlap with the real pooled rows
        bot = geo_next.h_hat - top - mid  # conv padding + adaptive zero-pad
        segs = []
        if top:
            segs.append(zrows(top))
        if mid:
            segs.append(gather_partition_rows(pooled, s0, s1))
        if bot:
            segs.append(zrows(bot))
        out.append(segs[0] if len(segs) == 1
                   else jnp.concatenate(segs, axis=-2))
    return jnp.stack(out, axis=0)


def partition_transition(blocks: jnp.ndarray, geo: ConvGeometry, pool: int,
                         geo_next: ConvGeometry, *,
                         relu: bool = False) -> jnp.ndarray:
    """Decoded partition grid of layer *i* -> APCP parts of layer *i+1*.

    ``blocks``: ``(k_a*k_b, [B,] N/k_b, H'/k_a, W')`` (already ReLU'd when
    ``relu=False`` — the fused transition applies the nonlinearity in the
    decode epilogue).  The composition of the three partition-space stages:
    channels rejoin per spatial partition (``partition_channel_merge``),
    ReLU + max-pool run per partition with halo rows exchanged between
    adjacent partitions (``partition_relu_pool``), and the pooled
    partitions re-slice straight into ``geo_next``'s adaptive-padded parts
    (``partition_apcp_slices``) — the merged ``([B,] C, H, W)`` tensor is
    never materialized.
    """
    assert geo.out_channels == geo_next.in_channels, (geo, geo_next)
    assert geo_next.height == geo.out_h // pool, (geo, pool, geo_next)
    spatial = partition_channel_merge(blocks, geo)
    if relu:
        spatial = jax.nn.relu(spatial)
    parts = [spatial[a] for a in range(geo.k_a)]
    pooled, _ = partition_relu_pool(parts, geo, pool, relu=False)
    return partition_apcp_slices(pooled, geo_next)


def np_reference_conv(x: np.ndarray, k: np.ndarray, stride: int, padding: int):
    """Tiny O(N*C*H*W*KH*KW) NumPy oracle of eq. (1) for tests."""
    c, h, w = x.shape
    n, c2, kh, kw = k.shape
    assert c == c2
    xp = np.pad(x, ((0, 0), (padding, padding), (padding, padding)))
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    y = np.zeros((n, ho, wo), dtype=np.result_type(x, k))
    for o in range(n):
        for i in range(ho):
            for j in range(wo):
                patch = xp[:, i * stride : i * stride + kh, j * stride : j * stride + kw]
                y[o, i, j] = np.sum(patch * k[o])
    return y
