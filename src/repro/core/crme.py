"""Circulant & Rotation Matrix Embedding (CRME) code construction.

Implements the encoding-matrix algebra of the paper (Sec. III): rotation
blocks ``R_theta^k`` with ``theta = 2*pi/q``, ``q = NextOdd(n)`` odd and
``q >= n``.  The coded evaluation points are effectively the complex roots of
unity ``exp(i * 2*pi*j/q)`` embedded in 2x2 real rotation blocks, which keeps
the recovery (generalized Vandermonde) matrix polynomially conditioned —
``kappa = O(n^{gamma+5.5})`` — versus the exponential blowup of real
Vandermonde codes.

All matrices here are small (``k x ell*n``) and built eagerly in float64
NumPy; they are constants of the distributed program, never traced.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

__all__ = [
    "next_odd",
    "rotation_matrix",
    "CrmeAxisCode",
    "make_axis_codes",
    "joint_columns",
    "recovery_matrix",
    "condition_number",
]


def next_odd(n: int) -> int:
    """Smallest odd integer ``q >= n`` (Algorithm 1's ``Nextodd``)."""
    return n if n % 2 == 1 else n + 1


def rotation_matrix(theta: float) -> np.ndarray:
    c, s = np.cos(theta), np.sin(theta)
    return np.array([[c, -s], [s, c]], dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class CrmeAxisCode:
    """CRME code along one partition axis.

    ``matrix`` has shape ``(k, ell * n)``: column block ``j`` holds the
    ``ell`` coded combinations sent to worker ``j``.

    ``ell == 2`` for genuine CRME coding (k even, k >= 2); the degenerate
    ``k == 1`` axis uses ``ell == 1`` with an all-ones matrix, i.e. the
    uncoded replication limit in which FCDCC collapses to plain spatial or
    channel partitioning (Table II).
    """

    k: int
    n: int
    q: int
    ell: int
    base: int  # exponent multiplier: A uses 1, B uses k_A/2 (eq. 16)
    matrix: np.ndarray  # (k, ell*n), float64

    def worker_columns(self, i: int) -> np.ndarray:
        """The ``(k, ell)`` columns assigned to worker ``i``."""
        return self.matrix[:, self.ell * i : self.ell * (i + 1)]


def _crme_matrix(k: int, n: int, q: int, base: int) -> np.ndarray:
    """Eq. (17): block (a, j) of the (k x 2n) matrix is R_theta^{base*j*a}."""
    theta = 2.0 * np.pi / q
    m = np.zeros((k, 2 * n), dtype=np.float64)
    for a in range(k // 2):
        for j in range(n):
            blk = rotation_matrix(theta * base * j * a)
            m[2 * a : 2 * a + 2, 2 * j : 2 * j + 2] = blk
    return m


@lru_cache(maxsize=None)
def make_axis_codes(k_a: int, k_b: int, n: int, q: int | None = None):
    """Build the (A, B) axis codes for an FCDCC plan.

    ``A`` codes the ``k_a`` input partitions with exponent base 1; ``B``
    codes the ``k_b`` filter partitions with exponent base ``k_a/2`` so the
    Kronecker product spans distinct "degrees" ``a + b*k_a/2`` (eq. 16) —
    exactly the polynomial-code degree layout, evaluated on the unit circle.
    """
    if k_a < 1 or k_b < 1:
        raise ValueError("k_a and k_b must be >= 1")
    for name, k in (("k_a", k_a), ("k_b", k_b)):
        if k != 1 and k % 2 != 0:
            raise ValueError(f"{name} must be 1 or even for CRME (got {k})")
    q = next_odd(n) if q is None else q
    if q < n or q % 2 == 0:
        raise ValueError(f"q must be odd and >= n (got q={q}, n={n})")

    ell_a = 1 if k_a == 1 else 2
    ell_b = 1 if k_b == 1 else 2
    delta = (k_a * k_b) // (ell_a * ell_b)
    if delta > n:
        raise ValueError(
            f"recovery threshold delta={delta} exceeds n={n}; "
            f"need k_a*k_b/(ell_a*ell_b) <= n"
        )

    if ell_a == 1:
        a_mat = np.ones((1, n), dtype=np.float64)
    else:
        a_mat = _crme_matrix(k_a, n, q, base=1)

    b_base = max(k_a // 2, 1)
    if ell_b == 1:
        b_mat = np.ones((1, n), dtype=np.float64)
    else:
        b_mat = _crme_matrix(k_b, n, q, base=b_base)

    a = CrmeAxisCode(k=k_a, n=n, q=q, ell=ell_a, base=1, matrix=a_mat)
    b = CrmeAxisCode(k=k_b, n=n, q=q, ell=ell_b, base=b_base, matrix=b_mat)
    return a, b


def joint_columns(a: CrmeAxisCode, b: CrmeAxisCode, worker: int) -> np.ndarray:
    """All ``ell_a*ell_b`` joint (Kronecker) columns of worker ``i``.

    Returns ``(k_a*k_b, ell_a*ell_b)``; output slot ``beta3 = ell_b*b1 + b2``
    corresponds to coded conv ``X~_{i,b1} * K~_{i,b2}`` and to column
    ``kron(A[:, ell_a*i+b1], B[:, ell_b*i+b2])`` (eq. 20/21, with the
    ordering fixed as documented in DESIGN.md §7).
    """
    a_cols = a.worker_columns(worker)  # (k_a, ell_a)
    b_cols = b.worker_columns(worker)  # (k_b, ell_b)
    cols = []
    for b1 in range(a.ell):
        for b2 in range(b.ell):
            cols.append(np.kron(a_cols[:, b1], b_cols[:, b2]))
    return np.stack(cols, axis=1)  # (k_a*k_b, ell_a*ell_b)


def recovery_matrix(a: CrmeAxisCode, b: CrmeAxisCode, workers) -> np.ndarray:
    """Recovery matrix E (eq. 42) from the given finished-worker indices.

    ``E`` is ``(Q, ell_a*ell_b*delta) = (Q, Q)``; decoding solves
    ``Y_coded = E^T @ Y_true`` for the true output blocks.
    """
    q_total = a.k * b.k
    need = q_total // (a.ell * b.ell)
    workers = list(workers)
    if len(workers) != need:
        raise ValueError(f"need exactly delta={need} workers, got {len(workers)}")
    e = np.concatenate([joint_columns(a, b, i) for i in workers], axis=1)
    assert e.shape == (q_total, q_total)
    return e


def condition_number(e: np.ndarray) -> float:
    return float(np.linalg.cond(e))
