"""Batched multi-layer coded inference engine: the ``CodedPipeline``.

The paper's deployment model (Sec. IV, Fig. 1) pre-stores coded filters on
the workers and streams a whole CNN's ConvL stack through the coded cluster.
This module is that *system* view, versus the per-layer kernel view of
``fcdcc.py``:

  * ``plan_layers``        — compile a ConvL stack (LeNet-5 / AlexNet /
    VGG-16 descriptors from ``repro.models.cnn``) into ``CodedLayerSpec``s,
    choosing per-layer ``(k_a, k_b)`` via the Sec. IV-E cost model
    (``cost.optimal_partition``) unless pinned by the caller.
  * ``CodedPipeline``      — encodes **every** layer's filters exactly once
    at construction (the resident-coded-filter store), caches one jitted
    worker program per distinct worker-program signature, and executes
    decode -> relu -> pool -> re-encode between layers for batched
    ``(B, C, H, W)`` inputs.

Amortization is the point: the seed path rebuilt ``CodedConv2d`` — and
re-encoded filters and re-jitted the worker program — for every layer of
every image.  A ``CodedPipeline`` pays encode+jit once and serves batches at
steady state; ``repro.runtime.FcdccCluster.run_pipeline`` drives the same
specs through the straggler-simulating master/worker runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .cost import CostWeights, optimal_partition
from .crme import recovery_matrix
from .fcdcc import CodedConv2d, FcdccPlan
from .nsctc import encode_tensor_list, group_by_worker
from .partition import ConvGeometry, merge_output, partition_transition

__all__ = [
    "CodedLayerSpec",
    "CodedPipeline",
    "ProgramCell",
    "plan_layers",
    "build_cnn_pipeline",
    "relu_pool",
]


@dataclasses.dataclass(frozen=True)
class ProgramCell:
    """One (program, argument-shape) cell of a pipeline's shape space.

    ``CodedPipeline.program_space`` enumerates every cell the pipeline can
    ever launch — per execution mode, layer, and batch bucket — as
    ``ShapeDtypeStruct`` arguments plus the jitted callable, so static
    analyzers (``repro.analysis``) can trace/lower each program without
    running data.

    ``kind``: ``encoder`` / ``worker`` / ``transition`` / ``decoder``.
    ``mode``: ``direct`` (single-process vmapped path) or ``cluster``
    (per-worker threaded-runtime path).
    ``cache_key``: the pipeline-side program-cache key; cells sharing
    (kind, mode, cache_key) and an argument signature share one jit trace,
    which is what the bounded-trace proof counts.
    ``allowed_const_shapes``: shapes a traced constant may legitimately
    take in this cell (e.g. the cluster encoder bakes the full-n A-code
    matrix — subset-independent, so it cannot cause retraces).
    ``donate_argnums``: argument indices the program donates.
    """

    cell_id: str
    kind: str
    mode: str
    layer: int
    bucket: int
    cache_key: tuple
    fn: callable
    args: tuple
    allowed_const_shapes: tuple = ()
    donate_argnums: tuple = ()

    @property
    def trace_signature(self) -> tuple:
        """What jit specializes on: program identity + argument avals."""
        return (
            self.kind,
            self.mode,
            self.cache_key,
            tuple((a.shape, str(a.dtype)) for a in self.args),
        )


@dataclasses.dataclass(frozen=True)
class CodedLayerSpec:
    """One compiled ConvL of a coded pipeline (static plan + geometry)."""

    name: str
    plan: FcdccPlan
    geo: ConvGeometry
    pool: int = 1  # max-pool factor applied after relu

    @property
    def out_hw(self) -> int:
        """Spatial size seen by the next layer (after pooling)."""
        return self.geo.out_h // self.pool if self.pool > 1 else self.geo.out_h

    @property
    def program_key(self) -> tuple:
        """Worker-program signature: layers sharing it share one jitted
        program (shape specialization is jit's job)."""
        return (
            self.plan.ell_a,
            self.plan.ell_b,
            self.geo.stride,
        )


def relu_pool(y: jnp.ndarray, pool: int) -> jnp.ndarray:
    """ReLU then ``pool x pool`` max-pool on the trailing (H, W) dims."""
    y = jax.nn.relu(y)
    if pool == 1:
        return y
    h, w = y.shape[-2:]
    h2, w2 = h - h % pool, w - w % pool
    y = y[..., :h2, :w2]
    return jnp.max(
        y.reshape(y.shape[:-2] + (h2 // pool, pool, w2 // pool, pool)),
        axis=(-3, -1),
    )


def _choose_kab(geo0: ConvGeometry, q: int, n: int, weights: CostWeights):
    """Cost-optimal feasible (k_a, k_b) with k_a*k_b = q and delta <= n."""
    _, _, landscape = optimal_partition(geo0, q, weights)
    for kab, _cost in sorted(landscape.items(), key=lambda kv: kv[1]):
        try:
            FcdccPlan(n=n, k_a=kab[0], k_b=kab[1])
        except ValueError:
            continue
        return kab
    raise ValueError(f"no feasible (k_a, k_b) for q={q} on n={n} workers")


def plan_layers(
    layers: Iterable,
    input_hw: int,
    n: int,
    *,
    q: int | None = None,
    default_kab: tuple[int, int] | None = None,
    per_layer_kab: dict | None = None,
    weights: CostWeights = CostWeights(),
) -> list[CodedLayerSpec]:
    """Compile a ConvL stack into per-layer coded specs.

    ``layers``: descriptors with ``name/in_ch/out_ch/kernel/stride/padding/
    pool`` attributes (``repro.models.cnn.ConvL`` or compatible).  The
    (k_a, k_b) of each layer comes from, in priority order:
    ``per_layer_kab[name]``, then ``default_kab``, then the cost-optimal
    feasible split of the ``q``-subtask budget (Sec. IV-E) — at least one of
    ``q`` / ``default_kab`` must be given.
    """
    if q is None and default_kab is None:
        raise ValueError("need q (subtask budget) or default_kab")
    specs = []
    hw = input_hw
    for layer in layers:
        geo0 = ConvGeometry(
            in_channels=layer.in_ch,
            out_channels=layer.out_ch,
            height=hw,
            width=hw,
            kernel_h=layer.kernel,
            kernel_w=layer.kernel,
            stride=layer.stride,
            padding=layer.padding,
        )
        kab = (per_layer_kab or {}).get(layer.name, default_kab)
        if kab is None:
            kab = _choose_kab(geo0, q, n, weights)
        k_a, k_b = kab
        plan = FcdccPlan(n=n, k_a=k_a, k_b=k_b)
        geo = dataclasses.replace(geo0, k_a=k_a, k_b=k_b)
        spec = CodedLayerSpec(layer.name, plan, geo, getattr(layer, "pool", 1))
        specs.append(spec)
        hw = spec.out_hw
    return specs


class CodedPipeline:
    """A whole CNN ConvL stack compiled against one coded cluster.

    Construction encodes every layer's filters exactly once (asserted by
    ``filter_encode_calls``); running feeds a ``(B, C, H, W)`` batch through
    encode -> coded worker convs -> decode -> relu -> pool per layer.  The
    per-worker view of the same specs/filters is consumed by
    ``repro.runtime.FcdccCluster`` (resident coded filters + straggler
    simulation); this class is the single-process mathematical engine.
    """

    def __init__(self, specs: Sequence[CodedLayerSpec], params: dict, *,
                 backend: str = "lax", fused_worker: bool = True,
                 interpret: bool = True,
                 bucket_sizes: Sequence[int] | None = None,
                 fuse_transitions: bool = False,
                 donate_transitions: bool | None = None,
                 pool: str | None = None, devices=None):
        specs = list(specs)
        if not specs:
            raise ValueError("empty pipeline")
        ns = {s.plan.n for s in specs}
        if len(ns) != 1:
            raise ValueError(f"all layers must target the same cluster, got n={ns}")
        self.specs = specs
        self.n = ns.pop()
        self.backend = backend
        # pallas-only: interpret=True emulates the worker kernels on CPU,
        # False lowers them to Mosaic for real TPU hardware
        self.interpret = interpret
        # worker-pool preference carried to whichever FcdccCluster /
        # CodedServer adopts this pipeline (None = auto-select there);
        # the pipeline's own math never consults it
        self.pool = pool
        self.devices = devices
        # partition-resident transitions: between ConvLs the activation is
        # decoded only to the (k_a, k_b) partition grid, relu+pool run per
        # spatial partition with halo exchange, and the partitions re-encode
        # directly — one jitted transition program per (layer, bucket), no
        # merged (B, C, H, W) round trip.  The final layer always merges.
        self.fuse_transitions = fuse_transitions
        # donate the fastest-delta worker-output buffer into the fused
        # transition program: between ConvL rounds the decode consumes
        # ``outs`` exactly once, so XLA can reuse its pages for the coded
        # next-layer shares instead of holding both live (allocator
        # pressure scales with delta x block x bucket otherwise).  None =
        # donate wherever XLA honors donation (CPU does not — it warns and
        # copies, so the CPU default keeps donation off).  Callers that
        # re-feed the same ``outs`` array into a transition twice (paired
        # benchmarks) must pass False.
        if donate_transitions is None:
            donate_transitions = jax.default_backend() != "cpu"
        self.donate_transitions = donate_transitions
        # batch-size buckets: callers pad request batches up to one of these
        # sizes (``pad_to_bucket``) so jit compiles a *bounded* set of batch
        # programs — one per (program, bucket), never one per batch size
        self.bucket_sizes: tuple[int, ...] | None = (
            self.normalize_buckets(bucket_sizes) if bucket_sizes else None
        )
        self.layers = [
            CodedConv2d(s.plan, s.geo, backend=backend,
                        fused_worker=fused_worker, interpret=interpret)
            for s in specs
        ]
        # resident coded filters: encoded exactly once, reused every run
        self.coded_filters = [
            layer.encode_filters(jnp.asarray(params[s.name]))
            for s, layer in zip(specs, self.layers)
        ]
        self.input_encode_calls = 0
        # program caches -------------------------------------------------
        self._encoders: dict[int, callable] = {}
        self._cluster_programs: dict[tuple, callable] = {}  # per-worker call
        self._batch_programs: dict[tuple, callable] = {}  # vmapped over workers
        self._decoders: dict[int, callable] = {}  # one per layer, any subset
        self._transitions: dict[tuple, callable] = {}  # by transition key
        self._all_encode_columns: dict[int, jnp.ndarray] = {}  # full-n, resident

    @staticmethod
    def normalize_buckets(bucket_sizes: Sequence[int]) -> tuple[int, ...]:
        """Sorted, deduplicated, validated bucket tuple (assign this — never
        a raw sequence — to ``bucket_sizes``)."""
        buckets = tuple(sorted(set(int(b) for b in bucket_sizes)))
        if not buckets or buckets[0] < 1:
            raise ValueError(f"bucket sizes must be >= 1, got {bucket_sizes}")
        return buckets

    # -- introspection -----------------------------------------------------
    @property
    def input_shape(self) -> tuple[int, int, int]:
        """Per-image ``(C, H, W)`` the first layer expects."""
        spec0 = self.specs[0]
        return (spec0.geo.in_channels, spec0.geo.height, spec0.geo.width)

    @property
    def input_dtype(self):
        """Request dtype: everything is cast to the coded-filter dtype so a
        stray client dtype can never grow the jit program cache."""
        return self.coded_filters[0].dtype

    @property
    def num_geometries(self) -> int:
        """Distinct (program key, geometry) pairs — with bucketing, the jit
        trace count is bounded by ``num_geometries * len(bucket_sizes)``."""
        return len({(s.program_key, s.geo) for s in self.specs})

    @staticmethod
    def _transition_key(spec: CodedLayerSpec, nxt: CodedLayerSpec) -> tuple:
        """Transition-program signature: everything the traced program
        closes over.  Adjacent layer pairs sharing it share one jitted
        program (e.g. VGG-16's repeated same-shape conv blocks), exactly
        as ``worker_program`` shares by ``program_key``."""
        return (spec.geo, spec.pool, nxt.geo, nxt.plan.ell_a)

    @property
    def num_transitions(self) -> int:
        """Distinct fused transition-program signatures across adjacent
        ConvL pairs when ``fuse_transitions`` (repeated transition
        geometries share one program), else zero."""
        if not self.fuse_transitions:
            return 0
        return len({
            self._transition_key(s, n)
            for s, n in zip(self.specs, self.specs[1:])
        })

    @property
    def transition_program_traces(self) -> int:
        """Shape-specialized compilations across the jitted transition
        programs — bounded by ``num_transitions * len(bucket_sizes)``."""
        return sum(
            fn._cache_size() if hasattr(fn, "_cache_size") else 1
            for fn in self._transitions.values()
        )

    @property
    def program_trace_bound(self) -> int:
        """The bounded-program contract under bucketing: worker-program plus
        transition-program traces never exceed (worker geometries + fused
        transition geometries) x buckets, no matter how many distinct batch
        sizes or survivor subsets the server has seen."""
        buckets = len(self.bucket_sizes) if self.bucket_sizes else 1
        return (self.num_geometries + self.num_transitions) * buckets

    @property
    def filter_encode_calls(self) -> int:
        """Total ``encode_filters`` invocations across layers (== number of
        layers when the encode-once contract holds)."""
        return sum(layer.filter_encode_calls for layer in self.layers)

    @property
    def num_worker_programs(self) -> int:
        """Distinct jitted worker programs in use.  The vmapped
        single-process cache and the per-worker cluster cache hold distinct
        compiled programs even for the same program key, so both count."""
        return len(self._batch_programs) + len(self._cluster_programs)

    @property
    def worker_program_traces(self) -> int:
        """Total shape-specialized compilations across all jitted worker
        programs.  With bucketed batches this is bounded by
        ``len(layer geometries) * len(bucket_sizes)`` regardless of how many
        distinct request-batch sizes the server has seen."""
        return sum(
            fn._cache_size() if hasattr(fn, "_cache_size") else 1
            for cache in (self._batch_programs, self._cluster_programs)
            for fn in cache.values()
        )

    def layer_delta(self, idx: int) -> int:
        return self.specs[idx].plan.delta

    # -- batch-size bucketing ----------------------------------------------
    @property
    def max_batch(self) -> int | None:
        """Largest admissible request batch (None = unbucketed/unbounded)."""
        return self.bucket_sizes[-1] if self.bucket_sizes else None

    def bucketize(self, batch: int) -> int:
        """Smallest bucket >= ``batch`` (identity when unbucketed)."""
        if self.bucket_sizes is None:
            return batch
        for b in self.bucket_sizes:
            if b >= batch:
                return b
        raise ValueError(
            f"batch {batch} exceeds the largest bucket {self.bucket_sizes[-1]}"
        )

    def pad_to_bucket(self, x: jnp.ndarray, axis: int = 0) -> tuple[jnp.ndarray, int]:
        """Zero-pad a batch up to its bucket size along ``axis``.

        ``axis=0`` is the plain ``(B, C, H, W)`` batch; partition-resident
        serving also pads mid-stack coded-share state (batch on axis 2 of
        ``(n, ell_a, B, C, h_hat, Wp)``).  Returns ``(padded, real_batch)``;
        the caller keeps the first ``real_batch`` rows along ``axis``.
        Padding rows are zeros — a zero activation encodes to zero shares,
        convolves to zero, and stays zero through relu/pool/halo, so they
        ride the whole coded stack as dead weight and are dropped at the
        end."""
        b = x.shape[axis]
        bucket = self.bucketize(b)
        if bucket == b:
            return x, b
        pad_shape = x.shape[:axis] + (bucket - b,) + x.shape[axis + 1:]
        return jnp.concatenate([x, jnp.zeros(pad_shape, x.dtype)], axis=axis), b

    # -- program caches ----------------------------------------------------
    def encoder(self, idx: int):
        """Jitted APCP+encode program for layer ``idx`` (the layer's own
        ``encode_inputs``; its call counter only ticks at trace time — the
        pipeline counts real invocations in ``input_encode_calls``)."""
        fn = self._encoders.get(idx)
        if fn is None:
            fn = self._encoders[idx] = jax.jit(self.layers[idx].encode_inputs)
        return fn

    def worker_program(self, idx: int, *, over_workers: bool = True):
        """The jitted coded worker program for layer ``idx``.

        ``over_workers=True`` gives the vmapped-over-the-worker-axis program
        (the single-process path); ``False`` gives the one-worker program the
        threaded cluster dispatches per worker.  Layers with the same
        ``program_key`` share one program — jit's shape cache handles the
        per-geometry specialization, so e.g. VGG-16's thirteen ConvLs run on
        a handful of compiled programs.
        """
        cache = self._batch_programs if over_workers else self._cluster_programs
        key = self.specs[idx].program_key
        fn = cache.get(key)
        if fn is None:
            compute = self.layers[idx].worker_compute
            fn = cache[key] = jax.jit(
                jax.vmap(compute) if over_workers else compute
            )
        return fn

    def encode_columns(self, idx: int, worker_ids: tuple[int, ...]) -> np.ndarray:
        """The A-code encoding columns of the selected workers — encoding
        with this slice produces only those workers' coded input shares
        ((n - delta)/n of the encode GEMM skipped versus full-n).

        Computed per call: the slice is a cheap host-side concat, and the
        threads-mode cluster picks timing-dependent subsets, so a per-subset
        cache would grow without bound on a persistent pipeline."""
        code = self.layers[idx].a_code
        return np.concatenate(
            [code.worker_columns(i) for i in worker_ids], axis=1
        )

    def encode_columns_all(self, idx: int) -> jnp.ndarray:
        """The full-n A-code encode columns of layer ``idx`` as a resident
        device array.  Unlike the timing-dependent subsets of
        ``encode_columns``, the all-workers matrix is one fixed constant
        per layer, so it is cached (one entry per layer, bounded) — the
        cluster's fused transition rounds re-encode for all n workers
        every round and must not rebuild + re-upload it each time."""
        m = self._all_encode_columns.get(idx)
        if m is None:
            m = self._all_encode_columns[idx] = jnp.asarray(
                self.layers[idx].a_code.matrix
            )
        return m

    def decode_matrix(self, idx: int, worker_ids: tuple[int, ...]) -> np.ndarray:
        """The QxQ decode inverse for layer ``idx`` under the given
        surviving-worker subset (host-side float64).  Computed per call —
        inverting a QxQ (e.g. 16x16) matrix costs microseconds, while a
        per-subset cache would grow up to C(n, delta) entries under the
        threads-mode cluster's timing-dependent subsets."""
        layer = self.layers[idx]
        e = recovery_matrix(layer.a_code, layer.b_code, list(worker_ids))
        return np.linalg.inv(e.T)

    def decoder_fn(self, idx: int):
        """The jitted decode+merge+relu+pool program for layer ``idx``,
        taking ``(outs, decode_matrix)``.

        One jitted program per layer: the decode inverse is a *runtime
        argument* (constant (Q, Q) shape), so the timing-dependent
        fastest-delta subsets chosen by the cluster never trigger a
        recompile or grow the program cache.
        """
        spec = self.specs[idx]
        fn = self._decoders.get(idx)
        if fn is None:
            q = spec.plan.k_a * spec.plan.k_b

            def dec(outs, d, _q=q, _geo=spec.geo, _pool=spec.pool):
                rows = outs.reshape(outs.shape[0] * outs.shape[1], -1)
                true_rows = d.astype(rows.dtype) @ rows
                blocks = true_rows.reshape((_q,) + outs.shape[2:])
                return relu_pool(merge_output(blocks, _geo), _pool)

            fn = self._decoders[idx] = jax.jit(dec)
        return fn

    def decoder(self, idx: int, worker_ids: tuple[int, ...]):
        """``decoder_fn`` with the subset's decode inverse bound; returns
        ``fn(outs)``."""
        fn = self.decoder_fn(idx)
        d = jnp.asarray(self.decode_matrix(idx, worker_ids))
        return lambda outs: fn(outs, d)

    def transition_fn(self, idx: int):
        """The jitted partition-resident transition program between ConvL
        ``idx`` and ``idx + 1``, taking ``(outs, decode_matrix,
        next_encode_columns)``.

        One program fuses the whole inter-layer round trip: decode layer
        ``idx``'s fastest-delta outputs only to the ``(k_a, k_b)`` grid,
        ReLU (in the decode epilogue), per-partition max-pool with halo
        exchange, re-slice into layer ``idx + 1``'s adaptive-padded APCP
        parts, and re-encode — the merged ``(B, C, H, W)`` tensor is never
        materialized.  The decode inverse and the next layer's encode
        columns are *runtime arguments* (constant shapes), so any
        timing-dependent survivor subset and any next-round worker
        selection reuse the one program per (transition geometry, bucket)
        — the bounded-program contract extends to transitions, and
        adjacent pairs with the same transition signature (repeated conv
        blocks) share one program.
        """
        if not 0 <= idx < len(self.specs) - 1:
            raise ValueError(f"no transition after layer {idx} "
                             f"({len(self.specs)} layers)")
        key = self._transition_key(self.specs[idx], self.specs[idx + 1])
        fn = self._transitions.get(key)
        if fn is None:
            spec, nxt = self.specs[idx], self.specs[idx + 1]
            q = spec.plan.k_a * spec.plan.k_b
            ell_next = nxt.plan.ell_a
            geo, pool, geo_next = spec.geo, spec.pool, nxt.geo

            def assemble(blocks):
                # relu already applied by the decode epilogue
                return partition_transition(blocks, geo, pool, geo_next,
                                            relu=False)

            if self.backend == "pallas":
                from repro.kernels.conv2d.ops import coded_transition

                interpret = self.interpret

                def trans(outs, d, m_next):
                    coded = coded_transition(outs, d, m_next, assemble,
                                             interpret=interpret)
                    return group_by_worker(coded, ell_next)
            else:
                def trans(outs, d, m_next):
                    rows = outs.reshape(outs.shape[0] * outs.shape[1], -1)
                    blocks = jax.nn.relu(
                        (d.astype(rows.dtype) @ rows)
                        .reshape((q,) + outs.shape[2:])
                    )
                    parts = assemble(blocks)
                    coded = encode_tensor_list(parts, m_next)
                    return group_by_worker(coded, ell_next)

            fn = self._transitions[key] = jax.jit(
                trans,
                donate_argnums=(0,) if self.donate_transitions else (),
            )
        return fn

    # -- kernel autotuning ---------------------------------------------------
    def autotune_kernels(self, bucket_sizes: Sequence[int] | None = None, *,
                         repeat: int = 3, force: bool = False,
                         path: str | None = None) -> dict:
        """Sweep every Pallas kernel cell this pipeline will launch and
        persist the winners in the autotune ledger (``repro.kernels
        .autotune``), then drop the compiled-program caches so rebuilt
        programs pick the tuned tiles up at their next trace.

        Cells are enumerated in *shape space* (``jax.eval_shape`` walks the
        encode -> worker -> transition chain without running it), one per
        (layer geometry, bucket): the worker's implicit-GEMM conv, and —
        under ``fuse_transitions`` — the transition's decode GEMM plus both
        re-encode GEMM widths (the fastest-delta subset the single-process
        path feeds it, and the all-n re-encode the cluster runtime uses).
        Already-cached cells return instantly (``force`` re-sweeps), so
        calling this at server startup costs sweeps only on a cold ledger.
        Returns ``{ledger key: winning params}`` for the cells visited.
        """
        if self.backend != "pallas":
            return {}
        from repro.kernels import autotune

        buckets = (self.normalize_buckets(bucket_sizes) if bucket_sizes
                   else (self.bucket_sizes or (1,)))
        last = len(self.specs) - 1
        tuned: dict[str, dict] = {}
        for bucket in buckets:
            x = jax.ShapeDtypeStruct((bucket,) + self.input_shape,
                                     self.input_dtype)
            for idx, (spec, layer) in enumerate(zip(self.specs, self.layers)):
                ids = self.layer_worker_ids(idx)
                m_sel = jax.ShapeDtypeStruct(
                    self.encode_columns(idx, ids).shape, self.input_dtype)
                xe = jax.eval_shape(layer.encode_inputs, x, m_sel)
                ke_shape = self.coded_filters[idx].shape[1:]
                wkey = autotune.worker_key(
                    xe.shape[1:], ke_shape, spec.geo.stride,
                    interpret=self.interpret)
                tuned[wkey] = autotune.tune_worker(
                    xe.shape[1:], ke_shape, spec.geo.stride,
                    interpret=self.interpret, dtype=self.input_dtype,
                    repeat=repeat, force=force, path=path)
                outs = jax.eval_shape(
                    jax.vmap(layer.worker_compute),
                    jax.ShapeDtypeStruct((len(ids),) + xe.shape[1:],
                                         xe.dtype),
                    jax.ShapeDtypeStruct((len(ids),) + ke_shape,
                                         self.coded_filters[idx].dtype),
                )
                if self.fuse_transitions and idx < last:
                    q = outs.shape[0] * outs.shape[1]
                    f = int(np.prod(outs.shape[2:]))
                    dkey = autotune.matmul_key(q, q, f, relu=True,
                                               interpret=self.interpret)
                    tuned[dkey] = autotune.tune_matmul(
                        q, q, f, relu=True, interpret=self.interpret,
                        dtype=self.input_dtype, repeat=repeat, force=force,
                        path=path)
                    nxt = self.specs[idx + 1]
                    geo, pool, geo_next = spec.geo, spec.pool, nxt.geo

                    def probe(outs_, d_):
                        rows = outs_.reshape(
                            outs_.shape[0] * outs_.shape[1], -1)
                        blocks = (d_.astype(rows.dtype) @ rows).reshape(
                            (q,) + outs_.shape[2:])
                        return partition_transition(blocks, geo, pool,
                                                    geo_next, relu=False)

                    parts = jax.eval_shape(
                        probe, outs,
                        jax.ShapeDtypeStruct((q, q), outs.dtype))
                    k2 = parts.shape[0]
                    fp = int(np.prod(parts.shape[1:]))
                    ids_next = self.layer_worker_ids(idx + 1)
                    # both re-encode widths: the fastest-delta' subset and
                    # the all-n round the cluster runtime re-encodes for
                    widths = {
                        self.encode_columns(idx + 1, ids_next).shape[1],
                        self.encode_columns_all(idx + 1).shape[1],
                    }
                    for width in sorted(widths):
                        ekey = autotune.matmul_key(
                            width, k2, fp, interpret=self.interpret)
                        tuned[ekey] = autotune.tune_matmul(
                            width, k2, fp, interpret=self.interpret,
                            dtype=self.input_dtype, repeat=repeat,
                            force=force, path=path)
                # next layer sees this layer's pooled output
                x = jax.ShapeDtypeStruct(
                    (bucket, spec.geo.out_channels, spec.out_hw,
                     spec.out_hw), self.input_dtype)
        # rebuilt programs consult the fresh winners at their next trace
        self._batch_programs.clear()
        self._cluster_programs.clear()
        self._transitions.clear()
        return tuned

    # -- shape-space enumeration -------------------------------------------
    def program_space(self, bucket_sizes: Sequence[int] | None = None, *,
                      modes: Sequence[str] = ("direct", "cluster")):
        """Enumerate every program cell this pipeline can launch, in shape
        space — no data is executed.

        Yields one ``ProgramCell`` per (mode, layer, bucket, program kind),
        walking the encode -> worker -> transition/decode chain with
        ``jax.eval_shape`` exactly as execution would (the same walk
        ``autotune_kernels`` performs).  ``direct`` is the single-process
        path (vmapped worker over the fastest-delta axis, subset-width
        re-encodes); ``cluster`` is the threaded-runtime path (per-worker
        programs, full-n re-encodes, full-matrix encoder).  Survivor
        subsets never appear in the signatures — only ``delta`` (the subset
        *size*) does — which is the shape-space half of the no-retrace
        contract; ``repro.analysis`` checks the other half (matrices enter
        as runtime arguments, not baked constants) on the traced jaxprs.
        """
        buckets = (self.normalize_buckets(bucket_sizes) if bucket_sizes
                   else (self.bucket_sizes or (1,)))
        last = len(self.specs) - 1
        dtype = self.input_dtype
        for mode in modes:
            if mode not in ("direct", "cluster"):
                raise ValueError(f"unknown mode {mode!r}")
            for bucket in buckets:
                x = jax.ShapeDtypeStruct((bucket,) + self.input_shape, dtype)
                for idx, (spec, layer) in enumerate(
                        zip(self.specs, self.layers)):
                    def cid(kind):
                        return f"{spec.name}[b={bucket}]/{kind}:{mode}"

                    ids = self.layer_worker_ids(idx)
                    delta = len(ids)
                    m_sel = jax.ShapeDtypeStruct(
                        self.encode_columns(idx, ids).shape, dtype)
                    ke_shape = self.coded_filters[idx].shape[1:]
                    # the encoder runs on every layer when unfused, and only
                    # on layer 0 when transitions re-encode in coded space
                    if not self.fuse_transitions or idx == 0:
                        if mode == "direct":
                            yield ProgramCell(
                                cid("encoder"), "encoder", mode, idx, bucket,
                                (idx,), self.encoder(idx), (x, m_sel))
                        else:
                            # the cluster encodes all n workers' shares with
                            # the resident full matrix (one-arg call bakes
                            # it — subset-independent, hence allowed)
                            yield ProgramCell(
                                cid("encoder"), "encoder", mode, idx, bucket,
                                (idx,), self.encoder(idx), (x,),
                                allowed_const_shapes=(
                                    tuple(layer.a_code.matrix.shape),))
                    xe = jax.eval_shape(layer.encode_inputs, x, m_sel)
                    if mode == "direct":
                        yield ProgramCell(
                            cid("worker"), "worker", mode, idx, bucket,
                            spec.program_key, self.worker_program(idx),
                            (jax.ShapeDtypeStruct(
                                (delta,) + xe.shape[1:], xe.dtype),
                             jax.ShapeDtypeStruct(
                                (delta,) + ke_shape, dtype)))
                    else:
                        yield ProgramCell(
                            cid("worker"), "worker", mode, idx, bucket,
                            spec.program_key,
                            self.worker_program(idx, over_workers=False),
                            (jax.ShapeDtypeStruct(xe.shape[1:], xe.dtype),
                             jax.ShapeDtypeStruct(ke_shape, dtype)))
                    outs = jax.eval_shape(
                        jax.vmap(layer.worker_compute),
                        jax.ShapeDtypeStruct((delta,) + xe.shape[1:],
                                             xe.dtype),
                        jax.ShapeDtypeStruct((delta,) + ke_shape, dtype),
                    )
                    q = spec.plan.k_a * spec.plan.k_b
                    d = jax.ShapeDtypeStruct((q, q), dtype)
                    if self.fuse_transitions and idx < last:
                        if mode == "direct":
                            m_next = jax.ShapeDtypeStruct(
                                self.encode_columns(
                                    idx + 1,
                                    self.layer_worker_ids(idx + 1)).shape,
                                dtype)
                        else:
                            m_next = jax.ShapeDtypeStruct(
                                self.encode_columns_all(idx + 1).shape,
                                dtype)
                        yield ProgramCell(
                            cid("transition"), "transition", mode, idx,
                            bucket,
                            self._transition_key(spec, self.specs[idx + 1]),
                            self.transition_fn(idx), (outs, d, m_next),
                            donate_argnums=(
                                (0,) if self.donate_transitions else ()))
                    if not self.fuse_transitions or idx == last:
                        yield ProgramCell(
                            cid("decoder"), "decoder", mode, idx, bucket,
                            (idx,), self.decoder_fn(idx), (outs, d))
                    x = jax.ShapeDtypeStruct(
                        (bucket, spec.geo.out_channels, spec.out_hw,
                         spec.out_hw), dtype)

    # -- execution ---------------------------------------------------------
    def layer_worker_ids(self, idx: int, worker_ids=None) -> tuple[int, ...]:
        """The survivors layer ``idx`` decodes from: the first delta of the
        available workers (all n when ``worker_ids`` is None)."""
        delta = self.layer_delta(idx)
        avail = list(range(self.n)) if worker_ids is None else list(worker_ids)
        if len(avail) < delta:
            raise ValueError(
                f"layer {self.specs[idx].name} needs delta={delta} workers, "
                f"got {len(avail)}"
            )
        return tuple(avail[:delta])

    def run(self, x: jnp.ndarray, worker_ids=None) -> jnp.ndarray:
        """Coded inference of the whole ConvL stack.

        ``x``: ``(B, C, H, W)`` batch or a single ``(C, H, W)`` image.
        ``worker_ids``: the available workers (any >= delta subset of n per
        layer decodes to the same output); default all n.

        With ``fuse_transitions`` the stack runs on the partition-resident
        path: survivor subsets are pre-picked per layer (same first-delta
        rule) and the inter-layer rounds stay in partition space.
        """
        if self.fuse_transitions:
            return self.run_prepared(x, self.prepare(worker_ids))
        squeeze = x.ndim == 3
        if squeeze:
            x = x[None]
        for idx, layer in enumerate(self.layers):
            ids = self.layer_worker_ids(idx, worker_ids)
            self.input_encode_calls += 1
            # encode only the selected workers' shares (matrix is a runtime
            # argument, so any subset reuses the one per-layer program)
            m_sel = jnp.asarray(self.encode_columns(idx, ids))
            xe = self.encoder(idx)(x, m_sel)
            sel = jnp.asarray(ids)
            outs = self.worker_program(idx)(xe, self.coded_filters[idx][sel])
            x = self.decoder(idx, ids)(outs)
        return x[0] if squeeze else x

    def prepare(self, worker_ids=None) -> list[tuple]:
        """Pre-pick every layer's survivor subset and build all host-side
        code artifacts up front: per-layer ``(encode_columns, selector,
        decode_matrix)`` as device arrays.

        ``worker_ids`` is either one available-worker list shared by all
        layers (each layer decodes from its first delta) or a per-layer
        sequence of subsets.  The returned plan is what ``run_prepared``
        executes without any host work between layers."""
        per_layer = (
            worker_ids is not None
            and len(worker_ids) == len(self.specs)
            and all(isinstance(w, (list, tuple)) for w in worker_ids)
        )
        prepped = []
        for idx in range(len(self.specs)):
            avail = worker_ids[idx] if per_layer else worker_ids
            ids = self.layer_worker_ids(idx, avail)
            prepped.append((
                jnp.asarray(self.encode_columns(idx, ids)),
                jnp.asarray(ids),
                jnp.asarray(self.decode_matrix(idx, ids)),
            ))
        return prepped

    def run_prepared(self, x: jnp.ndarray, prepared=None, *, worker_ids=None) -> jnp.ndarray:
        """Coded inference over pre-picked survivor subsets — the serving
        fast path.

        ``run`` interleaves host-side code prep (encode-column slices,
        decode-inverse solves) between device launches, forcing a sync at
        every layer boundary.  Here all of that comes from ``prepare``
        (or is built once up front), so the whole stack is dispatched
        asynchronously: decode of layer *i* overlaps encode of layer *i+1*
        on the device queue.  The serving engine reuses one ``prepare``
        plan across every batch that sees the same survivor set."""
        if prepared is None:
            prepared = self.prepare(worker_ids)
        if len(prepared) != len(self.specs):
            raise ValueError(
                f"prepared plan covers {len(prepared)} layers, "
                f"pipeline has {len(self.specs)}"
            )
        squeeze = x.ndim == 3
        if squeeze:
            x = x[None]
        if self.fuse_transitions:
            # partition-resident path: encode once into layer 0's shares,
            # then thread coded partition-space state between layers — the
            # transition of layer i re-encodes directly for layer i+1's
            # selected workers; only the final layer merges to a tensor.
            last = len(self.specs) - 1
            self.input_encode_calls += 1
            xe = self.encoder(0)(x, prepared[0][0])
            for idx, (m_sel, sel, d) in enumerate(prepared):
                outs = self.worker_program(idx)(
                    xe, self.coded_filters[idx][sel]
                )
                if idx < last:
                    xe = self.transition_fn(idx)(outs, d, prepared[idx + 1][0])
                else:
                    x = self.decoder_fn(idx)(outs, d)
            return x[0] if squeeze else x
        for idx, (m_sel, sel, d) in enumerate(prepared):
            self.input_encode_calls += 1
            xe = self.encoder(idx)(x, m_sel)
            outs = self.worker_program(idx)(xe, self.coded_filters[idx][sel])
            x = self.decoder_fn(idx)(outs, d)
        return x[0] if squeeze else x


def build_cnn_pipeline(
    name: str,
    params: dict,
    n: int,
    *,
    q: int | None = None,
    default_kab: tuple[int, int] | None = None,
    per_layer_kab: dict | None = None,
    input_hw: int | None = None,
    weights: CostWeights = CostWeights(),
    backend: str = "lax",
    interpret: bool = True,
    bucket_sizes: Sequence[int] | None = None,
    fuse_transitions: bool = False,
    donate_transitions: bool | None = None,
    pool: str | None = None,
    devices=None,
) -> CodedPipeline:
    """Compile one of the named CNNs (``lenet5``/``alexnet``/``vgg16``) into
    a ``CodedPipeline`` (lazy model import keeps core free of model deps)."""
    from repro.models.cnn import CNN_SPECS

    hw0, layers = CNN_SPECS[name]
    specs = plan_layers(
        layers,
        input_hw if input_hw is not None else hw0,
        n,
        q=q,
        default_kab=default_kab,
        per_layer_kab=per_layer_kab,
        weights=weights,
    )
    return CodedPipeline(specs, params, backend=backend, interpret=interpret,
                         bucket_sizes=bucket_sizes,
                         fuse_transitions=fuse_transitions,
                         donate_transitions=donate_transitions,
                         pool=pool, devices=devices)
