"""Step-function builders: train / prefill / serve, with shardings.

All builders return ``(fn, in_shardings, out_shardings, example_inputs)``
ready for ``jax.jit(fn, in_shardings=..., out_shardings=...)`` — used both
by the real launchers and by the dry-run (which lowers against
ShapeDtypeStructs only).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import schema_pspecs, schema_shapes
from repro.optim import AdamWConfig, apply_updates, compress_tree, init_state
from repro.optim.schedule import cosine_with_warmup
from repro.sharding import resolve_pspec


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    warmup: int = 100
    total_steps: int = 10000
    grad_compression: str | None = None  # None | "int8" | "topk"
    # layer-level remat lives inside the models (scan bodies are
    # jax.checkpoint'ed); this flag adds a whole-loss remat on top.
    remat: bool = False
    # gradient accumulation: saved activations scale with B/microbatches,
    # the capacity lever for large-model train cells (§Perf).
    microbatches: int = 1
    # FSDP/ZeRO: shard params/grads/optimizer state over the data axes too.
    fsdp: bool = True


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def batch_pspecs(bundle, batch_shapes, mesh):
    axes = bundle.batch_axes(batch_shapes)
    return jax.tree.map(
        lambda leaf, ax: resolve_pspec(leaf.shape, ax, dict(mesh.shape)),
        batch_shapes,
        axes,
    )


def cache_pspecs(bundle, cache_shapes, mesh):
    axes = bundle.cache_axes(cache_shapes)
    return jax.tree.map(
        lambda leaf, ax: resolve_pspec(leaf.shape, ax, dict(mesh.shape)),
        cache_shapes,
        axes,
    )


def opt_state_pspecs(param_pspecs):
    return {
        "m": param_pspecs,
        "v": param_pspecs,
        "step": P(),
    }


def build_train_step(bundle, mesh, tcfg: TrainConfig = TrainConfig()):
    """train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    Cross-pod gradient compression: when the mesh has a "pod" axis and
    compression is enabled, gradients are (error-feedback) compressed before
    the optimizer — modeling the deployed compress -> pod-reduce ->
    decompress pipeline with reproducible numerics (DESIGN.md §5).
    """
    loss_fn = bundle.loss_fn
    if tcfg.remat:
        loss_fn = jax.checkpoint(loss_fn)

    param_ps = schema_pspecs(bundle.schema, mesh, fsdp=tcfg.fsdp)
    opt_ps = opt_state_pspecs(param_ps)

    def _constrain_like_params(tree):
        return jax.tree.map(
            lambda a, ps: jax.lax.with_sharding_constraint(a, ps), tree, param_ps
        )

    def _grads(params, batch):
        m = tcfg.microbatches
        if m <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        mb = jax.tree.map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:])
            if getattr(x, "ndim", 0) >= 1 and x.shape[0] % m == 0
            else jnp.broadcast_to(x, (m,) + getattr(x, "shape", ())),
            batch,
        )

        def body(acc, mbatch):
            loss, g = jax.value_and_grad(loss_fn)(params, mbatch)
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(a.dtype) / m, acc, g
            )
            return _constrain_like_params(acc), loss

        acc0 = _constrain_like_params(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )
        acc, losses = jax.lax.scan(body, acc0, mb)
        return jnp.mean(losses), acc

    def train_step(params, opt_state, batch):
        loss, grads = _grads(params, batch)
        if tcfg.grad_compression and "pod" in mesh.shape:
            grads, _ = compress_tree(grads, None, tcfg.grad_compression)
        lr_scale = cosine_with_warmup(
            opt_state["step"], warmup=tcfg.warmup, total=tcfg.total_steps
        )
        params, opt_state, metrics = apply_updates(
            params, grads, opt_state, tcfg.opt, lr_scale
        )
        return params, opt_state, {"loss": loss, **metrics}

    return train_step, param_ps, opt_ps


def build_prefill_step(bundle, mesh):
    def prefill(params, batch):
        return bundle.prefill_fn(params, batch)

    return prefill, schema_pspecs(bundle.schema, mesh)


def build_serve_step(bundle, mesh):
    def serve(params, cache, batch):
        logits, cache = bundle.decode_fn(params, cache, batch)
        # greedy next token (serving loop feeds it back)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve, schema_pspecs(bundle.schema, mesh)


def make_opt_shapes(bundle, dtype=jnp.bfloat16):
    params = schema_shapes(bundle.schema, dtype)
    return jax.eval_shape(init_state, params)
