"""End-to-end training driver.

Runs on whatever devices exist (1 CPU here; a pod via the production mesh)
with the full production substrate: sharded params/opt-state, deterministic
resumable data pipeline, checkpoint/restart (async), straggler-aware
logging, optional cross-pod gradient compression.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 256 --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro import compat
from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import get_bundle
from repro.data import DataConfig, SyntheticTokens
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig, init_state


def train(arch: str, *, steps: int, batch: int, seq: int, smoke: bool,
          ckpt_dir: str | None = None, ckpt_every: int = 50,
          grad_compression: str | None = None, lr: float = 3e-4,
          mesh=None, log_every: int = 10, param_dtype=jnp.float32):
    bundle = get_bundle(arch, smoke=smoke)
    mesh = mesh or make_host_mesh()
    tcfg = steps_mod.TrainConfig(
        opt=AdamWConfig(lr=lr), warmup=min(20, steps // 10 + 1),
        total_steps=steps, grad_compression=grad_compression,
    )
    step_fn, param_ps, opt_ps = steps_mod.build_train_step(bundle, mesh, tcfg)

    with compat.set_mesh(mesh):
        params = bundle.init(jax.random.PRNGKey(0), param_dtype)
        opt_state = init_state(params)
        start = 0
        ckpt = None
        if ckpt_dir:
            ckpt = AsyncCheckpointer(ckpt_dir)
            last = latest_step(ckpt_dir)
            if last is not None:
                state = restore(ckpt_dir, last, {"params": params, "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                start = last
                print(f"restored step {start} from {ckpt_dir}")

        data = SyntheticTokens(
            DataConfig(vocab=bundle.cfg.vocab, seq_len=seq, global_batch=batch)
        )
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        losses = []
        t0 = time.time()
        for step in range(start, steps):
            hb = data.batch(step)
            b = {k: jnp.asarray(v) for k, v in hb.items()}
            if bundle.family == "encdec":
                b["frames"] = jax.random.normal(
                    jax.random.PRNGKey(step), (batch, bundle.cfg.enc_len, bundle.cfg.d_model),
                    param_dtype,
                )
            if bundle.family == "vlm":
                b["prefix"] = jax.random.normal(
                    jax.random.PRNGKey(step), (batch, 8, bundle.cfg.d_model), param_dtype
                )
            params, opt_state, metrics = jitted(params, opt_state, b)
            losses.append(float(metrics["loss"]))
            if (step + 1) % log_every == 0:
                dt = (time.time() - t0) / log_every
                print(
                    f"step {step+1:5d} loss {losses[-1]:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms/step",
                    flush=True,
                )
                t0 = time.time()
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.submit(step + 1, {"params": params, "opt": opt_state})
        if ckpt:
            ckpt.submit(steps, {"params": params, "opt": opt_state})
            ckpt.wait()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    losses = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        smoke=args.smoke, ckpt_dir=args.ckpt_dir,
        grad_compression=args.grad_compression, lr=args.lr,
    )
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
