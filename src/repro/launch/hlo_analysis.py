"""Static HLO cost analyzer for the roofline.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so scanned
layer stacks (and flash-attention inner scans) are undercounted by their
trip counts.  This module parses the post-optimization HLO text and:

  * multiplies while-loop bodies by their trip counts (from the
    ``known_trip_count`` backend_config XLA attaches to scan-lowered loops),
  * counts dot/convolution FLOPs exactly from shapes + contraction dims
    (per-computation symbol table resolves operand shapes),
  * recurses into fusion computations for their dots,
  * models HBM bytes as operand+result buffer traffic of top-level ops
    (one read per operand, one write per result — the fusion boundary is
    where XLA spills to HBM),
  * accumulates per-collective wire bytes with ring-collective factors:
      all-reduce         2*S_in*(g-1)/g    (g = replica-group size)
      all-gather         S_out*(g-1)/g
      reduce-scatter     S_in*(g-1)/g
      all-to-all         S_in*(g-1)/g
      collective-permute S_in

All byte counts are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _parse_shapes(text: str):
    """All (dtype, dims) shapes in a type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, [int(d) for d in dims.split(",") if d], n))
    return out


def _bytes_of(text: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, _, n in _parse_shapes(text))


def _elems_of(text: str) -> int:
    shapes = _parse_shapes(text)
    return shapes[0][2] if shapes else 0


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    dot_flops: float = 0.0

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        self.dot_flops += other.dot_flops
        for k, v in other.collectives.items():
            self.collectives[k] += v
        return self

    def scaled(self, f: float) -> "Cost":
        c = Cost(self.flops * f, self.bytes * f, self.collective_bytes * f,
                 dot_flops=self.dot_flops * f)
        c.collectives = defaultdict(
            float, {k: v * f for k, v in self.collectives.items()}
        )
        return c

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "dot_flops": self.dot_flops,
            "collectives": dict(self.collectives),
        }


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# result type (tuple or array, lazily matched so "while(" is not swallowed)
# followed by the opcode
_OPCODE_RE = re.compile(
    r"^(\(.*?\)|[\w\-]+\[[\d,]*\](?:\{[^}]*\})?(?:\s*:\s*\w+)?)\s+([\w\-]+)\("
)


def _split_computations(hlo: str):
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        if not line.startswith(" "):
            m = _HEADER_RE.match(line.strip())
            if m and "->" in line:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
        elif cur is not None and line.strip():
            comps[cur].append(line.strip())
    return comps, entry


def _operand_names(rhs: str, opcode: str):
    inner = rhs.split(opcode + "(", 1)[1]
    # cut at matching close paren (array operands never contain parens)
    inner = inner.split(")", 1)[0]
    # jax 0.4.x prints typed operands with layout braces
    # ("f32[64,64]{1,0} %name"); strip layouts so their commas don't split
    # the operand list, then pull the %names (works for the bare "%a, %b"
    # style of newer jax too).
    inner = re.sub(r"\{[^}]*\}", "", inner)
    return re.findall(r"%([\w\.\-]+)", inner)


def analyze_hlo(hlo: str, num_partitions: int = 1) -> Cost:
    comps, entry = _split_computations(hlo)

    # symbol tables: per computation, instruction name -> type text
    symtabs: dict[str, dict[str, str]] = {}
    for name, lines in comps.items():
        tab = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            res_name, rhs = m.group(1), m.group(2)
            om = _OPCODE_RE.match(rhs)
            if om:
                tab[res_name] = om.group(1)
        symtabs[name] = tab

    cache: dict[str, Cost] = {}

    def comp_cost(name: str, top_level: bool) -> Cost:
        key = f"{name}@{top_level}"
        if key in cache:
            return cache[key]
        cache[key] = Cost()  # cycle guard
        total = Cost()
        tab = symtabs.get(name, {})
        for line in comps.get(name, []):
            m = _INSTR_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            om = _OPCODE_RE.match(rhs)
            if not om:
                continue
            result_type, opcode = om.group(1), om.group(2)
            c = Cost()
            if opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                trips = 1
                mt = re.search(r'known_trip_count.{0,8}"n":"(\d+)"', line)
                if mt:
                    trips = int(mt.group(1))
                else:
                    mc = re.search(r"condition=%?([\w\.\-]+)", line)
                    if mc:
                        consts = [
                            int(x)
                            for l2 in comps.get(mc.group(1), [])
                            for x in re.findall(r"constant\((\d+)\)", l2)
                        ]
                        trips = max(consts) if consts else 1
                if mb:
                    c += comp_cost(mb.group(1), top_level).scaled(trips)
            elif opcode == "fusion":
                mcall = re.search(r"calls=%?([\w\.\-]+)", line)
                dus_update_bytes = None
                if mcall:
                    inner = comp_cost(mcall.group(1), False)
                    c.flops += inner.flops
                    c.dot_flops += inner.dot_flops
                    c.collective_bytes += inner.collective_bytes
                    dus_update_bytes = _dus_root_update_bytes(
                        comps.get(mcall.group(1), [])
                    )
                if top_level:
                    if dus_update_bytes is not None:
                        # in-place dynamic-update-slice root: XLA aliases the
                        # full buffer; actual HBM traffic is the updated
                        # region (read-modify-write), not the whole operand.
                        c.bytes += 2 * dus_update_bytes
                    else:
                        c.bytes += _bytes_of(result_type) + sum(
                            _bytes_of(tab.get(o, ""))
                            for o in _operand_names(rhs, opcode)
                        )
            elif opcode in ("call", "async-start", "async-done"):
                mcall = re.search(r"(?:to_apply|called_computation)=%?([\w\.\-]+)", line)
                if mcall:
                    c += comp_cost(mcall.group(1), top_level)
            elif opcode == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", line)
                names = []
                if branches:
                    names = [b.strip().lstrip("%") for b in branches[0].split(",")]
                else:
                    names = re.findall(r"(?:true|false)_computation=%?([\w\.\-]+)", line)
                for b in names:
                    c += comp_cost(b, top_level)
            elif opcode == "dot":
                ops = _operand_names(rhs, opcode)
                result_elems = _elems_of(result_type)
                contract = 1
                mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if mc and ops:
                    lhs_shape = _parse_shapes(tab.get(ops[0], ""))
                    if lhs_shape:
                        dims = lhs_shape[0][1]
                        for d in (int(x) for x in mc.group(1).split(",") if x):
                            if d < len(dims):
                                contract *= dims[d]
                c.flops += 2.0 * result_elems * contract
                c.dot_flops += 2.0 * result_elems * contract
                if top_level:
                    c.bytes += _bytes_of(result_type) + sum(
                        _bytes_of(tab.get(o, "")) for o in ops
                    )
            elif opcode == "convolution":
                ops = _operand_names(rhs, opcode)
                result_elems = _elems_of(result_type)
                per_out = 1.0
                if len(ops) >= 2:
                    rhs_shape = _parse_shapes(tab.get(ops[1], ""))
                    mo = re.search(r"dim_labels=[\w?]+_(\w+)->", line)
                    if rhs_shape:
                        dims = rhs_shape[0][1]
                        kelems = 1
                        for d in dims:
                            kelems *= d
                        if mo:
                            # output-feature position marked 'o' in labels
                            labels = mo.group(1)
                            opos = labels.index("o") if "o" in labels else 0
                            per_out = kelems / max(dims[opos], 1)
                        else:
                            per_out = kelems / max(max(dims), 1)
                c.flops += 2.0 * result_elems * per_out
                c.dot_flops += 2.0 * result_elems * per_out
                if top_level:
                    c.bytes += _bytes_of(result_type) + sum(
                        _bytes_of(tab.get(o, "")) for o in ops
                    )
            elif any(opcode.startswith(col) for col in _COLLECTIVES):
                g = _group_size(line, num_partitions)
                ops = _operand_names(rhs, opcode)
                in_b = sum(_bytes_of(tab.get(o, "")) for o in ops) or _bytes_of(result_type)
                out_b = _bytes_of(result_type)
                factor = (g - 1) / g if g > 1 else 0.0
                if opcode.startswith("all-reduce"):
                    wire = 2.0 * in_b * factor
                elif opcode.startswith("all-gather"):
                    wire = out_b * factor
                elif opcode.startswith("reduce-scatter"):
                    wire = in_b * factor
                elif opcode.startswith("all-to-all"):
                    wire = in_b * factor
                else:
                    wire = in_b
                c.collective_bytes += wire
                c.collectives[opcode.split(".")[0].split("-start")[0]] += wire
                if top_level:
                    c.bytes += out_b + in_b
            elif opcode in _NO_TRAFFIC:
                pass
            elif opcode == "dynamic-update-slice":
                ops = _operand_names(rhs, opcode)
                upd = _bytes_of(tab.get(ops[1], "")) if len(ops) > 1 else 0
                if top_level:
                    c.bytes += 2 * upd  # in-place read-modify-write
            elif opcode == "dynamic-slice":
                if top_level:
                    c.bytes += 2 * _bytes_of(result_type)  # slice read + write
            else:
                c.flops += _elems_of(result_type)
                if top_level:
                    c.bytes += _bytes_of(result_type) + sum(
                        _bytes_of(tab.get(o, "")) for o in _operand_names(rhs, opcode)
                    )
            total += c
        cache[key] = total
        return total

    if entry is None:
        entry = list(comps)[-1]
    return comp_cost(entry, True)


def _dus_root_update_bytes(comp_lines: list[str]) -> int | None:
    """If a fusion computation's ROOT is dynamic-update-slice, return the
    update-operand byte size (the true HBM write), else None."""
    tab = {}
    root = None
    for line in comp_lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        om = _OPCODE_RE.match(m.group(2))
        if om:
            tab[m.group(1)] = (om.group(1), om.group(2), m.group(2))
        if line.startswith("ROOT"):
            root = m.group(1)
    if root is None or root not in tab:
        return None
    rtype, ropcode, rrhs = tab[root]
    if ropcode != "dynamic-update-slice":
        return None
    ops = _operand_names(rrhs, ropcode)
    if len(ops) > 1 and ops[1] in tab:
        return _bytes_of(tab[ops[1]][0])
    return None


def _group_size(line: str, num_partitions: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    return num_partitions
