"""Serving driver for both model families.

  * LM archs (``qwen3-4b``, ...): batched prefill + greedy decode loop with
    KV cache.
  * CNN archs (``lenet5``/``alexnet``/``vgg16``): routed through the coded
    serving engine — a ``repro.serving.CodedServer`` owning one resident
    ``CodedPipeline`` on a straggler-simulating ``FcdccCluster``, with
    continuous batching of concurrent requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --batch 4 --prompt-len 32 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --arch lenet5 --requests 16 \
      --workers 8 --stragglers 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_bundle
from repro.launch.mesh import make_host_mesh


def serve_lm(arch: str, *, batch: int, prompt_len: int, gen: int, smoke: bool,
             mesh=None, param_dtype=jnp.float32):
    bundle = get_bundle(arch, smoke=smoke)
    mesh = mesh or make_host_mesh()
    max_len = prompt_len + gen

    with compat.set_mesh(mesh):
        params = bundle.init(jax.random.PRNGKey(0), param_dtype)
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (batch, prompt_len), 0, bundle.cfg.vocab
        )
        cache = bundle.make_cache(batch, max_len, param_dtype)
        decode = jax.jit(bundle.decode_fn, donate_argnums=(1,))

        # prefill by stepping the decoder over the prompt (cache warm-up);
        # attention-free archs carry recurrent state the same way.
        t0 = time.time()
        for t in range(prompt_len):
            logits, cache = decode(
                params, cache, {"tokens": prompts[:, t : t + 1], "pos": jnp.int32(t)}
            )
        prefill_s = time.time() - t0

        out_tokens = []
        if prompt_len > 0:
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        else:  # empty prompt: no logits yet, start from BOS-like token 0
            tok = jnp.zeros((batch, 1), jnp.int32)
        t0 = time.time()
        for t in range(prompt_len, max_len):
            out_tokens.append(tok)
            logits, cache = decode(params, cache, {"tokens": tok, "pos": jnp.int32(t)})
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        decode_s = time.time() - t0

    seq = jnp.concatenate(out_tokens, axis=1)
    tps = batch * gen / decode_s
    print(
        f"{arch}: prefill {prompt_len} toks in {prefill_s:.2f}s; "
        f"generated {gen} x {batch} in {decode_s:.2f}s ({tps:.1f} tok/s)"
    )
    return seq


def serve_cnn(arch: str, *, requests: int, workers: int, stragglers: int,
              straggler_delay: float, smoke: bool, kab=(2, 4),
              mode: str = "threads", seed: int = 0):
    """Fire ``requests`` concurrent single-image requests at a
    ``CodedServer`` and print the latency/throughput stats.

    Default ``mode="threads"``: the printed percentiles are wall-clock, so
    injected straggler delays must really elapse (``simulated`` only shifts
    the subset-selection clock and would make the knobs cosmetic)."""
    from repro.models.cnn import CNN_SPECS, init_cnn, input_hw
    from repro.runtime import StragglerModel
    from repro.serving import CodedServer

    hw0 = input_hw(arch, smoke=smoke)
    rng = np.random.default_rng(seed)
    params = init_cnn(arch, jax.random.PRNGKey(0))
    straggler = StragglerModel.fixed(workers, stragglers, straggler_delay,
                                     seed=seed)
    server = CodedServer.from_cnn(
        arch, params, workers, default_kab=kab, input_hw=hw0,
        straggler=straggler, mode=mode,
    )
    server.warmup()
    c0 = CNN_SPECS[arch][1][0].in_ch
    xs = rng.standard_normal((requests, c0, hw0, hw0)).astype(np.float32)
    with server:
        handles = server.submit_many(xs)
        outs = [h.result(timeout=300.0) for h in handles]
    stats = server.stats()
    print(f"{arch}: coded serving on n={workers} workers "
          f"({stragglers} stragglers +{straggler_delay}s): "
          f"{stats.summary_line()}")
    return outs, stats


def serve(arch: str, *, batch: int, prompt_len: int, gen: int, smoke: bool,
          mesh=None, param_dtype=jnp.float32):
    """Route by family: CNN archs hit the coded serving engine, LM archs
    the decode loop (``batch`` becomes the number of concurrent requests)."""
    from repro.models.cnn import CNN_SPECS

    if arch in CNN_SPECS:
        outs, _ = serve_cnn(arch, requests=batch, workers=8, stragglers=1,
                            straggler_delay=0.1, smoke=smoke)
        return outs
    return serve_lm(arch, batch=batch, prompt_len=prompt_len, gen=gen,
                    smoke=smoke, mesh=mesh, param_dtype=param_dtype)


def main():
    from repro.models.cnn import CNN_SPECS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b",
                    help=f"LM arch or CNN: {sorted(CNN_SPECS)}")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    # CNN serving knobs
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--stragglers", type=int, default=2)
    ap.add_argument("--straggler-delay", type=float, default=0.1)
    ap.add_argument("--mode", default="threads",
                    choices=("threads", "simulated"),
                    help="threads = wall-clock straggler sleeps (CNN only)")
    args = ap.parse_args()
    if args.arch in CNN_SPECS:
        serve_cnn(args.arch, requests=args.requests, workers=args.workers,
                  stragglers=args.stragglers,
                  straggler_delay=args.straggler_delay, smoke=args.smoke,
                  mode=args.mode)
        return
    seq = serve_lm(
        args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, smoke=args.smoke,
    )
    print("sample tokens:", seq[0, :16].tolist())


if __name__ == "__main__":
    main()
