"""Serving driver for both model families.

  * LM archs (``qwen3-4b``, ...): batched prefill + greedy decode loop with
    KV cache.
  * CNN archs (``lenet5``/``alexnet``/``vgg16``): routed through the coded
    serving engine — a ``repro.serving.CodedServer`` with one or several
    resident ``CodedPipeline``s sharing a straggler-simulating
    ``FcdccCluster`` worker pool, continuous batching across the models'
    concurrent requests.  ``--arch`` may repeat to co-serve several CNNs
    from the one pool, and ``--http-port`` raises the JSON front-end
    (``repro.serving.ServingFrontend``) in front of the engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --batch 4 --prompt-len 32 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --arch lenet5 --requests 16 \
      --workers 8 --stragglers 2
  PYTHONPATH=src python -m repro.launch.serve --arch lenet5 --arch alexnet \
      --smoke --http-port 8080
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_bundle
from repro.launch.mesh import make_host_mesh


def serve_lm(arch: str, *, batch: int, prompt_len: int, gen: int, smoke: bool,
             mesh=None, param_dtype=jnp.float32):
    bundle = get_bundle(arch, smoke=smoke)
    mesh = mesh or make_host_mesh()
    max_len = prompt_len + gen

    with compat.set_mesh(mesh):
        params = bundle.init(jax.random.PRNGKey(0), param_dtype)
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (batch, prompt_len), 0, bundle.cfg.vocab
        )
        cache = bundle.make_cache(batch, max_len, param_dtype)
        decode = jax.jit(bundle.decode_fn, donate_argnums=(1,))

        # prefill: one batched jitted pass fills the whole prompt's cache
        # (families without a cache-filling prefill — recurrent state that
        # only advances one token at a time — fall back to stepping the
        # decoder over the prompt).
        t0 = time.time()
        if prompt_len > 0 and bundle.prefill_cache_fn is not None:
            pf = jax.jit(bundle.prefill_cache_fn, donate_argnums=(1,))
            logits, cache = pf(params, cache, {"tokens": prompts})
            jax.block_until_ready(logits)
        else:
            for t in range(prompt_len):
                logits, cache = decode(
                    params, cache,
                    {"tokens": prompts[:, t : t + 1], "pos": jnp.int32(t)},
                )
        prefill_s = time.time() - t0

        out_tokens = []
        if prompt_len > 0:
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        else:  # empty prompt: no logits yet, start from BOS-like token 0
            tok = jnp.zeros((batch, 1), jnp.int32)
        t0 = time.time()
        for t in range(prompt_len, max_len):
            out_tokens.append(tok)
            logits, cache = decode(params, cache, {"tokens": tok, "pos": jnp.int32(t)})
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        decode_s = time.time() - t0

    seq = jnp.concatenate(out_tokens, axis=1)
    tps = batch * gen / decode_s
    print(
        f"{arch}: prefill {prompt_len} toks in {prefill_s:.2f}s; "
        f"generated {gen} x {batch} in {decode_s:.2f}s ({tps:.1f} tok/s)"
    )
    return seq


def _check_cnn_archs(archs) -> None:
    from repro.models.cnn import CNN_SPECS

    unknown = [a for a in archs if a not in CNN_SPECS]
    if unknown:
        raise SystemExit(
            f"unknown CNN arch(s) {unknown}; valid: {sorted(CNN_SPECS)}"
        )
    dupes = sorted({a for a in archs if archs.count(a) > 1})
    if dupes:
        raise SystemExit(f"duplicate --arch value(s) {dupes}; each model "
                         f"registers once on the shared pool")


def build_cnn_server(archs, *, workers: int, stragglers: int,
                     straggler_delay: float, smoke: bool, kab=(2, 4),
                     mode: str = "threads", seed: int = 0,
                     fuse_transitions: bool = False,
                     pool: str | None = None, pipeline_depth: int = 2):
    """One multi-model ``CodedServer``: every arch's pipeline resident on
    the same n-worker pool (its own scheduler/buckets per model).
    ``fuse_transitions`` serves on the partition-resident path (batches
    advance between ConvLs as coded partition shares, no full-activation
    round trip).  ``pool`` selects the worker executor: ``"device"`` pins
    each coded worker to its own ``jax.Device`` (real accelerators, or CPU
    host devices under ``XLA_FLAGS=--xla_force_host_platform_device_
    count=N``), ``"threads"`` keeps the per-worker thread executors, and
    None auto-selects the device pool on multi-device hosts.
    ``pipeline_depth`` is the round-pipelining window: how many dispatched
    worker rounds may be in flight at once (1 = serial dispatch->collect)."""
    from repro.core.pipeline import build_cnn_pipeline
    from repro.models.cnn import init_cnn, input_hw
    from repro.runtime import StragglerModel
    from repro.serving import CodedServer

    _check_cnn_archs(archs)
    straggler = StragglerModel.fixed(workers, stragglers, straggler_delay,
                                     seed=seed)
    server = CodedServer(straggler=straggler, mode=mode,
                         bucket_sizes=(1, 2, 4, 8), pool=pool,
                         pipeline_depth=pipeline_depth)
    for arch in archs:
        params = init_cnn(arch, jax.random.PRNGKey(0))
        server.register_model(arch, build_cnn_pipeline(
            arch, params, workers, default_kab=kab,
            input_hw=input_hw(arch, smoke=smoke),
            fuse_transitions=fuse_transitions,
        ))
    return server


def serve_cnn(archs, *, requests: int, workers: int, stragglers: int,
              straggler_delay: float, smoke: bool, kab=(2, 4),
              mode: str = "threads", seed: int = 0,
              http_port: int | None = None,
              fuse_transitions: bool = False,
              pool: str | None = None, pipeline_depth: int = 2):
    """Serve one or several CNN archs from one shared coded worker pool.

    Without ``--http-port``: fire ``requests`` concurrent single-image
    requests per model and print latency/throughput stats.  With it: raise
    the JSON front-end and serve until interrupted (graceful drain).

    Default ``mode="threads"``: the printed percentiles are wall-clock, so
    injected straggler delays must really elapse (``simulated`` only shifts
    the subset-selection clock and would make the knobs cosmetic)."""
    from repro.models.cnn import CNN_SPECS, input_hw

    archs = [archs] if isinstance(archs, str) else list(archs)
    server = build_cnn_server(
        archs, workers=workers, stragglers=stragglers,
        straggler_delay=straggler_delay, smoke=smoke, kab=kab, mode=mode,
        seed=seed, fuse_transitions=fuse_transitions, pool=pool,
        pipeline_depth=pipeline_depth,
    )
    server.warmup()

    if http_port is not None:
        from repro.serving import ServingFrontend

        frontend = ServingFrontend(server, port=http_port)
        with frontend:
            print(f"serving {archs} on {frontend.url} "
                  f"(POST /v1/infer, GET /v1/models, GET /v1/stats); "
                  f"Ctrl-C drains and exits")
            try:
                frontend._thread.join()
            except KeyboardInterrupt:
                print("\ndraining ...")
        for m, s in server.per_model_stats().items():
            print(f"{m}: {s.summary_line()}")
        return None, server.stats()

    rng = np.random.default_rng(seed)
    handles = []
    with server:
        for arch in archs:
            hw0 = input_hw(arch, smoke=smoke)
            c0 = CNN_SPECS[arch][1][0].in_ch
            xs = rng.standard_normal((requests, c0, hw0, hw0)) \
                .astype(np.float32)
            handles.append(server.submit_many(xs, arch))
        outs = [[h.result(timeout=300.0) for h in hs] for hs in handles]
    for arch in archs:
        stats = server.stats(arch) if len(archs) > 1 else server.stats()
        print(f"{arch}: coded serving on n={workers} shared workers "
              f"({stragglers} stragglers +{straggler_delay}s): "
              f"{stats.summary_line()}")
    agg = server.stats()
    if len(archs) > 1:
        print(f"aggregate: {agg.summary_line()} "
              f"(coalesced merges: {agg.coalesced})")
    return outs, agg


def serve(arch: str, *, batch: int, prompt_len: int, gen: int, smoke: bool,
          mesh=None, param_dtype=jnp.float32, workers: int = 8,
          stragglers: int = 1, straggler_delay: float = 0.1):
    """Route by family: CNN archs hit the coded serving engine (``batch``
    becomes the number of concurrent requests, the cluster shape comes
    from ``workers``/``stragglers``), LM archs the decode loop."""
    from repro.models.cnn import CNN_SPECS

    if arch in CNN_SPECS:
        outs, _ = serve_cnn(arch, requests=batch, workers=workers,
                            stragglers=stragglers,
                            straggler_delay=straggler_delay, smoke=smoke)
        return outs[0]
    return serve_lm(arch, batch=batch, prompt_len=prompt_len, gen=gen,
                    smoke=smoke, mesh=mesh, param_dtype=param_dtype)


def main():
    from repro.configs import ARCH_IDS
    from repro.models.cnn import CNN_SPECS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help=f"LM arch ({ARCH_IDS}) or CNN ({sorted(CNN_SPECS)});"
                         " repeat to co-serve several CNNs on one pool")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    # CNN serving knobs
    ap.add_argument("--requests", type=int, default=16,
                    help="concurrent single-image requests per CNN model")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--stragglers", type=int, default=2)
    ap.add_argument("--straggler-delay", type=float, default=0.1)
    ap.add_argument("--mode", default="threads",
                    choices=("threads", "simulated"),
                    help="threads = wall-clock straggler sleeps (CNN only)")
    ap.add_argument("--pool", default="auto",
                    choices=("auto", "threads", "device"),
                    help="worker executor: device = one jax.Device per "
                         "coded worker (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 for CPU "
                         "host devices); auto picks device on multi-device "
                         "hosts (CNN only)")
    ap.add_argument("--http-port", type=int, default=None,
                    help="serve the JSON front-end on this port (CNN only; "
                         "0 = ephemeral)")
    ap.add_argument("--fuse-transitions", action="store_true",
                    help="partition-resident layer transitions: batches "
                         "advance between ConvLs as coded partition shares "
                         "(CNN only)")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="round-pipelining window: dispatched worker rounds "
                         "in flight at once (1 = serial dispatch->collect; "
                         "CNN only)")
    args = ap.parse_args()
    archs = args.arch or ["qwen3-4b"]
    if all(a in CNN_SPECS for a in archs):
        serve_cnn(archs, requests=args.requests, workers=args.workers,
                  stragglers=args.stragglers,
                  straggler_delay=args.straggler_delay, smoke=args.smoke,
                  mode=args.mode, http_port=args.http_port,
                  fuse_transitions=args.fuse_transitions,
                  pool=None if args.pool == "auto" else args.pool,
                  pipeline_depth=args.pipeline_depth)
        return
    if len(archs) > 1 or args.http_port is not None or args.fuse_transitions:
        raise SystemExit(
            f"multi-model / --http-port / --fuse-transitions serving is "
            f"CNN-only (valid CNN archs: {sorted(CNN_SPECS)}); got {archs}"
        )
    if archs[0] not in ARCH_IDS:
        raise SystemExit(
            f"unknown arch {archs[0]!r}; LM archs: {ARCH_IDS}, "
            f"CNN archs: {sorted(CNN_SPECS)}"
        )
    seq = serve_lm(
        archs[0], batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, smoke=args.smoke,
    )
    print("sample tokens:", seq[0, :16].tolist())


if __name__ == "__main__":
    main()
