"""Batched serving driver: prefill + greedy decode loop with KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_bundle
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh


def serve(arch: str, *, batch: int, prompt_len: int, gen: int, smoke: bool,
          mesh=None, param_dtype=jnp.float32):
    bundle = get_bundle(arch, smoke=smoke)
    mesh = mesh or make_host_mesh()
    max_len = prompt_len + gen

    with compat.set_mesh(mesh):
        params = bundle.init(jax.random.PRNGKey(0), param_dtype)
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (batch, prompt_len), 0, bundle.cfg.vocab
        )
        cache = bundle.make_cache(batch, max_len, param_dtype)
        decode = jax.jit(bundle.decode_fn, donate_argnums=(1,))

        # prefill by stepping the decoder over the prompt (cache warm-up);
        # attention-free archs carry recurrent state the same way.
        t0 = time.time()
        tok = None
        for t in range(prompt_len):
            logits, cache = decode(
                params, cache, {"tokens": prompts[:, t : t + 1], "pos": jnp.int32(t)}
            )
        prefill_s = time.time() - t0

        out_tokens = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        t0 = time.time()
        for t in range(prompt_len, max_len):
            out_tokens.append(tok)
            logits, cache = decode(params, cache, {"tokens": tok, "pos": jnp.int32(t)})
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        decode_s = time.time() - t0

    seq = jnp.concatenate(out_tokens, axis=1)
    tps = batch * gen / decode_s
    print(
        f"{arch}: prefill {prompt_len} toks in {prefill_s:.2f}s; "
        f"generated {gen} x {batch} in {decode_s:.2f}s ({tps:.1f} tok/s)"
    )
    return seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    seq = serve(
        args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, smoke=args.smoke,
    )
    print("sample tokens:", seq[0, :16].tolist())


if __name__ == "__main__":
    main()
