"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the "pod"
axis carries data parallelism across the inter-pod (DCN-ish) links; the
gradient-compression path in the train step targets exactly that axis.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """1-device mesh for tests/examples on this CPU container."""
    return compat.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def make_worker_mesh(n: int, devices=None):
    """1-D ``("workers",)`` mesh for the coded cluster's device pool.

    Uses ``devices`` when given, else every addressable device — capped at
    ``n`` (a 6-worker cluster on an 8-device host leaves 2 devices free for
    the master / other tenants).  Fewer devices than workers is fine: the
    pool round-robins workers over the mesh (``sharding.worker_devices``),
    down to the 1-device degenerate case CI's default host exposes.  On a
    ``--xla_force_host_platform_device_count=8`` host (or a real TPU/GPU
    slice) each worker gets its own compute queue.
    """
    if n < 1:
        raise ValueError(f"need n >= 1 workers, got {n}")
    devs = list(devices) if devices is not None else list(jax.devices())
    devs = devs[:n]
    return compat.make_mesh(
        (len(devs),), ("workers",),
        axis_types=(jax.sharding.AxisType.Auto,), devices=devs,
    )


# TPU v5e-ish hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link (we charge the full collective wire bytes
#               against one link — the bottleneck-link model)
