import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("EXTRA_XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we:
  1. build the bundle + ShapeDtypeStruct inputs (no allocation),
  2. jit the right step (train/prefill/serve) with full shardings,
  3. ``.lower().compile()`` on the production mesh,
  4. record memory_analysis / cost_analysis / parsed-HLO roofline terms
     into results/dryrun/<cell>.json (resumable cache).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat
from repro.configs import ARCH_IDS, get_bundle  # noqa: E402
from repro.configs.shapes import SHAPES, batch_structs  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")

# Cells skipped by design (DESIGN.md §4): long_500k needs sub-quadratic
# attention; pure full-attention archs skip it.
def cell_skip_reason(bundle, shape: str) -> str | None:
    if shape == "long_500k" and not bundle.sub_quadratic:
        return "long_500k skipped: full-attention arch (quadratic); see DESIGN.md"
    return None


def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(arch: str, shape: str, mesh, *, smoke_scale=None, extra=None):
    """Returns (lowered, compiled, meta).  Raises on sharding bugs."""
    kw = {}
    if arch.startswith("deepseek") and shape != "long_500k":
        # align MoE dispatch groups with the data-parallel degree
        dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        b = SHAPES[shape]["global_batch"]
        if smoke_scale:
            b = max(b // smoke_scale, 2)
        kw["dispatch_groups"] = dp if b % dp == 0 else 1
    bundle = get_bundle(arch, **kw) if kw else get_bundle(arch)
    if extra:
        bundle = extra(bundle)
    kind = SHAPES[shape]["kind"]
    batch, cache = batch_structs(bundle, shape, smoke_scale=smoke_scale)
    params = bundle.param_shapes(jnp.bfloat16)

    with compat.set_mesh(mesh):
        if kind == "train":
            from repro.models.common import count_params

            baseline = os.environ.get("REPRO_BASELINE") == "1"
            n_params = count_params(bundle.schema)
            micro = 1 if baseline else (8 if n_params > 1e11 else
                                        4 if n_params > 5e9 else 1)
            # FSDP pays off (and is needed for capacity) only at scale;
            # on <5B models the weight all-gathers regress the roofline
            # (measured on paligemma train_4k: 3.7x flops) -- see §Perf.
            use_fsdp = (not baseline) and n_params > 5e9
            tcfg = steps_mod.TrainConfig(microbatches=micro, fsdp=use_fsdp)
            fn, param_ps, opt_ps = steps_mod.build_train_step(bundle, mesh, tcfg)
            opt_shapes = steps_mod.make_opt_shapes(bundle)
            batch_ps = steps_mod.batch_pspecs(bundle, batch, mesh)
            jitted = jax.jit(
                fn,
                in_shardings=(
                    _named(mesh, param_ps),
                    _named(mesh, opt_ps),
                    _named(mesh, batch_ps),
                ),
                out_shardings=(
                    _named(mesh, param_ps),
                    _named(mesh, opt_ps),
                    None,
                ),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params, opt_shapes, batch)
        elif kind == "prefill":
            fn, param_ps = steps_mod.build_prefill_step(bundle, mesh)
            batch_ps = steps_mod.batch_pspecs(bundle, batch, mesh)
            jitted = jax.jit(
                fn,
                in_shardings=(_named(mesh, param_ps), _named(mesh, batch_ps)),
            )
            lowered = jitted.lower(params, batch)
        else:  # decode
            fn, param_ps = steps_mod.build_serve_step(bundle, mesh)
            batch_ps = steps_mod.batch_pspecs(bundle, batch, mesh)
            cache_ps = steps_mod.cache_pspecs(bundle, cache, mesh)
            jitted = jax.jit(
                fn,
                in_shardings=(
                    _named(mesh, param_ps),
                    _named(mesh, cache_ps),
                    _named(mesh, batch_ps),
                ),
                out_shardings=(None, _named(mesh, cache_ps)),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params, cache, batch)
        compiled = lowered.compile()
    return lowered, compiled, {"bundle": bundle, "kind": kind}


def run_cell(arch: str, shape: str, *, multi_pod: bool, force=False, smoke_scale=None):
    tag = f"{arch}__{shape}__{'2x16x16' if multi_pod else '16x16'}"
    if smoke_scale:
        # Smoke runs get their own cache file: a scaled-down record must
        # never be resumed (or roofline-reported) as a production cell.
        tag += f"__smoke{smoke_scale}"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            cached = json.load(f)
            # Records written before smoke tagging lack the key entirely and
            # may be smoke-poisoned production cells -- recompute those.
            if "smoke_scale" in cached and cached["smoke_scale"] == smoke_scale:
                return cached

    bundle = get_bundle(arch)
    skip = cell_skip_reason(bundle, shape)
    rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod, "tag": tag,
           "smoke_scale": smoke_scale}
    if skip:
        rec.update(status="skipped", reason=skip)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.size
        t0 = time.time()
        try:
            lowered, compiled, meta = lower_cell(
                arch, shape, mesh, smoke_scale=smoke_scale
            )
            mem = compiled.memory_analysis()
            cost = compat.cost_analysis(compiled)
            hlo_cost = analyze_hlo(compiled.as_text(), n_dev)
            rec.update(
                status="ok",
                compile_s=round(time.time() - t0, 1),
                devices=n_dev,
                memory={
                    k: int(getattr(mem, k, 0))
                    for k in (
                        "argument_size_in_bytes",
                        "output_size_in_bytes",
                        "temp_size_in_bytes",
                        "generated_code_size_in_bytes",
                    )
                },
                xla_cost={
                    "flops": float(cost.get("flops", -1)),
                    "bytes_accessed": float(cost.get("bytes accessed", -1)),
                },
                hlo_cost=hlo_cost.as_dict(),
            )
        except Exception as e:  # sharding bug -> fail loudly but record
            rec.update(
                status="error",
                error=f"{type(e).__name__}: {e}",
                trace=traceback.format_exc()[-2000:],
            )
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    print(f"[{status:7s}] {tag} " + (
        f"compile={rec.get('compile_s')}s temp={rec.get('memory',{}).get('temp_size_in_bytes',0)/2**30:.2f}GiB"
        if status == "ok" else rec.get("reason", rec.get("error", ""))[:120]
    ), flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke-scale", type=int, default=None,
                    help="divide batch/seq for quick validation")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(
                    arch, shape, multi_pod=mp, force=args.force,
                    smoke_scale=args.smoke_scale,
                )
                failures += rec["status"] == "error"
    print(f"\ndone; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
