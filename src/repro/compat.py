"""jax version-compat shims.

The substrate targets the modern mesh API (``jax.make_mesh(axis_types=...)``,
``jax.set_mesh``, ``jax.sharding.AxisType``, ``jax.sharding.get_abstract_mesh``)
but must also run on jax 0.4.x, where none of those exist.  This module
provides call-site helpers (``make_mesh`` / ``set_mesh``) and an ``install()``
that grafts the missing attributes onto ``jax`` itself so that *test code and
subprocesses written against the new API* run unchanged on 0.4.x.

Nothing here touches device state: importing jax does not initialise a
backend, so the dry-run's XLA_FLAGS dance keeps working.

``install()`` is idempotent and a no-op on jax versions that already ship
the real APIs; it runs once at ``import repro``.
"""
from __future__ import annotations

import contextlib
import enum

import jax
import jax.sharding


class _AxisTypeFallback(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` on jax 0.4.x.

    Old jax has no sharding-in-types, so every axis behaves as Auto; the enum
    only exists so call sites passing ``axis_types=(AxisType.Auto,) * k``
    type-check and hash.
    """

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


# captured before install() may rebind jax.make_mesh to our wrapper
_ORIG_MAKE_MESH = getattr(jax, "make_mesh", None)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates ``axis_types`` on jax 0.4.x (and
    falls back to a device-grid ``Mesh`` on versions predating make_mesh)."""
    if _ORIG_MAKE_MESH is None:  # < 0.4.35
        import numpy as _np

        devs = devices if devices is not None else jax.devices()
        grid = _np.asarray(devs).reshape(axis_shapes)
        return jax.sharding.Mesh(grid, axis_names)
    try:
        return _ORIG_MAKE_MESH(
            axis_shapes, axis_names, axis_types=axis_types, devices=devices
        )
    except TypeError:  # 0.4.x: no axis_types kwarg
        return _ORIG_MAKE_MESH(axis_shapes, axis_names, devices=devices)


def set_mesh(mesh):
    """Context manager equivalent of ``jax.set_mesh``.

    On new jax this defers to the real thing; on 0.4.x a ``Mesh`` is itself a
    context manager that installs the thread-local resource env, which is
    what ``with_sharding_constraint`` with a bare ``PartitionSpec`` (and our
    ``shard_hint``) consult at trace time.
    """
    real = getattr(jax, "set_mesh", None)
    if real is not None and real is not set_mesh:
        return real(mesh)
    return _mesh_context(mesh)


@contextlib.contextmanager
def _mesh_context(mesh):
    with mesh:
        yield mesh


def get_abstract_mesh():
    """The mesh active in the current context (``.empty`` when none is)."""
    real = getattr(jax.sharding, "get_abstract_mesh", None)
    if real is not None and real is not get_abstract_mesh:
        return real()
    from jax._src import mesh as mesh_lib

    return mesh_lib.thread_resources.env.physical_mesh


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict on both jax lines.

    jax 0.4.x returns a one-element list of per-device dicts; newer jax
    returns the dict directly.
    """
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        return c[0] if c else {}
    return c


def install() -> None:
    """Graft missing new-API attributes onto ``jax`` (0.4.x only)."""
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisTypeFallback
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = set_mesh
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = get_abstract_mesh
    # late-0.4.x make_mesh exists but rejects axis_types (and pre-0.4.35 has
    # no make_mesh at all); replace with the tolerant wrapper so new-API call
    # sites (including test subprocesses) work verbatim.
    import inspect

    try:
        params = (
            inspect.signature(_ORIG_MAKE_MESH).parameters if _ORIG_MAKE_MESH else {}
        )
    except (TypeError, ValueError):  # pragma: no cover - exotic builds
        params = {}
    if "axis_types" not in params:
        jax.make_mesh = make_mesh
