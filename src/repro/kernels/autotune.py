"""Kernel autotuner: per-cell tile/buffer sweeps with a persistent ledger.

The coded hot path runs two Pallas kernel families — the worker's
implicit-GEMM conv (``coded_worker_pallas``) and the transition/decode
GEMMs (``matmul_pallas``) — whose best (block sizes, buffer depth, im2col
strategy) depend on the (geometry, batch-bucket) cell: skinny decode GEMMs
want wide N blocks, small-share conv cells want the two-step im2col, big
shares want the in-kernel one.  This module sweeps a bounded candidate set
per cell, caches the winner in a JSON ledger keyed by
``kind/backend/interpret/shape``, and exposes trace-time lookups that the
ops layer consults when a jitted program is built.

Contract with the bounded-program guarantee: **lookups never sweep**.  A
sweep runs only through the explicit ``tune_*`` entry points (called by
``CodedPipeline.autotune_kernels`` and ``benchmarks/exp10_kernel_roofline``);
a cache miss at trace time just returns None and the kernel uses its
defaults.  Tile sizes are static kernel arguments, so a tuned program is
the same single trace per (geometry, bucket) an untuned one would be.

The ledger lives at ``results/autotune_cache.json`` by default (machine
local, gitignored) — override with ``REPRO_AUTOTUNE_CACHE`` or the
``path`` arguments.
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "cache_path", "clear_cache", "load_cache", "save_cache", "sweep_count",
    "matmul_key", "worker_key", "matmul_params", "worker_params",
    "tune_matmul", "tune_worker",
]

_LOCK = threading.RLock()
# key -> {"params": {...}, "us": float, ...}  # guarded-by: _LOCK
_CACHE: dict | None = None
# how many real sweeps ran (tests assert cache hits skip them)  # guarded-by: _LOCK
_SWEEPS = 0

# Bounded candidate sets: every candidate is a full static-arg tuple, so a
# sweep costs len(candidates) extra jit traces ONCE per cell, never per run.
MATMUL_CANDIDATES: tuple[dict, ...] = (
    {"bm": 128, "bn": 128, "bk": 128, "num_buffers": 1},
    {"bm": 128, "bn": 128, "bk": 128, "num_buffers": 2},
    {"bm": 128, "bn": 128, "bk": 128, "num_buffers": 4},
    {"bm": 128, "bn": 512, "bk": 128, "num_buffers": 2},
    {"bm": 256, "bn": 128, "bk": 256, "num_buffers": 2},
)


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join("results", "autotune_cache.json"),
    )


def _backend_tag(interpret: bool) -> str:
    return f"{jax.default_backend()}/interpret={int(bool(interpret))}"


def matmul_key(m: int, k: int, n: int, *, relu: bool = False,
               interpret: bool = True) -> str:
    return (f"matmul/{_backend_tag(interpret)}/"
            f"m{m}k{k}n{n}/relu={int(bool(relu))}")


def worker_key(xe_shape: tuple, ke_shape: tuple, stride: int, *,
               interpret: bool = True) -> str:
    """Cell key for one worker subtask: coded-share and filter-group shapes
    (the batch dim rides inside ``xe_shape``, so buckets key separately)."""
    xs = "x".join(map(str, xe_shape))
    ks = "x".join(map(str, ke_shape))
    return f"worker/{_backend_tag(interpret)}/xe{xs}/ke{ks}/s{stride}"


# -- ledger ----------------------------------------------------------------
def load_cache(path: str | None = None, *, reload: bool = False) -> dict:
    """The in-memory ledger, loading the JSON file on first touch."""
    global _CACHE
    with _LOCK:
        if _CACHE is None or reload:
            p = path or cache_path()
            try:
                with open(p) as f:
                    _CACHE = json.load(f)
            except (FileNotFoundError, json.JSONDecodeError):
                _CACHE = {}
        return _CACHE


def save_cache(path: str | None = None) -> str:
    p = path or cache_path()
    with _LOCK:
        cache = load_cache(path)
        os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
        tmp = f"{p}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, p)  # atomic: concurrent readers never see a torn file
    return p


def clear_cache(*, memory_only: bool = False, path: str | None = None) -> None:
    """Drop the in-memory ledger (and the JSON file unless ``memory_only``)."""
    global _CACHE, _SWEEPS
    with _LOCK:
        _CACHE = None
        _SWEEPS = 0
        if not memory_only:
            try:
                os.remove(path or cache_path())
            except FileNotFoundError:
                pass


def sweep_count() -> int:
    """Real sweeps run since import/clear — the cache-hit test hook."""
    return _SWEEPS


def _lookup(key: str) -> dict | None:
    entry = load_cache().get(key)
    return dict(entry["params"]) if entry else None


def _record(key: str, params: dict, us: float, swept: list, path=None) -> None:
    global _SWEEPS
    with _LOCK:
        _SWEEPS += 1
        load_cache(path)[key] = {
            "params": params,
            "us": round(us, 2),
            "swept": swept,
        }
        save_cache(path)


# -- trace-time lookups (never sweep) --------------------------------------
def matmul_params(m: int, k: int, n: int, *, relu: bool = False,
                  interpret: bool = True) -> dict | None:
    """Tuned ``matmul_pallas`` kwargs for this GEMM cell, or None."""
    return _lookup(matmul_key(m, k, n, relu=relu, interpret=interpret))


def worker_params(xe_shape: tuple, ke_shape: tuple, stride: int, *,
                  interpret: bool = True) -> dict | None:
    """Tuned ``coded_worker_pallas`` kwargs for this worker cell, or None."""
    return _lookup(worker_key(xe_shape, ke_shape, stride,
                              interpret=interpret))


# -- timing ----------------------------------------------------------------
def _time_best(fn, args, repeat: int) -> float:
    jax.block_until_ready(fn(*args))  # compile outside the timed region
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6


# -- sweeps ----------------------------------------------------------------
def tune_matmul(m: int, k: int, n: int, *, relu: bool = False,
                interpret: bool = True, dtype=jnp.float32,
                candidates=None, repeat: int = 3, force: bool = False,
                path: str | None = None) -> dict:
    """Sweep ``matmul_pallas`` configs for an (m, k, n) cell; cache winner.

    Returns the winning kwargs.  A cached cell returns instantly without
    sweeping unless ``force``.
    """
    key = matmul_key(m, k, n, relu=relu, interpret=interpret)
    if not force:
        hit = _lookup(key)
        if hit is not None:
            return hit
    from repro.kernels.matmul.kernel import matmul_pallas

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    swept = []
    best, best_us = None, float("inf")
    for cand in candidates or MATMUL_CANDIDATES:
        us = _time_best(
            lambda a_, b_, c=dict(cand): matmul_pallas(
                a_, b_, relu=relu, interpret=interpret, **c),
            (a, b), repeat,
        )
        swept.append({"params": dict(cand), "us": round(us, 2)})
        if us < best_us:
            best, best_us = dict(cand), us
    _record(key, best, best_us, swept, path)
    return best


def worker_candidates(xe_shape: tuple, ke_shape: tuple,
                      stride: int) -> list[dict]:
    """Candidate set for a worker cell: the in-kernel-im2col kernel over a
    few output-row tiles, plus the two-step path over buffer depths."""
    from repro.kernels.conv2d.kernel import default_bo

    kh, kw = ke_shape[-2:]
    hh, wp = xe_shape[-2:]
    ho = (hh - kh) // stride + 1
    wo = (wp - kw) // stride + 1
    bos = sorted({default_bo(ho, wo), ho, default_bo(ho, wo, target=64)})
    cands = [{"fused_im2col": True, "bo": bo} for bo in bos if ho % bo == 0]
    cands += [
        {"fused_im2col": False, "num_buffers": 2},
        {"fused_im2col": False, "num_buffers": 4},
    ]
    return cands


def tune_worker(xe_shape: tuple, ke_shape: tuple, stride: int, *,
                interpret: bool = True, dtype=jnp.float32, candidates=None,
                repeat: int = 3, force: bool = False,
                path: str | None = None) -> dict:
    """Sweep the coded-worker kernel for one (shapes, stride) cell.

    ``xe_shape``: one worker's coded input shares ``(ell_a, [B,] C, h_hat,
    Wp)``; ``ke_shape``: its filter groups ``(ell_b, N/k_b, C, KH, KW)``.
    The sweep covers both im2col strategies, so the tuned path is never
    slower than either default.
    """
    key = worker_key(xe_shape, ke_shape, stride, interpret=interpret)
    if not force:
        hit = _lookup(key)
        if hit is not None:
            return hit
    from repro.kernels.conv2d.kernel import coded_worker_pallas

    rng = np.random.default_rng(0)
    xe = jnp.asarray(rng.standard_normal(xe_shape), dtype)
    ke = jnp.asarray(rng.standard_normal(ke_shape), dtype)
    swept = []
    best, best_us = None, float("inf")
    for cand in candidates or worker_candidates(xe_shape, ke_shape, stride):
        fn = jax.jit(
            lambda x, k, c=dict(cand): coded_worker_pallas(
                x, k, stride, interpret=interpret, **c)
        )
        us = _time_best(fn, (xe, ke), repeat)
        swept.append({"params": dict(cand), "us": round(us, 2)})
        if us < best_us:
            best, best_us = dict(cand), us
    _record(key, best, best_us, swept, path)
    return best
