from .ops import coded_worker, conv2d_im2col
from .ref import conv2d_ref
