"""Convolution as implicit GEMM for the TPU MXU.

Hardware adaptation (DESIGN.md §3): the paper's workers run a black-box CPU
convolution; on TPU the native form is im2col (done by XLA's
``conv_general_dilated_patches``, a pure data-movement op) followed by an
MXU-tiled GEMM (the Pallas matmul kernel).  The GEMM dims are
``M = H'*W'`` (output pixels), ``K = C*K_H*K_W`` (patch), ``N = out
channels`` — M and N are 128-padded inside the matmul kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.matmul.kernel import matmul_pallas

__all__ = ["conv2d_im2col_pallas", "coded_worker_pallas",
           "coded_transition_pallas"]


def conv2d_im2col_pallas(
    x: jnp.ndarray,
    k: jnp.ndarray,
    stride: int = 1,
    padding: int = 0,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """``x``: (C, H, W); ``k``: (N, C, KH, KW) -> (N, H', W').

    The degenerate one-share/one-group/one-image case of the fused worker
    kernel — delegating keeps a single owner for the im2col patch-ordering
    and GEMM-layout contract."""
    c, h, w = x.shape
    n, c2, kh, kw = k.shape
    assert c == c2
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding)))
    return coded_worker_pallas(x[None], k[None], stride, interpret=interpret)[0]


def coded_worker_pallas(
    xe: jnp.ndarray,
    ke: jnp.ndarray,
    stride: int = 1,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """One worker's entire fused coded subtask as a single MXU tile sweep.

    The paper's Algorithm 4 runs ``ell_a * ell_b`` pairwise convolutions per
    worker; here they collapse into ONE im2col + ONE Pallas GEMM: the
    ``ell_a`` coded input shares (x the request batch B) ride the GEMM M
    dimension and the ``ell_b`` coded filter groups concatenate into the N
    dimension — one kernel launch per worker per layer instead of
    ``ell_a * ell_b * B`` tiny unbatched GEMMs.

    ``xe``: coded input shares ``(ell_a, [B,] C, h_hat, Wp)`` — already
    conv-padded by APCP, so the patch extraction is VALID.
    ``ke``: coded filter groups ``(ell_b, N/k_b, C, KH, KW)``.
    Returns ``(ell_a*ell_b, [B,] N/k_b, H'/k_a, W')``, slot
    ``ell_b * b1 + b2`` (same layout as the unfused loop).
    """
    batched = xe.ndim == 5
    ea = xe.shape[0]
    b = xe.shape[1] if batched else 1
    c, hh, wp = xe.shape[-3:]
    eb, nb, c2, kh, kw = ke.shape
    assert c == c2, (xe.shape, ke.shape)
    xin = xe.reshape(ea * b, c, hh, wp)
    patches = jax.lax.conv_general_dilated_patches(
        xin,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=((0, 0), (0, 0)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (ea*B, C*KH*KW, H', W') — pure data movement, feeds the MXU GEMM
    _, ck, ho, wo = patches.shape
    # M = ea*B*H'*W' output pixels, K = C*KH*KW patch, N = eb*(N/k_b)
    lhs = patches.transpose(0, 2, 3, 1).reshape(ea * b * ho * wo, ck)
    rhs = ke.reshape(eb * nb, ck).T
    out = matmul_pallas(lhs, rhs, interpret=interpret)  # (M, eb*nb)
    y = out.reshape(ea, b, ho, wo, eb, nb)
    y = jnp.transpose(y, (0, 4, 1, 5, 2, 3)).reshape(ea * eb, b, nb, ho, wo)
    return y if batched else y[:, 0]


def coded_transition_pallas(
    outs: jnp.ndarray,
    d: jnp.ndarray,
    m_next: jnp.ndarray,
    assemble,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """One partition-resident layer transition: decode-GEMM (ReLU fused into
    the tile-sweep epilogue) -> partition-space pool/halo re-slice ->
    encode-GEMM, compiled as a single program.

    The round-trip path runs decode+merge, a separate elementwise
    relu/pool over the assembled ``([B,] N, H', W')`` tensor, then
    ``apcp_partition`` + encode from scratch.  Here the activation never
    leaves partition space: the decode is one MXU tile sweep over
    ``d (Q, Q) @ rows (Q, F)`` with the ReLU applied in-register at the
    flush (``matmul_pallas(relu=True)``), ``assemble`` (the
    geometry-specialized ``partition_transition`` closure passed in from
    ``CodedPipeline`` — pure static slicing/max, traced inline) exchanges
    halo rows and re-slices the pooled partitions, and the re-encode is a
    second tile sweep ``m_next^T (L, k_a') @ parts (k_a', F')``.  The pool
    between the two GEMMs is a nonlinearity, so two sweeps is the minimum —
    but both run inside one jitted program with no merged-tensor round trip.

    ``outs``: fastest-delta worker outputs ``(delta, ell2, *block)``;
    ``d``: the ``(Q, Q)`` decode inverse; ``m_next``: the next layer's
    A-code encode columns ``(k_a', L)``.  Returns the coded next-layer
    input shares ``(L, *part)`` (worker-grouping is the caller's job).
    """
    q = d.shape[0]
    rows = outs.reshape(outs.shape[0] * outs.shape[1], -1)
    decoded = matmul_pallas(
        d.astype(rows.dtype), rows, relu=True, interpret=interpret
    )
    blocks = decoded.reshape((q,) + outs.shape[2:])
    parts = assemble(blocks)  # (k_a', [B,] C, h_hat', W'+2p')
    k2 = parts.shape[0]
    cols = m_next.astype(parts.dtype)  # (k_a', L)
    coded = matmul_pallas(cols.T, parts.reshape(k2, -1), interpret=interpret)
    return coded.reshape((cols.shape[1],) + parts.shape[1:])
