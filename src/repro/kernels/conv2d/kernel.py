"""Convolution as implicit GEMM for the TPU MXU.

Hardware adaptation (DESIGN.md §3): the paper's workers run a black-box CPU
convolution; on TPU the native form is im2col followed by an MXU-tiled GEMM.
The GEMM dims are ``M = H'*W'`` (output pixels), ``K = C*K_H*K_W`` (patch),
``N = out channels``.

Two im2col strategies:

  * **In-kernel im2col** (``fused_im2col=True``, the default) — patch
    extraction is fused into the GEMM tile load: the grid walks (image
    share, output-row tile, N tile), each step pulls one padded input share
    into VMEM via ``BlockSpec`` streaming and gathers its ``C*KH*KW`` patch
    rows *inside* the kernel (static shifted slices over the share — pure
    register traffic), so the ``(ea*B, C*KH*KW, H', W')`` patch tensor —
    the largest intermediate on the worker hot path — never exists in HBM.
    When the whole share is too big for VMEM (uncoded full-frame convs),
    the **K-streamed** variant keeps the share in HBM and double-buffers
    per-K-chunk channel windows in via async copies instead
    (``stream_k``); it accumulates the same fp32 chunks in the same order,
    so it is bit-identical to the resident variant.
  * **Two-step** (``fused_im2col=False``, the fallback for odd geometries)
    — XLA's ``conv_general_dilated_patches`` materializes the patch tensor
    in HBM, then one ``matmul_pallas`` tile sweep consumes it.

All paths accumulate fp32 over the same 128-sized K chunks in the same
order, so their outputs are bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.matmul.kernel import matmul_pallas

__all__ = ["conv2d_im2col_pallas", "coded_worker_pallas",
           "coded_transition_pallas"]

# Guard for the in-kernel im2col path: one input share (C*hh*wp) and one
# patch tile (bo*wo x K) must both fit VMEM comfortably.  Geometries past
# the guard silently take the two-step path (the documented fallback).
_FUSED_VMEM_ELEMS = 1 << 21  # 2M fp32 elements = 8 MB of the ~16 MB VMEM


def conv2d_im2col_pallas(
    x: jnp.ndarray,
    k: jnp.ndarray,
    stride: int = 1,
    padding: int = 0,
    *,
    interpret: bool = True,
    **tile_kw,
) -> jnp.ndarray:
    """``x``: (C, H, W); ``k``: (N, C, KH, KW) -> (N, H', W').

    The degenerate one-share/one-group/one-image case of the fused worker
    kernel — delegating keeps a single owner for the im2col patch-ordering
    and GEMM-layout contract."""
    c, h, w = x.shape
    n, c2, kh, kw = k.shape
    assert c == c2
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding)))
    return coded_worker_pallas(x[None], k[None], stride, interpret=interpret,
                               **tile_kw)[0]


def _worker_im2col_kernel(x_ref, w_ref, o_ref, *, stride: int, kh: int,
                          kw: int, bo: int, wo: int, ck: int, bk: int):
    """One (share, output-row tile, N tile) step of the fused worker GEMM.

    ``x_ref``: (1, C, hh, wp) — the whole padded input share, streamed to
    VMEM by the pallas pipeline.  ``w_ref``: (kp, bn) — one N-tile of the
    reshaped coded filters, K zero-padded to the chunk grid.  The patch
    rows for this tile are gathered here, in-kernel, as ``KH*KW`` shifted
    strided slices of the share — never materialized outside VMEM.
    """
    i = pl.program_id(1)
    x = x_ref[0]  # (C, hh, wp)
    c, _, wp = x.shape
    span = (bo - 1) * stride + kh  # input rows feeding bo output rows
    xwin = jax.lax.dynamic_slice(x, (0, i * bo * stride, 0), (c, span, wp))
    taps = []
    for dh in range(kh):
        for dw in range(kw):
            taps.append(jax.lax.slice(
                xwin, (0, dh, dw),
                (c, dh + (bo - 1) * stride + 1, dw + (wo - 1) * stride + 1),
                (1, stride, stride),
            ))  # (C, bo, wo): tap (dh, dw) of every output pixel in the tile
    # feature order must match kccp-reshaped filters: C slowest, then KH, KW
    patch = jnp.stack(taps, axis=1).reshape(ck, bo * wo).T  # (bo*wo, ck)
    kp, bn = w_ref.shape
    if kp > ck:  # zero-pad K to the chunk grid (exact under fp32 addition)
        patch = jnp.concatenate(
            [patch, jnp.zeros((bo * wo, kp - ck), patch.dtype)], axis=1)
    acc = jnp.zeros((bo * wo, bn), jnp.float32)
    for kk in range(kp // bk):  # same chunk order as matmul_pallas: bit-compat
        acc += jnp.dot(
            patch[:, kk * bk:(kk + 1) * bk],
            w_ref[kk * bk:(kk + 1) * bk, :],
            preferred_element_type=jnp.float32,
        )
    o_ref[...] = acc.astype(o_ref.dtype).reshape(1, bo, wo, bn)


def _k_windows(ck: int, bk: int, kh: int, kw: int, kp: int):
    """Per-K-chunk channel windows ``(c_lo, cw)`` for the streamed path.

    Chunk ``kk`` covers patch columns ``[kk*bk, (kk+1)*bk)``; with the
    (C, KH, KW) feature order those columns touch only channels
    ``kk*bk // (kh*kw)`` .. ``(last real column) // (kh*kw)`` — the slice
    of the share the chunk's DMA must bring in.  ``kp = _pad_to(ck, bk)``
    guarantees every chunk holds at least one real column."""
    wins = []
    for kk in range(kp // bk):
        k0 = kk * bk
        k1 = min(ck, k0 + bk) - 1  # last real (non-padding) column
        c_lo = k0 // (kh * kw)
        c_hi = k1 // (kh * kw)
        wins.append((c_lo, c_hi - c_lo + 1))
    return wins


def _worker_im2col_stream_kernel(x_hbm, w_ref, o_ref, buf, sem, *,
                                 stride: int, kh: int, kw: int, bo: int,
                                 wo: int, ck: int, bk: int, windows):
    """K-streamed variant of ``_worker_im2col_kernel``: the share stays in
    HBM (``x_hbm``: (G, C, hh, wp), ``memory_space=ANY``) and each K chunk
    double-buffers only its channel window ``(cw, span, wp)`` into VMEM via
    async copies — the resident path's whole-share ``(1, C, hh, wp)`` VMEM
    block never exists.  The per-chunk patch gather and the fp32
    accumulation order are identical to the resident kernel, so the two
    variants are bit-identical."""
    gi = pl.program_id(0)
    i = pl.program_id(1)
    span = (bo - 1) * stride + kh
    r0 = i * bo * stride
    kp, bn = w_ref.shape
    n_chunks = kp // bk

    def copy_in(kk):  # chunk kk's channel window -> VMEM slot kk % 2
        c_lo, cw = windows[kk]
        return pltpu.make_async_copy(
            x_hbm.at[gi, pl.ds(c_lo, cw), pl.ds(r0, span), :],
            buf.at[kk % 2, pl.ds(0, cw)],
            sem.at[kk % 2],
        )

    copy_in(0).start()
    if n_chunks > 1:
        copy_in(1).start()
    acc = jnp.zeros((bo * wo, bn), jnp.float32)
    for kk in range(n_chunks):  # static unroll: windows/offsets are static
        c_lo, cw = windows[kk]
        copy_in(kk).wait()
        xw = jax.lax.slice(buf[kk % 2], (0, 0, 0), (cw, span, buf.shape[-1]))
        taps = []
        for dh in range(kh):
            for dw in range(kw):
                taps.append(jax.lax.slice(
                    xw, (0, dh, dw),
                    (cw, dh + (bo - 1) * stride + 1,
                     dw + (wo - 1) * stride + 1),
                    (1, stride, stride),
                ))
        # window rows are a contiguous block of the full (C, KH, KW) feature
        # order starting at c_lo*kh*kw — slice the chunk's bk columns out
        win = jnp.stack(taps, axis=1).reshape(cw * kh * kw, bo * wo).T
        off = kk * bk - c_lo * kh * kw
        real = min(ck, (kk + 1) * bk) - kk * bk
        chunk = jax.lax.slice(win, (0, off), (bo * wo, off + real))
        if real < bk:  # zero-pad like the resident path (exact in fp32)
            chunk = jnp.concatenate(
                [chunk, jnp.zeros((bo * wo, bk - real), chunk.dtype)], axis=1)
        acc += jnp.dot(
            chunk,
            w_ref[kk * bk:(kk + 1) * bk, :],
            preferred_element_type=jnp.float32,
        )
        if kk + 2 < n_chunks:  # prefetch into the slot just consumed
            copy_in(kk + 2).start()
    o_ref[...] = acc.astype(o_ref.dtype).reshape(1, bo, wo, bn)


def _fused_worker_gemm(xin, ke, stride, *, interpret, bo, bn, bk,
                       stream=False):
    """In-kernel-im2col GEMM: xin (G, C, hh, wp) x ke (eb, nb, C, KH, KW)
    -> (G, ho, wo, eb*nb).  ``stream=True`` keeps the share in HBM and
    double-buffers per-K-chunk channel windows (bit-identical output)."""
    g, c, hh, wp = xin.shape
    eb, nb, _, kh, kw = ke.shape
    ho = (hh - kh) // stride + 1
    wo = (wp - kw) // stride + 1
    assert ho % bo == 0, f"bo={bo} must divide H'={ho}"
    ck = c * kh * kw
    n = eb * nb
    bk_ = min(bk, _ceil128(ck))
    kp = _pad_to(ck, bk_)
    bn_ = min(bn, _ceil128(n))
    np_ = _pad_to(n, bn_)
    w = ke.reshape(n, ck).T  # (ck, N), K ordered (C, KH, KW) like the patch
    if (kp, np_) != (ck, n):
        w = jnp.pad(w, ((0, kp - ck), (0, np_ - n)))
    if stream:
        windows = tuple(_k_windows(ck, bk_, kh, kw, kp))
        cw_max = max(cw for _, cw in windows)
        span = (bo - 1) * stride + kh
        out = pl.pallas_call(
            functools.partial(_worker_im2col_stream_kernel, stride=stride,
                              kh=kh, kw=kw, bo=bo, wo=wo, ck=ck, bk=bk_,
                              windows=windows),
            grid=(g, ho // bo, np_ // bn_),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec((kp, bn_), lambda gi, i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((1, bo, wo, bn_),
                                   lambda gi, i, j: (gi, i, 0, j)),
            out_shape=jax.ShapeDtypeStruct((g, ho, wo, np_),
                                           jnp.result_type(xin.dtype,
                                                           ke.dtype)),
            scratch_shapes=[
                pltpu.VMEM((2, cw_max, span, wp), xin.dtype),
                pltpu.SemaphoreType.DMA((2,)),
            ],
            interpret=interpret,
        )(xin, w)
        return out if np_ == n else out[..., :n]
    out = pl.pallas_call(
        functools.partial(_worker_im2col_kernel, stride=stride, kh=kh, kw=kw,
                          bo=bo, wo=wo, ck=ck, bk=bk_),
        grid=(g, ho // bo, np_ // bn_),
        in_specs=[
            pl.BlockSpec((1, c, hh, wp), lambda gi, i, j: (gi, 0, 0, 0)),
            pl.BlockSpec((kp, bn_), lambda gi, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bo, wo, bn_), lambda gi, i, j: (gi, i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((g, ho, wo, np_),
                                       jnp.result_type(xin.dtype, ke.dtype)),
        interpret=interpret,
    )(xin, w)
    return out if np_ == n else out[..., :n]


def _fused_feasible(xin_shape, kh: int, kw: int, stride: int, ho: int,
                    wo: int, bo: int) -> bool:
    """Geometry admits the in-kernel im2col path (else: two-step fallback)."""
    _, c, hh, wp = xin_shape
    if ho < 1 or wo < 1 or bo < 1 or ho % bo != 0:
        return False
    share = c * hh * wp
    patch = bo * wo * _pad_to(c * kh * kw, 128)
    return share <= _FUSED_VMEM_ELEMS and patch <= _FUSED_VMEM_ELEMS


def _stream_feasible(xin_shape, kh: int, kw: int, stride: int, ho: int,
                     wo: int, bo: int, bk: int) -> bool:
    """Geometry admits the K-streamed in-kernel im2col path: the double
    buffer (2 channel windows), the per-chunk patch window, and the whole
    w N-tile must fit VMEM — but the whole share need not."""
    _, c, hh, wp = xin_shape
    if ho < 1 or wo < 1 or bo < 1 or ho % bo != 0:
        return False
    ck = c * kh * kw
    bk_ = min(bk, _ceil128(ck))
    kp = _pad_to(ck, bk_)
    cw_max = max(cw for _, cw in _k_windows(ck, bk_, kh, kw, kp))
    span = (bo - 1) * stride + kh
    buf = 2 * cw_max * span * wp
    win = bo * wo * cw_max * kh * kw
    return (buf <= _FUSED_VMEM_ELEMS and win <= _FUSED_VMEM_ELEMS
            and kp * 128 <= _FUSED_VMEM_ELEMS)


def default_bo(ho: int, wo: int, target: int = 256) -> int:
    """Largest divisor of ``ho`` whose M tile (bo*wo patch rows) stays near
    ``target`` rows — full-height tiles for the small shares coded layers
    produce, split tiles when H' is large."""
    best = 1
    for cand in range(1, ho + 1):
        if ho % cand == 0 and cand * wo <= target:
            best = cand
    return best


def coded_worker_pallas(
    xe: jnp.ndarray,
    ke: jnp.ndarray,
    stride: int = 1,
    *,
    interpret: bool = True,
    fused_im2col: bool | None = None,
    stream_k: bool | None = None,
    bo: int | None = None,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    num_buffers: int = 2,
) -> jnp.ndarray:
    """One worker's entire fused coded subtask as a single MXU tile sweep.

    The paper's Algorithm 4 runs ``ell_a * ell_b`` pairwise convolutions per
    worker; here they collapse into ONE implicit-GEMM sweep: the ``ell_a``
    coded input shares (x the request batch B) ride the GEMM M dimension and
    the ``ell_b`` coded filter groups concatenate into the N dimension — one
    kernel launch per worker per layer instead of ``ell_a * ell_b * B`` tiny
    unbatched GEMMs.

    ``xe``: coded input shares ``(ell_a, [B,] C, h_hat, Wp)`` — already
    conv-padded by APCP, so the patch extraction is VALID.
    ``ke``: coded filter groups ``(ell_b, N/k_b, C, KH, KW)``.
    Returns ``(ell_a*ell_b, [B,] N/k_b, H'/k_a, W')``, slot
    ``ell_b * b1 + b2`` (same layout as the unfused loop).

    ``fused_im2col`` selects the im2col strategy (module docstring); None =
    in-kernel when the geometry admits it.  ``stream_k`` picks the fused
    path's share residency: True forces the K-streamed variant (share in
    HBM, per-chunk channel windows double-buffered to VMEM), False forces
    whole-share-resident, None auto-falls-back to streaming when the share
    is too big for the resident path — so uncoded full-frame convs still
    take the fused path.  Both variants are bit-identical.  ``bo`` is the
    fused path's output-row tile (must divide H'; None = ``default_bo``);
    ``bm/bn/bk/num_buffers`` tile the GEMM (``bm``/``num_buffers`` drive
    the two-step path's ``matmul_pallas``; the fused path streams shares
    at grid level).
    """
    batched = xe.ndim == 5
    ea = xe.shape[0]
    b = xe.shape[1] if batched else 1
    c, hh, wp = xe.shape[-3:]
    eb, nb, c2, kh, kw = ke.shape
    assert c == c2, (xe.shape, ke.shape)
    xin = xe.reshape(ea * b, c, hh, wp)
    ho = (hh - kh) // stride + 1
    wo = (wp - kw) // stride + 1
    bo_ = bo if bo is not None else default_bo(ho, wo)
    stream = bool(stream_k)
    if fused_im2col is None:
        if stream_k is True:
            fused_im2col = True
        elif _fused_feasible(xin.shape, kh, kw, stride, ho, wo, bo_):
            fused_im2col = True
        elif stream_k is None and _stream_feasible(xin.shape, kh, kw, stride,
                                                   ho, wo, bo_, bk):
            fused_im2col = stream = True
        else:
            fused_im2col = False
    if fused_im2col:
        out = _fused_worker_gemm(xin, ke, stride, interpret=interpret,
                                 bo=bo_, bn=bn, bk=bk,
                                 stream=stream)  # (G, ho, wo, eb*nb)
        y = out.reshape(ea, b, ho, wo, eb, nb)
    else:
        patches = jax.lax.conv_general_dilated_patches(
            xin,
            filter_shape=(kh, kw),
            window_strides=(stride, stride),
            padding=((0, 0), (0, 0)),
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )  # (ea*B, C*KH*KW, H', W') — materialized in HBM, then GEMM'd
        _, ck, ho, wo = patches.shape
        # M = ea*B*H'*W' output pixels, K = C*KH*KW patch, N = eb*(N/k_b)
        lhs = patches.transpose(0, 2, 3, 1).reshape(ea * b * ho * wo, ck)
        rhs = ke.reshape(eb * nb, ck).T
        out = matmul_pallas(lhs, rhs, interpret=interpret, bm=bm, bn=bn,
                            bk=bk, num_buffers=num_buffers)  # (M, eb*nb)
        y = out.reshape(ea, b, ho, wo, eb, nb)
    y = jnp.transpose(y, (0, 4, 1, 5, 2, 3)).reshape(ea * eb, b, nb, ho, wo)
    return y if batched else y[:, 0]


def coded_transition_pallas(
    outs: jnp.ndarray,
    d: jnp.ndarray,
    m_next: jnp.ndarray,
    assemble,
    *,
    interpret: bool = True,
    decode_kw: dict | None = None,
    encode_kw: dict | None = None,
) -> jnp.ndarray:
    """One partition-resident layer transition: decode-GEMM (ReLU fused into
    the tile-sweep epilogue) -> partition-space pool/halo re-slice ->
    encode-GEMM, compiled as a single program.

    The round-trip path runs decode+merge, a separate elementwise
    relu/pool over the assembled ``([B,] N, H', W')`` tensor, then
    ``apcp_partition`` + encode from scratch.  Here the activation never
    leaves partition space: the decode is one MXU tile sweep over
    ``d (Q, Q) @ rows (Q, F)`` with the ReLU applied in-register at the
    flush (``matmul_pallas(relu=True)``), ``assemble`` (the
    geometry-specialized ``partition_transition`` closure passed in from
    ``CodedPipeline`` — pure static slicing/max, traced inline) exchanges
    halo rows and re-slices the pooled partitions, and the re-encode is a
    second tile sweep ``m_next^T (L, k_a') @ parts (k_a', F')``.  The pool
    between the two GEMMs is a nonlinearity, so two sweeps is the minimum —
    but both run inside one jitted program with no merged-tensor round trip.

    ``outs``: fastest-delta worker outputs ``(delta, ell2, *block)``;
    ``d``: the ``(Q, Q)`` decode inverse; ``m_next``: the next layer's
    A-code encode columns ``(k_a', L)``.  Returns the coded next-layer
    input shares ``(L, *part)`` (worker-grouping is the caller's job).
    ``decode_kw``/``encode_kw`` pass explicit tile/buffer overrides to the
    two ``matmul_pallas`` sweeps; when omitted, the autotune ledger is
    consulted per GEMM cell at trace time (lookup only — never a sweep).
    """
    from repro.kernels import autotune

    q = d.shape[0]
    rows = outs.reshape(outs.shape[0] * outs.shape[1], -1)
    if decode_kw is None:
        decode_kw = autotune.matmul_params(
            q, q, rows.shape[1], relu=True, interpret=interpret) or {}
    decoded = matmul_pallas(
        d.astype(rows.dtype), rows, relu=True, interpret=interpret,
        **decode_kw
    )
    blocks = decoded.reshape((q,) + outs.shape[2:])
    parts = assemble(blocks)  # (k_a', [B,] C, h_hat', W'+2p')
    k2 = parts.shape[0]
    cols = m_next.astype(parts.dtype)  # (k_a', L)
    flat = parts.reshape(k2, -1)
    if encode_kw is None:
        encode_kw = autotune.matmul_params(
            cols.shape[1], k2, flat.shape[1], interpret=interpret) or {}
    coded = matmul_pallas(cols.T, flat, interpret=interpret, **encode_kw)
    return coded.reshape((cols.shape[1],) + parts.shape[1:])


def _ceil128(x: int) -> int:
    return -(-x // 128) * 128


def _pad_to(x: int, b: int) -> int:
    return -(-x // b) * b
