"""Convolution as implicit GEMM for the TPU MXU.

Hardware adaptation (DESIGN.md §3): the paper's workers run a black-box CPU
convolution; on TPU the native form is im2col (done by XLA's
``conv_general_dilated_patches``, a pure data-movement op) followed by an
MXU-tiled GEMM (the Pallas matmul kernel).  The GEMM dims are
``M = H'*W'`` (output pixels), ``K = C*K_H*K_W`` (patch), ``N = out
channels`` — M and N are 128-padded inside the matmul kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.matmul.kernel import matmul_pallas

__all__ = ["conv2d_im2col_pallas", "coded_worker_pallas"]


def conv2d_im2col_pallas(
    x: jnp.ndarray,
    k: jnp.ndarray,
    stride: int = 1,
    padding: int = 0,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """``x``: (C, H, W); ``k``: (N, C, KH, KW) -> (N, H', W').

    The degenerate one-share/one-group/one-image case of the fused worker
    kernel — delegating keeps a single owner for the im2col patch-ordering
    and GEMM-layout contract."""
    c, h, w = x.shape
    n, c2, kh, kw = k.shape
    assert c == c2
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding)))
    return coded_worker_pallas(x[None], k[None], stride, interpret=interpret)[0]


def coded_worker_pallas(
    xe: jnp.ndarray,
    ke: jnp.ndarray,
    stride: int = 1,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """One worker's entire fused coded subtask as a single MXU tile sweep.

    The paper's Algorithm 4 runs ``ell_a * ell_b`` pairwise convolutions per
    worker; here they collapse into ONE im2col + ONE Pallas GEMM: the
    ``ell_a`` coded input shares (x the request batch B) ride the GEMM M
    dimension and the ``ell_b`` coded filter groups concatenate into the N
    dimension — one kernel launch per worker per layer instead of
    ``ell_a * ell_b * B`` tiny unbatched GEMMs.

    ``xe``: coded input shares ``(ell_a, [B,] C, h_hat, Wp)`` — already
    conv-padded by APCP, so the patch extraction is VALID.
    ``ke``: coded filter groups ``(ell_b, N/k_b, C, KH, KW)``.
    Returns ``(ell_a*ell_b, [B,] N/k_b, H'/k_a, W')``, slot
    ``ell_b * b1 + b2`` (same layout as the unfused loop).
    """
    batched = xe.ndim == 5
    ea = xe.shape[0]
    b = xe.shape[1] if batched else 1
    c, hh, wp = xe.shape[-3:]
    eb, nb, c2, kh, kw = ke.shape
    assert c == c2, (xe.shape, ke.shape)
    xin = xe.reshape(ea * b, c, hh, wp)
    patches = jax.lax.conv_general_dilated_patches(
        xin,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=((0, 0), (0, 0)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (ea*B, C*KH*KW, H', W') — pure data movement, feeds the MXU GEMM
    _, ck, ho, wo = patches.shape
    # M = ea*B*H'*W' output pixels, K = C*KH*KW patch, N = eb*(N/k_b)
    lhs = patches.transpose(0, 2, 3, 1).reshape(ea * b * ho * wo, ck)
    rhs = ke.reshape(eb * nb, ck).T
    out = matmul_pallas(lhs, rhs, interpret=interpret)  # (M, eb*nb)
    y = out.reshape(ea, b, ho, wo, eb, nb)
    y = jnp.transpose(y, (0, 4, 1, 5, 2, 3)).reshape(ea * eb, b, nb, ho, wo)
    return y if batched else y[:, 0]
