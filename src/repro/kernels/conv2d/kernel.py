"""Convolution as implicit GEMM for the TPU MXU.

Hardware adaptation (DESIGN.md §3): the paper's workers run a black-box CPU
convolution; on TPU the native form is im2col (done by XLA's
``conv_general_dilated_patches``, a pure data-movement op) followed by an
MXU-tiled GEMM (the Pallas matmul kernel).  The GEMM dims are
``M = H'*W'`` (output pixels), ``K = C*K_H*K_W`` (patch), ``N = out
channels`` — M and N are 128-padded inside the matmul kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.matmul.kernel import matmul_pallas

__all__ = ["conv2d_im2col_pallas"]


def conv2d_im2col_pallas(
    x: jnp.ndarray,
    k: jnp.ndarray,
    stride: int = 1,
    padding: int = 0,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """``x``: (C, H, W); ``k``: (N, C, KH, KW) -> (N, H', W')."""
    c, h, w = x.shape
    n, c2, kh, kw = k.shape
    assert c == c2
    patches = jax.lax.conv_general_dilated_patches(
        x[None],
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (1, C*KH*KW, H', W')
    _, ck, ho, wo = patches.shape
    lhs = patches[0].reshape(ck, ho * wo).T  # (M, K)
    rhs = k.reshape(n, ck).T  # (K, N)
    out = matmul_pallas(lhs, rhs, interpret=interpret)  # (M, N)
    return out.T.reshape(n, ho, wo)
