"""Public conv ops used by CodedConv2d's ``backend='pallas'`` path.

``interpret`` is a real knob here (plumbed from the class APIs down to
``pl.pallas_call``): ``True`` emulates the kernel on CPU (this container),
``False`` lowers to Mosaic on real TPU hardware.
"""
from .kernel import (
    coded_transition_pallas,
    coded_worker_pallas,
    conv2d_im2col_pallas,
)

__all__ = ["conv2d_im2col", "coded_worker", "coded_transition"]


def conv2d_im2col(x, k, stride=1, padding=0, *, interpret=True):
    return conv2d_im2col_pallas(x, k, stride, padding, interpret=interpret)


def coded_worker(xe, ke, stride=1, *, interpret=True):
    """Fused batched coded-worker subtask: one im2col + one MXU GEMM."""
    return coded_worker_pallas(xe, ke, stride, interpret=interpret)


def coded_transition(outs, d, m_next, assemble, *, interpret=True):
    """Fused partition-resident layer transition: decode-GEMM with ReLU
    epilogue -> partition-space pool/halo re-slice -> encode-GEMM."""
    return coded_transition_pallas(outs, d, m_next, assemble,
                                   interpret=interpret)
