"""Public conv ops used by CodedConv2d's ``backend='pallas'`` path.

``interpret`` is a real knob here (plumbed from the class APIs down to
``pl.pallas_call``): ``True`` emulates the kernel on CPU (this container),
``False`` lowers to Mosaic on real TPU hardware.

When the caller passes no explicit tile/strategy kwargs, the autotune
ledger (``repro.kernels.autotune``) is consulted at trace time — shapes are
concrete under tracing, the lookup never sweeps, and tile sizes are static
kernel args, so a tuned program costs the same single jit trace per
(geometry, bucket) an untuned one does.
"""
from repro.kernels import autotune

from .kernel import (
    coded_transition_pallas,
    coded_worker_pallas,
    conv2d_im2col_pallas,
)

__all__ = ["conv2d_im2col", "coded_worker", "coded_transition"]


def conv2d_im2col(x, k, stride=1, padding=0, *, interpret=True, **tile_kw):
    return conv2d_im2col_pallas(x, k, stride, padding, interpret=interpret,
                                **tile_kw)


def coded_worker(xe, ke, stride=1, *, interpret=True, **tile_kw):
    """Fused batched coded-worker subtask: one implicit-GEMM tile sweep.

    No explicit ``tile_kw`` -> the autotuned winner for this
    (shares, filters, stride) cell, when one is in the ledger.
    """
    if not tile_kw:
        tile_kw = autotune.worker_params(
            tuple(xe.shape), tuple(ke.shape), stride, interpret=interpret
        ) or {}
    return coded_worker_pallas(xe, ke, stride, interpret=interpret, **tile_kw)


def coded_transition(outs, d, m_next, assemble, *, interpret=True, **kw):
    """Fused partition-resident layer transition: decode-GEMM with ReLU
    epilogue -> partition-space pool/halo re-slice -> encode-GEMM.  The two
    GEMM sweeps consult the autotune ledger unless ``decode_kw``/
    ``encode_kw`` are passed."""
    return coded_transition_pallas(outs, d, m_next, assemble,
                                   interpret=interpret, **kw)
