"""Public conv op used by CodedConv2d's ``backend='pallas'`` path."""
from .kernel import conv2d_im2col_pallas

__all__ = ["conv2d_im2col"]


def conv2d_im2col(x, k, stride=1, padding=0, *, interpret=True):
    return conv2d_im2col_pallas(x, k, stride, padding, interpret=interpret)
