"""Pure-jnp direct convolution oracle (eq. 1)."""
import jax.numpy as jnp


def conv2d_ref(x, k, stride=1, padding=0):
    """Naive O(N*H'*W'*C*KH*KW) einsum-based conv: x (C,H,W), k (N,C,KH,KW)."""
    c, h, w = x.shape
    n, _, kh, kw = k.shape
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding)))
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    rows = []
    for i in range(kh):
        cols = []
        for j in range(kw):
            cols.append(
                xp[:, i : i + stride * ho : stride, j : j + stride * wo : stride]
            )
        rows.append(jnp.stack(cols, axis=0))
    patches = jnp.stack(rows, axis=0)  # (KH, KW, C, H', W')
    return jnp.einsum("ijchw,ncij->nhw", patches, k)
