"""Public encode/decode ops built on the coded-GEMM kernel.

Every op consults the persistent autotune ledger (``kernels/autotune``)
for its (m, k, n) cell when the caller passes no explicit tile kwargs —
the same lookup-never-sweeps discipline as the ``matmul`` op, so the
bounded-trace contract holds (a ledger miss just takes the defaults).
"""
import jax.numpy as jnp

from repro.kernels import autotune

from .kernel import coded_gemm_pallas

__all__ = ["crme_encode", "crme_decode", "coded_gemm"]


def _tuned(m: int, k: int, n: int, interpret: bool) -> dict:
    params = autotune.matmul_params(m, k, n, interpret=interpret)
    if not params:
        return {}
    return {k_: v for k_, v in params.items()
            if k_ in ("bm", "bn", "bk", "num_buffers")}


def coded_gemm(code, feats, *, interpret=True, **kw):
    if not kw:
        kw = _tuned(code.shape[0], code.shape[1], feats.shape[1], interpret)
    return coded_gemm_pallas(code, feats, interpret=interpret, **kw)


def crme_encode(parts, matrix, *, interpret=True):
    """``parts`` (k, *block), ``matrix`` (k, ell*n) -> (ell*n, *block)."""
    k = parts.shape[0]
    rows = parts.reshape(k, -1)
    m = jnp.asarray(matrix, dtype=parts.dtype)
    out = coded_gemm(m.T, rows, interpret=interpret)
    return out.reshape((m.shape[1],) + parts.shape[1:])


def crme_decode(decode_matrix, coded, *, interpret=True):
    """``decode_matrix`` (Q, Q) = inv(E^T); ``coded`` (Q, *block)."""
    q = coded.shape[0]
    rows = coded.reshape(q, -1)
    d = jnp.asarray(decode_matrix, dtype=coded.dtype)
    out = coded_gemm(d, rows, interpret=interpret)
    return out.reshape(coded.shape)
