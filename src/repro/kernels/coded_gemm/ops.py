"""Public encode/decode ops built on the coded-GEMM kernel."""
import jax.numpy as jnp

from .kernel import coded_gemm_pallas

__all__ = ["crme_encode", "crme_decode", "coded_gemm"]


def coded_gemm(code, feats, *, interpret=True, **kw):
    return coded_gemm_pallas(code, feats, interpret=interpret, **kw)


def crme_encode(parts, matrix, *, interpret=True):
    """``parts`` (k, *block), ``matrix`` (k, ell*n) -> (ell*n, *block)."""
    k = parts.shape[0]
    rows = parts.reshape(k, -1)
    m = jnp.asarray(matrix, dtype=parts.dtype)
    out = coded_gemm_pallas(m.T, rows, interpret=interpret)
    return out.reshape((m.shape[1],) + parts.shape[1:])


def crme_decode(decode_matrix, coded, *, interpret=True):
    """``decode_matrix`` (Q, Q) = inv(E^T); ``coded`` (Q, *block)."""
    q = coded.shape[0]
    rows = coded.reshape(q, -1)
    d = jnp.asarray(decode_matrix, dtype=coded.dtype)
    out = coded_gemm_pallas(d, rows, interpret=interpret)
    return out.reshape(coded.shape)
