"""CRME encode/decode as a skinny GEMM on the shared matmul lowering.

Both NSCTC phases are ``small code matrix (Q x Q or k x 2n) @ wide feature
matrix (rows x F)`` products.  ``coded_gemm_pallas`` rides the
multi-buffered ``matmul_pallas`` lowering (async-DMA operand streaming,
autotunable tiles) instead of carrying its own single-purpose kernel: the
code matrix always fits one K tile, so the accumulation order — one MXU
dot per feature tile — is identical to the legacy lowering and the outputs
are bit-equal (tests/test_kernels.py proves it).

``coded_gemm_pallas_legacy`` keeps the original feature-axis-only kernel
as the parity reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.matmul.kernel import matmul_pallas

__all__ = ["coded_gemm_pallas", "coded_gemm_pallas_legacy"]


def coded_gemm_pallas(
    code: jnp.ndarray,
    feats: jnp.ndarray,
    *,
    interpret: bool = True,
    bm: int = 128,
    bn: int = 512,
    bk: int = 128,
    num_buffers: int = 2,
) -> jnp.ndarray:
    """``code`` (R_out, R_in) @ ``feats`` (R_in, F) -> (R_out, F).

    R_* are code dimensions (tiny — the whole code matrix fits one
    (bm, bk) tile after padding); F is the flattened tensor-block feature
    axis.  Tile kwargs default to the legacy shape (one row-block, 512-wide
    feature tiles) and are overridable from the autotune ledger.
    """
    return matmul_pallas(
        code, feats, bm=bm, bn=bn, bk=bk,
        interpret=interpret, num_buffers=num_buffers,
    )


def _coded_kernel(m_ref, t_ref, o_ref):
    o_ref[...] = jnp.dot(
        m_ref[...], t_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bf", "interpret"))
def coded_gemm_pallas_legacy(
    code: jnp.ndarray,
    feats: jnp.ndarray,
    *,
    bf: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """The pre-rebase lowering (feature-axis grid only): kept as the
    bit-parity reference for the matmul-backed path."""
    r_out, r_in = code.shape
    r_in2, f = feats.shape
    assert r_in == r_in2

    r_out_p = -(-r_out // 8) * 8
    r_in_p = -(-r_in // 8) * 8
    bf_ = min(bf, -(-f // 128) * 128)
    fp = -(-f // bf_) * bf_
    code = jnp.pad(code, ((0, r_out_p - r_out), (0, r_in_p - r_in)))
    feats = jnp.pad(feats, ((0, r_in_p - r_in), (0, fp - f)))

    out = pl.pallas_call(
        _coded_kernel,
        grid=(fp // bf_,),
        in_specs=[
            pl.BlockSpec((r_out_p, r_in_p), lambda i: (0, 0)),
            pl.BlockSpec((r_in_p, bf_), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((r_out_p, bf_), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r_out_p, fp), feats.dtype),
        interpret=interpret,
    )(code, feats)
    return out[:r_out, :f]
