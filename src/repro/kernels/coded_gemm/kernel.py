"""CRME encode/decode as a skinny GEMM Pallas kernel.

Both NSCTC phases are ``small code matrix (Q x Q or k x 2n) @ wide feature
matrix (rows x F)`` products.  The code matrix fits entirely in VMEM, so the
kernel blocks only over the feature axis: grid = (F/bf,), each program does
one (rows_out x rows_in) @ (rows_in x bf) MXU call and a single HBM write.
This is the fused "tensor-list x matrix" primitive of eq. (18)/(45).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["coded_gemm_pallas"]


def _coded_kernel(m_ref, t_ref, o_ref):
    o_ref[...] = jnp.dot(
        m_ref[...], t_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bf", "interpret"))
def coded_gemm_pallas(
    code: jnp.ndarray,
    feats: jnp.ndarray,
    *,
    bf: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """``code`` (R_out, R_in) @ ``feats`` (R_in, F) -> (R_out, F).

    R_* are code dimensions (tiny, <= 8*128 keeps the whole code matrix in
    one VMEM tile); F is the flattened tensor-block feature axis.
    """
    r_out, r_in = code.shape
    r_in2, f = feats.shape
    assert r_in == r_in2

    r_out_p = -(-r_out // 8) * 8
    r_in_p = -(-r_in // 8) * 8
    bf_ = min(bf, -(-f // 128) * 128)
    fp = -(-f // bf_) * bf_
    code = jnp.pad(code, ((0, r_out_p - r_out), (0, r_in_p - r_in)))
    feats = jnp.pad(feats, ((0, r_in_p - r_in), (0, fp - f)))

    out = pl.pallas_call(
        _coded_kernel,
        grid=(fp // bf_,),
        in_specs=[
            pl.BlockSpec((r_out_p, r_in_p), lambda i: (0, 0)),
            pl.BlockSpec((r_in_p, bf_), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((r_out_p, bf_), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r_out_p, fp), feats.dtype),
        interpret=interpret,
    )(code, feats)
    return out[:r_out, :f]
