from .ops import coded_gemm, crme_decode, crme_encode
from .ref import coded_gemm_ref
