"""Pure-jnp oracle for the coded-GEMM kernel."""
import jax.numpy as jnp


def coded_gemm_ref(code, feats):
    return jnp.dot(
        code, feats, preferred_element_type=jnp.float32
    ).astype(feats.dtype)
