"""Flash attention as a Pallas TPU kernel.

The pure-JAX scan version in ``repro/models/transformer.py`` is what GSPMD
partitions across the mesh; on real TPU hardware this kernel replaces the
inner per-shard computation: grid (batch*heads, q_blocks, kv_blocks) with
the kv axis innermost, online-softmax state (m, l, acc) in VMEM scratch,
one HBM write per output tile.  Blocks are (bq, d)/(bk, d) with d padded
to the 128-lane register width by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int,
                  sk_valid: int):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_idx < sk_valid  # padded key columns contribute nothing
    if causal:
        ok &= k_idx <= q_idx
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "causal", "bq", "bk", "interpret")
)
def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    scale: float | None = None,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """q: (BH, Sq, D); k/v: (BH, Sk, D) -> (BH, Sq, D)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    bq_ = min(bq, sq)
    bk_ = min(bk, sk)
    # pad sequence dims to block multiples; padded keys get masked by the
    # causal test (k_idx > any q_idx) or contribute exp(-inf)=0 via NEG_INF
    sq_p = -(-sq // bq_) * bq_
    sk_p = -(-sk // bk_) * bk_
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0)))
    nq, nk = sq_p // bq_, sk_p // bk_

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, bq=bq_, bk=bk_, nk=nk,
            sk_valid=sk,
        ),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq]
