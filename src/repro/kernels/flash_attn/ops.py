"""Public wrapper: (B, S, H, D) layout -> kernel's (B*H, S, D)."""
import jax.numpy as jnp

from .kernel import flash_attention_pallas

__all__ = ["flash_attention"]


def flash_attention(q, k, v, *, scale=None, causal=True, interpret=True, **kw):
    """q: (B, Sq, H, D); k/v: (B, Sk, H, D) (GQA pre-repeated)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    out = flash_attention_pallas(
        qf, kf, vf, scale=scale, causal=causal, interpret=interpret, **kw
    )
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
