"""Pure-jnp oracle for the flash-attention kernel."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, scale=None, causal=True):
    """q: (BH, Sq, D); k/v: (BH, Sk, D)."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
