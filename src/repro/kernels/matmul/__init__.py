from .ops import matmul
from .ref import matmul_ref
