"""Jit'd public wrapper for the tiled matmul kernel."""
from .kernel import matmul_pallas

__all__ = ["matmul"]


def matmul(a, b, *, interpret=True, **kw):
    return matmul_pallas(a, b, interpret=interpret, **kw)
