"""Jit'd public wrapper for the tiled matmul kernel."""
from repro.kernels import autotune

from .kernel import matmul_pallas

__all__ = ["matmul"]


def matmul(a, b, *, interpret=True, **kw):
    if not kw:  # no explicit tiles: consult the autotune ledger (trace-time)
        kw = autotune.matmul_params(
            a.shape[0], a.shape[1], b.shape[1], interpret=interpret
        ) or {}
    return matmul_pallas(a, b, interpret=interpret, **kw)
