"""MXU-tiled matmul Pallas kernel.

TPU mapping: blocks are multiples of (8, 128) fp32 register tiles; the MXU
consumes 128x128 operands, so default blocks are 128-aligned.  Accumulation
is fp32 in a VMEM scratch across the K grid dimension (innermost), written
back once on the last K step — one HBM write per output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["matmul_pallas"]


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int,
                   relu: bool = False):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        if relu:  # fused epilogue: applied in-register before the HBM write
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype",
                              "relu")
)
def matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
    out_dtype=None,
    relu: bool = False,
) -> jnp.ndarray:
    """``a @ b`` with explicit VMEM tiling.  Shapes padded to block grid.

    ``interpret=True`` runs the kernel body in Python on CPU (this container
    has no TPU); on real hardware pass ``interpret=False``.

    ``relu=True`` fuses ``max(., 0)`` into the flush epilogue — the output
    tile is rectified in-register on the last K step, so a GEMM-then-ReLU
    consumer (the coded transition's decode) costs no extra pass over HBM.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)

    bm_, bn_, bk_ = (min(bm, _ceil8(m)), min(bn, _ceil128(n)), min(bk, _ceil128(k)))
    mp, np_, kp = _pad_to(m, bm_), _pad_to(n, bn_), _pad_to(k, bk_)
    a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    k_steps = kp // bk_

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps, relu=relu),
        grid=(mp // bm_, np_ // bn_, k_steps),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def _ceil8(x: int) -> int:
    return -(-x // 8) * 8


def _ceil128(x: int) -> int:
    return -(-x // 128) * 128


def _pad_to(x: int, b: int) -> int:
    return -(-x // b) * b
