"""MXU-tiled matmul Pallas kernel with multi-buffered operand streaming.

TPU mapping: blocks are multiples of (8, 128) fp32 register tiles; the MXU
consumes 128x128 operands, so default blocks are 128-aligned.  Accumulation
is fp32 in a VMEM scratch across the K steps (innermost), written back once
after the last K step — one HBM write per output tile.

Two lowering strategies share the same math (identical bk-chunked fp32
accumulation order, so their outputs are bit-identical):

  * ``num_buffers == 1`` — the classic 3-D grid sweep: pallas streams one
    (bm, bk) x (bk, bn) operand pair per grid step via ``BlockSpec``.  One
    VMEM buffer per operand; no explicit overlap.
  * ``num_buffers >= 2`` — pipelined operand streaming: the grid covers
    only (M, N) tiles, operands stay in HBM (``memory_space=ANY``), and the
    kernel walks K itself, rotating each operand through ``num_buffers``
    VMEM slots with explicit async DMA — the HBM->VMEM copy of K-step t+1
    (and beyond, up to ``num_buffers - 1`` steps ahead) overlaps the MXU
    compute of step t.  Double buffering is the default; quad buffering is
    the knob for deeper DMA latency hiding on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["matmul_pallas"]


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int,
                   relu: bool = False):
    """Single-buffered body: K is the innermost grid dimension."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        if relu:  # fused epilogue: applied in-register before the HBM write
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


def _matmul_stream_kernel(a_hbm, b_hbm, o_ref, a_buf, b_buf, a_sem, b_sem,
                          acc_ref, *, k_steps: int, bm: int, bn: int, bk: int,
                          num_buffers: int, relu: bool = False):
    """Pipelined body: grid covers (M, N); the kernel streams K itself.

    Each operand rotates through ``num_buffers`` VMEM slots.  The copy for
    K-step ``t + num_buffers`` is issued right after step ``t``'s compute
    releases its slot, so up to ``num_buffers - 1`` DMAs are always in
    flight behind the MXU.
    """
    i, j = pl.program_id(0), pl.program_id(1)

    def a_dma(slot, kk):
        return pltpu.make_async_copy(
            a_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * bk, bk)],
            a_buf.at[slot], a_sem.at[slot],
        )

    def b_dma(slot, kk):
        return pltpu.make_async_copy(
            b_hbm.at[pl.ds(kk * bk, bk), pl.ds(j * bn, bn)],
            b_buf.at[slot], b_sem.at[slot],
        )

    # fill the pipeline: one in-flight copy per buffer slot
    for s in range(min(num_buffers, k_steps)):
        a_dma(s, s).start()
        b_dma(s, s).start()

    acc_ref[...] = jnp.zeros_like(acc_ref)

    def step(kk, _):
        slot = jax.lax.rem(kk, num_buffers)
        a_dma(slot, kk).wait()
        b_dma(slot, kk).wait()
        acc_ref[...] += jnp.dot(
            a_buf[slot], b_buf[slot], preferred_element_type=jnp.float32
        )
        # the compute above released this slot — refill it from k-step
        # kk + num_buffers while the other slots' copies keep the MXU fed
        @pl.when(kk + num_buffers < k_steps)
        def _prefetch():
            a_dma(slot, kk + num_buffers).start()
            b_dma(slot, kk + num_buffers).start()

        return 0

    jax.lax.fori_loop(0, k_steps, step, 0)
    acc = acc_ref[...]
    if relu:  # fused epilogue, identical to the single-buffered flush
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype",
                              "relu", "num_buffers")
)
def matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
    out_dtype=None,
    relu: bool = False,
    num_buffers: int = 2,
) -> jnp.ndarray:
    """``a @ b`` with explicit VMEM tiling.  Shapes padded to block grid.

    ``interpret=True`` runs the kernel body in Python on CPU (this container
    has no TPU); on real hardware pass ``interpret=False``.

    ``relu=True`` fuses ``max(., 0)`` into the flush epilogue — the output
    tile is rectified in-register on the last K step, so a GEMM-then-ReLU
    consumer (the coded transition's decode) costs no extra pass over HBM.

    ``num_buffers`` selects the lowering: 1 = the single-buffered 3-D grid
    sweep, >= 2 = pipelined operand streaming through that many VMEM slots
    per operand (module docstring).  Both accumulate fp32 over the same
    bk-sized K chunks in the same order, so outputs are bit-identical.

    Block-aligned operands skip the pad entirely (and the trailing slice),
    so the aligned fast path costs zero extra HBM copies.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    if num_buffers < 1:
        raise ValueError(f"num_buffers must be >= 1, got {num_buffers}")
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)

    bm_, bn_, bk_ = (min(bm, _ceil8(m)), min(bn, _ceil128(n)), min(bk, _ceil128(k)))
    mp, np_, kp = _pad_to(m, bm_), _pad_to(n, bn_), _pad_to(k, bk_)
    if (mp, kp) != (m, k):  # aligned fast path: no pad, no extra HBM copy
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    k_steps = kp // bk_

    if num_buffers == 1:
        out = pl.pallas_call(
            functools.partial(_matmul_kernel, k_steps=k_steps, relu=relu),
            grid=(mp // bm_, np_ // bn_, k_steps),
            in_specs=[
                pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
            interpret=interpret,
        )(a, b)
    else:
        out = pl.pallas_call(
            functools.partial(
                _matmul_stream_kernel, k_steps=k_steps, bm=bm_, bn=bn_,
                bk=bk_, num_buffers=num_buffers, relu=relu,
            ),
            grid=(mp // bm_, np_ // bn_),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
            scratch_shapes=[
                pltpu.VMEM((num_buffers, bm_, bk_), a.dtype),
                pltpu.VMEM((num_buffers, bk_, bn_), b.dtype),
                pltpu.SemaphoreType.DMA((num_buffers,)),
                pltpu.SemaphoreType.DMA((num_buffers,)),
                pltpu.VMEM((bm_, bn_), jnp.float32),
            ],
            interpret=interpret,
        )(a, b)
    if (mp, np_) == (m, n):
        return out
    return out[:m, :n]


def _ceil8(x: int) -> int:
    return -(-x // 8) * 8


def _ceil128(x: int) -> int:
    return -(-x // 128) * 128


def _pad_to(x: int, b: int) -> int:
    return -(-x // b) * b
