"""Mesh-aware sharding hints.

``shard_hint(x, spec...)`` applies ``with_sharding_constraint`` only when a
mesh is active (jax.set_mesh context), choosing per-dim mesh axes from the
candidates that (a) exist in the current mesh and (b) divide the dim —
so the same model code runs on 1 CPU device, a 16x16 pod, or a 2x16x16
multi-pod mesh without edits (smollm's 9 heads simply fall back to
replication, etc.).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

# canonical logical axes
BATCH = ("pod", "data")  # batch (or sequence for long-context) shards here
MODEL = "model"
WORKERS = "workers"  # the coded cluster's n-worker axis (1-D worker mesh)

__all__ = ["shard_hint", "BATCH", "MODEL", "WORKERS", "resolve_pspec",
           "worker_devices"]


def worker_devices(mesh, n: int) -> list:
    """The n coded workers' device pinning, derived from a worker mesh
    (``launch.mesh.make_worker_mesh``): worker ``i`` runs on device
    ``i % mesh_size``.  With fewer physical devices than workers the
    round-robin oversubscribes evenly (the 1-device degenerate case pins
    everything to that device — functionally the thread pool's layout);
    with ``mesh_size >= n`` every worker owns its device exclusively."""
    devs = list(mesh.devices.flat)
    if not devs:
        raise ValueError("empty mesh")
    return [devs[i % len(devs)] for i in range(n)]


def _resolve_dim(dim: int, cand, mesh_shape) -> tuple[str, ...] | None:
    if cand is None:
        return None
    if isinstance(cand, str):
        cand = (cand,)
    chosen = tuple(a for a in cand if a in mesh_shape)
    if not chosen:
        return None
    total = math.prod(mesh_shape[a] for a in chosen)
    if total and dim % total == 0:
        return chosen
    # try single best axis
    for a in chosen:
        if dim % mesh_shape[a] == 0:
            return (a,)
    return None


def resolve_pspec(shape, axes, mesh_shape) -> P:
    out = []
    used: set[str] = set()
    for dim, cand in zip(shape, axes):
        r = _resolve_dim(dim, cand, mesh_shape)
        if r is None or any(a in used for a in r):
            out.append(None)
        else:
            used.update(r)
            out.append(r if len(r) > 1 else r[0])
    return P(*out)


def shard_hint(x, *axes):
    """Constrain ``x`` (rank == len(axes)) if a mesh is active.

    Each entry of ``axes`` is None, an axis name, or a tuple of candidate
    axis names to use jointly (e.g. ``BATCH`` = ("pod", "data")).
    """
    am = compat.get_abstract_mesh()
    if am.empty:
        return x
    spec = resolve_pspec(x.shape, axes, dict(am.shape))
    return jax.lax.with_sharding_constraint(x, spec)
