"""Jaxpr introspection helpers for the contract analyzer.

Everything here operates on traced jaxprs only — no data is executed.
The helpers recurse through nested closed jaxprs (pjit bodies, scan/cond
branches, custom_jvp calls, ...) because the interesting facts about a
jitted closure — e.g. a constant captured by the jitted function — live
on the *inner* pjit ClosedJaxpr, not the outer trace.
"""

from __future__ import annotations

from typing import Any, Iterator

import jax
import numpy as np
from jax import core as jax_core

ClosedJaxpr = jax_core.ClosedJaxpr
Jaxpr = jax_core.Jaxpr


def _nested_jaxprs(params: dict) -> Iterator[ClosedJaxpr | Jaxpr]:
    for value in params.values():
        if isinstance(value, (ClosedJaxpr, Jaxpr)):
            yield value
        elif isinstance(value, (list, tuple)):
            for item in value:
                if isinstance(item, (ClosedJaxpr, Jaxpr)):
                    yield item


def iter_eqns(jaxpr: ClosedJaxpr | Jaxpr) -> Iterator[Any]:
    """Yield every equation in ``jaxpr`` and all nested jaxprs."""
    inner = jaxpr.jaxpr if isinstance(jaxpr, ClosedJaxpr) else jaxpr
    for eqn in inner.eqns:
        yield eqn
        for sub in _nested_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def collect_consts(jaxpr: ClosedJaxpr | Jaxpr) -> list[tuple[Any, Any]]:
    """All (constvar, const_value) pairs, including nested closed jaxprs.

    A jitted closure's captured arrays appear as consts of the inner pjit
    ClosedJaxpr, so a top-level-only scan would miss them.
    """
    out: list[tuple[Any, Any]] = []
    if isinstance(jaxpr, ClosedJaxpr):
        out.extend(zip(jaxpr.jaxpr.constvars, jaxpr.consts))
        inner = jaxpr.jaxpr
    else:
        inner = jaxpr
    for eqn in inner.eqns:
        for sub in _nested_jaxprs(eqn.params):
            out.extend(collect_consts(sub))
    return out


def iter_avals(jaxpr: ClosedJaxpr | Jaxpr) -> Iterator[Any]:
    """Yield the aval of every var (inputs, outputs, intermediates)."""
    inner = jaxpr.jaxpr if isinstance(jaxpr, ClosedJaxpr) else jaxpr
    for var in list(inner.invars) + list(inner.constvars):
        yield var.aval
    for eqn in inner.eqns:
        for var in eqn.outvars:
            yield var.aval
        for sub in _nested_jaxprs(eqn.params):
            yield from iter_avals(sub)


def primitive_names(jaxpr: ClosedJaxpr | Jaxpr) -> set[str]:
    return {eqn.primitive.name for eqn in iter_eqns(jaxpr)}


def const_arrays(jaxpr: ClosedJaxpr | Jaxpr) -> list[np.ndarray]:
    """Baked constants as concrete arrays (skips non-array consts)."""
    arrays = []
    for _, value in collect_consts(jaxpr):
        if hasattr(value, "shape") and hasattr(value, "dtype"):
            arrays.append(np.asarray(value))
    return arrays


def make_jaxpr_abstract(fn, *arg_shapes) -> ClosedJaxpr:
    """Trace ``fn`` on ShapeDtypeStructs without touching data."""
    return jax.make_jaxpr(fn)(*arg_shapes)
