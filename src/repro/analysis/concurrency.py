"""Concurrency lint: an AST pass over the threaded layers.

Scope (default): ``src/repro/serving``, ``src/repro/runtime``, and
``src/repro/kernels/autotune.py`` — everything that takes locks.

Rules:

- ``CONC-GUARD`` (error): a field annotated ``# guarded-by: <lock>`` is
  mutated outside a ``with <lock>:`` block.  Guards name a real lock
  (``self._lock``, ``self.not_empty``, module-level ``_LOCK``) and are
  *checked*; non-identifier guard values (``engine-thread``,
  ``control-thread``) declare a single-writer discipline and are
  documentation only.  ``__init__``/``__post_init__`` are exempt (no
  concurrent access before construction completes).
- ``CONC-GUARD-UNKNOWN`` (warning): a checked-style guard names a lock
  the lint cannot find — a typo'd annotation must not silently disable
  checking.
- ``CONC-ORDER`` (error): the lock-acquisition-order graph (edges
  ``A -> B`` when B is acquired while A is held, including through
  self-method calls) contains a cycle — a deadlock risk.
- ``CONC-SELF-DEADLOCK`` (error): a non-reentrant ``threading.Lock`` is
  re-acquired while already held (lexically or through a self-method
  call) — guaranteed deadlock on that path.
- ``CONC-WAIT-LOOP`` (warning): ``Condition.wait`` outside a ``while``
  predicate loop — wakeups are spurious and conditions must be re-checked.
  ``Event.wait`` is level-triggered and exempt.
- ``CONC-THREAD-LIFECYCLE`` (warning): a class starts threads / timers /
  executors but has no ``join``/``shutdown``/``cancel`` call anywhere —
  no teardown path means leaked threads under repeated construction.

Suppression: append ``# analysis: allow(RULE-NAME)`` to the flagged line.

The lint is intentionally *intra-module* with limited type inference
(``self.x = ClassName(...)``, annotated parameters, local aliases): it
resolves lock identity to canonical ``ClassName.attr`` / ``module:NAME``
ids and propagates held-lock sets through private (``_``-prefixed)
self-method calls by fixpoint (entry set = intersection over internal
call sites).  Calls it cannot resolve are skipped, never guessed — the
lint prefers missed findings over false positives.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable

from repro.analysis.findings import Report, Severity

GUARD_RE = re.compile(r"#\s*guarded-by:\s*([^#\n]+?)\s*(?:#|$)")
ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\(([A-Z0-9-]+)\)")
IDENT_RE = re.compile(r"^(self\.)?[A-Za-z_][A-Za-z0-9_]*$")

# method names that mutate their receiver in place
MUTATORS = {
    "append", "extend", "insert", "remove", "pop", "popitem", "clear",
    "update", "setdefault", "add", "discard", "appendleft", "popleft",
    "sort", "reverse",
}

# threading factory name -> kind
FACTORY_KINDS = {
    "Lock": "lock",
    "RLock": "rlock",
    "Condition": "condition",
    "Event": "event",
    "Semaphore": "semaphore",
    "BoundedSemaphore": "semaphore",
    "Barrier": "barrier",
}
LOCKY_KINDS = {"lock", "rlock", "condition", "semaphore"}
THREAD_FACTORIES = {"Thread", "Timer", "ThreadPoolExecutor",
                    "ProcessPoolExecutor"}
TEARDOWN_METHODS = {"join", "shutdown", "cancel"}


@dataclasses.dataclass
class GuardSpec:
    raw: str  # annotation text as written
    canonical: str | None  # resolved lock id; None = doc-only
    lineno: int


@dataclasses.dataclass
class ClassInfo:
    name: str
    node: ast.ClassDef
    # field name -> kind ("lock"/"rlock"/"condition"/"event"/...)
    lock_fields: dict = dataclasses.field(default_factory=dict)
    # field name -> class name it holds (limited inference)
    field_types: dict = dataclasses.field(default_factory=dict)
    # field name -> canonical id of the lock it aliases
    aliases: dict = dataclasses.field(default_factory=dict)
    # field name -> GuardSpec
    guards: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ModuleInfo:
    path: str
    modname: str
    lines: list
    tree: ast.AST
    classes: dict = dataclasses.field(default_factory=dict)
    # module-global name -> kind
    global_locks: dict = dataclasses.field(default_factory=dict)
    # module-global name -> GuardSpec
    global_guards: dict = dataclasses.field(default_factory=dict)


def _call_factory(node: ast.AST) -> str | None:
    """``threading.Lock()`` / ``Condition(RLock())`` / bare ``Lock()`` ->
    the factory's base name; None for anything else.  Conditional
    expressions (``X() if cond else param``) resolve through either arm."""
    if isinstance(node, ast.IfExp):
        return _call_factory(node.body) or _call_factory(node.orelse)
    if not isinstance(node, ast.Call):
        return None
    fn = node.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else None
    )
    return name


def _annotation_kind(ann: ast.AST) -> str | None:
    """Field kind from a type annotation (``threading.Lock``,
    ``threading.Lock | None``, ``Condition``)."""
    text = ast.unparse(ann)
    for factory, kind in FACTORY_KINDS.items():
        if re.search(rf"\b{factory}\b", text):
            return kind
    return None


def _guard_comments(lines: list) -> dict:
    """lineno -> guard text, attaching standalone-comment annotations to
    the next non-comment line."""
    out: dict[int, str] = {}
    pending: str | None = None
    for i, line in enumerate(lines, start=1):
        m = GUARD_RE.search(line)
        stripped = line.strip()
        if m:
            if stripped.startswith("#"):
                pending = m.group(1).strip()
                continue
            out[i] = m.group(1).strip()
            pending = None
        elif pending is not None and stripped and not stripped.startswith("#"):
            out[i] = pending
            pending = None
    return out


def _doc_only(guard: str) -> bool:
    return not IDENT_RE.match(guard)


class _ModuleScanner:
    """Pass 1: classes, lock fields, field types, aliases, guards."""

    def __init__(self, path: str, modname: str, source: str):
        self.info = ModuleInfo(
            path=path, modname=modname, lines=source.splitlines(),
            tree=ast.parse(source),
        )

    def scan(self) -> ModuleInfo:
        info = self.info
        guard_lines = _guard_comments(info.lines)
        for node in info.tree.body:
            if isinstance(node, ast.ClassDef):
                info.classes[node.name] = self._scan_class(node, guard_lines)
            elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                self._scan_global(node, guard_lines)
        return info

    def _scan_global(self, node, guard_lines) -> None:
        info = self.info
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        names = [t.id for t in targets if isinstance(t, ast.Name)]
        factory = _call_factory(getattr(node, "value", None))
        kind = FACTORY_KINDS.get(factory) if factory else None
        for name in names:
            if kind:
                info.global_locks[name] = kind
            guard = guard_lines.get(node.lineno)
            if guard:
                info.global_guards[name] = GuardSpec(
                    guard, self._canon_guard(guard, None), node.lineno
                )

    def _scan_class(self, node: ast.ClassDef, guard_lines) -> ClassInfo:
        ci = ClassInfo(node.name, node)
        # dataclass-style annotated fields in the class body
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) and isinstance(
                    stmt.target, ast.Name):
                kind = _annotation_kind(stmt.annotation)
                if kind:
                    ci.lock_fields[stmt.target.id] = kind
                guard = guard_lines.get(stmt.lineno)
                if guard:
                    ci.guards[stmt.target.id] = GuardSpec(
                        guard, None, stmt.lineno)  # canonical filled below
        # __init__-style self.X assignments anywhere in the class
        for fn in [s for s in node.body if isinstance(s, ast.FunctionDef)]:
            params = {
                a.arg: ast.unparse(a.annotation)
                for a in fn.args.args
                if a.annotation is not None
            }
            for stmt in ast.walk(fn):
                if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                    continue
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                for t in targets:
                    if not (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        continue
                    field = t.attr
                    value = getattr(stmt, "value", None)
                    factory = _call_factory(value)
                    if factory in FACTORY_KINDS:
                        ci.lock_fields.setdefault(
                            field, FACTORY_KINDS[factory])
                    elif factory and factory[0].isupper():
                        # self.x = ClassName(...): remember the type
                        ci.field_types.setdefault(field, factory)
                    if isinstance(value, ast.Attribute) and isinstance(
                            value.value, ast.Name) and value.value.id == "self":
                        # self._lock = self.not_empty: alias
                        ci.aliases[field] = value.attr
                    if isinstance(value, ast.Name) and value.id in params:
                        # self.x = param  (annotated): remember the type,
                        # or the lock kind if the annotation is a lock type
                        ann = params[value.id]
                        kind = _annotation_kind(ast.parse(ann, mode="eval").body) \
                            if ann else None
                        if kind:
                            ci.lock_fields.setdefault(field, kind)
                        else:
                            m = re.match(r"[A-Za-z_][A-Za-z0-9_]*", ann)
                            if m and m.group(0)[0].isupper():
                                ci.field_types.setdefault(field, m.group(0))
                    guard = guard_lines.get(stmt.lineno)
                    if guard and field not in ci.guards:
                        ci.guards[field] = GuardSpec(guard, None, stmt.lineno)
        return ci

    def _canon_guard(self, guard: str, cls: ClassInfo | None) -> str | None:
        if _doc_only(guard):
            return None
        if guard.startswith("self."):
            if cls is None:
                return None
            return canonical_attr(cls, guard[len("self."):], self.info)
        return f"{self.info.modname}:{guard}"


def canonical_attr(cls: ClassInfo, attr: str, info: ModuleInfo) -> str:
    """``ClassName.attr`` with same-class aliases resolved."""
    seen = set()
    while attr in cls.aliases and attr not in seen:
        seen.add(attr)
        attr = cls.aliases[attr]
    return f"{cls.name}.{attr}"


def finalize_guards(info: ModuleInfo) -> None:
    scanner_canon = _ModuleScanner.__dict__["_canon_guard"]
    shim = type("_S", (), {"info": info, "_canon_guard": scanner_canon})()
    for ci in info.classes.values():
        for field, spec in ci.guards.items():
            spec.canonical = shim._canon_guard(spec.raw, ci)
    for name, spec in info.global_guards.items():
        spec.canonical = shim._canon_guard(spec.raw, None)


# -- pass 2: per-function facts ---------------------------------------------

@dataclasses.dataclass
class MethodFacts:
    name: str
    cls: str | None
    # (owner_class_or_None, field, frozenset(held), lineno)
    mutations: list = dataclasses.field(default_factory=list)
    # (lock_id, frozenset(held_before), lineno)
    acquires: list = dataclasses.field(default_factory=list)
    # (callee_name, frozenset(held), lineno) — self.method() calls
    self_calls: list = dataclasses.field(default_factory=list)
    # (lock_id_or_None(kind unknown), receiver_kind, in_while, lineno)
    waits: list = dataclasses.field(default_factory=list)
    starts_threads: list = dataclasses.field(default_factory=list)  # linenos
    has_teardown: bool = False


class _FunctionWalker(ast.NodeVisitor):
    def __init__(self, info: ModuleInfo, cls: ClassInfo | None,
                 fn: ast.FunctionDef):
        self.info = info
        self.cls = cls
        self.fn = fn
        self.facts = MethodFacts(fn.name, cls.name if cls else None)
        self.held: frozenset = frozenset()
        self.while_depth = 0
        # local name -> class name (annotated params + simple aliases)
        self.local_types: dict[str, str] = {}
        # local name -> canonical lock id (lock aliases)
        self.local_locks: dict[str, str] = {}
        for a in fn.args.args:
            if a.annotation is not None:
                text = ast.unparse(a.annotation)
                m = re.match(r"[A-Za-z_][A-Za-z0-9_]*", text)
                if m and m.group(0)[0].isupper():
                    self.local_types[a.arg] = m.group(0)

    # -- resolution --------------------------------------------------------
    def _type_of(self, node: ast.AST) -> str | None:
        if isinstance(node, ast.Name):
            return self.local_types.get(node.id)
        if isinstance(node, ast.Attribute) and isinstance(
                node.value, ast.Name) and node.value.id == "self" and self.cls:
            return self.cls.field_types.get(node.attr)
        return None

    def _lock_id(self, node: ast.AST) -> tuple[str | None, str | None]:
        """Canonical lock id and kind for an expression, or (None, None)."""
        if isinstance(node, ast.Name):
            if node.id in self.local_locks:
                lock = self.local_locks[node.id]
                return lock, self._kind_of(lock)
            if node.id in self.info.global_locks:
                lock = f"{self.info.modname}:{node.id}"
                return lock, self.info.global_locks[node.id]
            return None, None
        if isinstance(node, ast.Attribute):
            owner: ClassInfo | None = None
            if isinstance(node.value, ast.Name) and node.value.id == "self":
                owner = self.cls
            else:
                tname = self._type_of(node.value)
                owner = self.info.classes.get(tname) if tname else None
            if owner is not None and (
                    node.attr in owner.lock_fields
                    or node.attr in owner.aliases):
                lock = canonical_attr(owner, node.attr, self.info)
                return lock, self._kind_of(lock)
        return None, None

    def _kind_of(self, lock_id: str) -> str | None:
        if ":" in lock_id:
            return self.info.global_locks.get(lock_id.split(":", 1)[1])
        cls_name, _, attr = lock_id.partition(".")
        ci = self.info.classes.get(cls_name)
        return ci.lock_fields.get(attr) if ci else None

    def _field_owner(self, node: ast.AST) -> tuple[str | None, str | None]:
        """(owner class name, field) of a ``<recv>.field`` expression."""
        if not isinstance(node, ast.Attribute):
            return None, None
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            return (self.cls.name if self.cls else None), node.attr
        tname = self._type_of(node.value)
        if tname and tname in self.info.classes:
            return tname, node.attr
        return None, None

    # -- walk --------------------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        prev = self.held
        acquired = []
        for item in node.items:
            lock, kind = self._lock_id(item.context_expr)
            if lock is not None and (kind in LOCKY_KINDS or kind is None):
                self.facts.acquires.append((lock, self.held, node.lineno))
                acquired.append(lock)
                self.held = self.held | {lock}
        for stmt in node.body:
            self.visit(stmt)
        self.held = prev

    def visit_While(self, node: ast.While) -> None:
        self.while_depth += 1
        self.generic_visit(node)
        self.while_depth -= 1

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested defs run later, possibly on another thread: analyze with an
        # empty held set (their facts merge into this method's under a
        # closure marker so entry-held propagation never applies)
        sub = _FunctionWalker(self.info, self.cls, node)
        sub.local_types.update(self.local_types)
        sub.generic_visit(node)
        f = sub.facts
        self.facts.mutations += f.mutations
        self.facts.acquires += f.acquires
        self.facts.waits += f.waits
        self.facts.starts_threads += f.starts_threads
        self.facts.has_teardown |= f.has_teardown
        # self-calls from closures lose the caller's held set by design

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record_store(t, node.lineno)
        # alias tracking: x = self._lock / sched = self.scheduler
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            lock, _ = self._lock_id(node.value)
            if lock is not None:
                self.local_locks[name] = lock
            tname = self._type_of(node.value)
            if tname is not None:
                self.local_types[name] = tname
            factory = _call_factory(node.value)
            if factory and factory[0].isupper() and \
                    factory in self.info.classes:
                self.local_types[name] = factory
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_store(node.target, node.lineno)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._record_store(node.target, node.lineno)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._record_store(t, node.lineno)
        self.generic_visit(node)

    def _record_store(self, target: ast.AST, lineno: int) -> None:
        # peel subscripts: self.d[k] = v mutates self.d
        while isinstance(target, ast.Subscript):
            target = target.value
        if isinstance(target, ast.Attribute):
            owner, field = self._field_owner(target)
            if owner is not None:
                self.facts.mutations.append(
                    (owner, field, self.held, lineno))
        elif isinstance(target, ast.Name):
            if target.id in self.info.global_guards and \
                    self._declares_global(target.id):
                self.facts.mutations.append(
                    (None, target.id, self.held, lineno))

    def _declares_global(self, name: str) -> bool:
        return any(
            isinstance(s, ast.Global) and name in s.names
            for s in ast.walk(self.fn)
        )

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute):
            recv = fn.value
            # mutator method on a tracked field: self.d.update(...)
            if fn.attr in MUTATORS:
                owner, field = self._field_owner(recv)
                if owner is not None:
                    self.facts.mutations.append(
                        (owner, field, self.held, node.lineno))
                elif isinstance(recv, ast.Subscript):
                    inner = recv.value
                    owner, field = self._field_owner(inner)
                    if owner is not None:
                        self.facts.mutations.append(
                            (owner, field, self.held, node.lineno))
                elif isinstance(recv, ast.Name) and \
                        recv.id in self.info.global_guards:
                    self.facts.mutations.append(
                        (None, recv.id, self.held, node.lineno))
            if fn.attr == "wait":
                lock, kind = self._lock_id(recv)
                if kind == "condition":
                    self.facts.waits.append(
                        (lock, self.while_depth > 0, node.lineno))
            if fn.attr in TEARDOWN_METHODS:
                self.facts.has_teardown = True
            # self.method(...) call for interprocedural propagation
            if isinstance(recv, ast.Name) and recv.id == "self":
                self.facts.self_calls.append(
                    (fn.attr, self.held, node.lineno))
            # module-global dict item mutation: _CACHE[k] = handled in
            # _record_store; _CACHE.update(...) handled above via Name recv
        factory = _call_factory(node)
        if factory in THREAD_FACTORIES:
            self.facts.starts_threads.append(node.lineno)
        self.generic_visit(node)


# -- pass 3: interprocedural fixpoint + rule evaluation ---------------------

def _collect_facts(info: ModuleInfo) -> dict:
    """(class_or_None, method) -> MethodFacts for every function."""
    facts: dict = {}
    for node in info.tree.body:
        if isinstance(node, ast.ClassDef):
            ci = info.classes[node.name]
            for fn in [s for s in node.body
                       if isinstance(s, ast.FunctionDef)]:
                w = _FunctionWalker(info, ci, fn)
                for stmt in fn.body:
                    w.visit(stmt)
                facts[(node.name, fn.name)] = w.facts
        elif isinstance(node, ast.FunctionDef):
            w = _FunctionWalker(info, None, node)
            for stmt in node.body:
                w.visit(stmt)
            facts[(None, node.name)] = w.facts
    return facts


def _entry_held(facts: dict) -> dict:
    """Fixpoint: locks provably held at entry of every private method
    (intersection over all internal call sites; public methods: none)."""
    entry = {key: frozenset() for key in facts}
    for _ in range(len(facts) + 1):
        changed = False
        # gather call-site held sets per callee
        sites: dict = {}
        for (cls, _name), f in facts.items():
            for callee, held, _ln in f.self_calls:
                key = (cls, callee)
                if key in facts:
                    sites.setdefault(key, []).append(
                        held | entry[(cls, f.name)])
        for key, f in facts.items():
            cls, name = key
            if not name.startswith("_") or name.startswith("__"):
                continue  # public or dunder: callable with nothing held
            if key not in sites:
                continue
            new = frozenset.intersection(*map(frozenset, sites[key]))
            if new != entry[key]:
                entry[key] = new
                changed = True
        if not changed:
            break
    return entry


def _suppressed(info: ModuleInfo, lineno: int, rule: str) -> bool:
    if 1 <= lineno <= len(info.lines):
        m = ALLOW_RE.search(info.lines[lineno - 1])
        if m and m.group(1) == rule:
            return True
    return False


def _loc(info: ModuleInfo, lineno: int) -> str:
    return f"{info.path}:{lineno}"


def _known_lock(info: ModuleInfo, canonical: str | None) -> bool:
    if canonical is None:
        return False
    if ":" in canonical:
        return canonical.split(":", 1)[1] in info.global_locks
    cls_name, _, attr = canonical.partition(".")
    ci = info.classes.get(cls_name)
    return ci is not None and attr in ci.lock_fields


def lint_module(info: ModuleInfo, report: Report,
                lock_graph: dict, lock_kinds: dict) -> None:
    finalize_guards(info)
    all_guards = list(info.global_guards.values()) + [
        s for ci in info.classes.values() for s in ci.guards.values()
    ]
    report.stats["guarded_fields_checked"] = report.stats.get(
        "guarded_fields_checked", 0) + sum(
        1 for s in all_guards if _known_lock(info, s.canonical))
    report.stats["guarded_fields_doc_only"] = report.stats.get(
        "guarded_fields_doc_only", 0) + sum(
        1 for s in all_guards if _doc_only(s.raw))
    facts = _collect_facts(info)
    entry = _entry_held(facts)

    # guard lookup tables
    def guard_of(owner: str | None, field: str) -> GuardSpec | None:
        if owner is None:
            return info.global_guards.get(field)
        ci = info.classes.get(owner)
        return ci.guards.get(field) if ci else None

    for key, f in facts.items():
        cls, name = key
        eh = entry.get(key, frozenset())
        exempt = name in ("__init__", "__post_init__", "__new__")
        for owner, field, held, lineno in f.mutations:
            spec = guard_of(owner, field)
            if spec is None or exempt:
                continue
            if not _known_lock(info, spec.canonical):
                continue  # doc-only or unresolvable (reported once below)
            if spec.canonical not in (held | eh):
                if not _suppressed(info, lineno, "CONC-GUARD"):
                    report.add(
                        "CONC-GUARD", Severity.ERROR, _loc(info, lineno),
                        f"{owner + '.' if owner else ''}{field} is "
                        f"guarded-by {spec.raw!r} but mutated in "
                        f"{cls + '.' if cls else ''}{name} without holding "
                        f"it",
                    )
        for lock, held, lineno in f.acquires:
            for h in held | eh:
                lock_graph.setdefault(h, {}).setdefault(
                    lock, _loc(info, lineno))
            kind = None
            if ":" in lock:
                kind = info.global_locks.get(lock.split(":", 1)[1])
            else:
                c, _, a = lock.partition(".")
                ci = info.classes.get(c)
                kind = ci.lock_fields.get(a) if ci else None
            if kind:
                lock_kinds[lock] = kind
            if lock in (held | eh) and lock_kinds.get(lock) == "lock":
                if not _suppressed(info, lineno, "CONC-SELF-DEADLOCK"):
                    report.add(
                        "CONC-SELF-DEADLOCK", Severity.ERROR,
                        _loc(info, lineno),
                        f"non-reentrant lock {lock} re-acquired while "
                        f"already held in "
                        f"{cls + '.' if cls else ''}{name}",
                    )
        for lock, in_while, lineno in f.waits:
            if not in_while and not _suppressed(
                    info, lineno, "CONC-WAIT-LOOP"):
                report.add(
                    "CONC-WAIT-LOOP", Severity.WARNING, _loc(info, lineno),
                    f"Condition.wait on {lock or 'a condition'} outside a "
                    f"while predicate loop; condition wakeups are spurious",
                )

    # interprocedural lock-order edges through private self-calls: caller
    # holding L calls a method that acquires M -> edge L -> M
    acq_closure: dict = {
        key: {lock for lock, _h, _l in f.acquires}
        for key, f in facts.items()
    }
    for _ in range(len(facts) + 1):
        changed = False
        for key, f in facts.items():
            cls, _name = key
            for callee, _held, _ln in f.self_calls:
                ck = (cls, callee)
                if ck in acq_closure and not (
                        acq_closure[ck] <= acq_closure[key]):
                    acq_closure[key] |= acq_closure[ck]
                    changed = True
        if not changed:
            break
    for key, f in facts.items():
        cls, _name = key
        eh = entry.get(key, frozenset())
        for callee, held, lineno in f.self_calls:
            ck = (cls, callee)
            if ck not in acq_closure:
                continue
            for h in held | eh:
                for m in acq_closure[ck]:
                    lock_graph.setdefault(h, {}).setdefault(
                        m, _loc(info, lineno))
                    if h == m and lock_kinds.get(h) == "lock" and \
                            not _suppressed(info, lineno,
                                            "CONC-SELF-DEADLOCK"):
                        report.add(
                            "CONC-SELF-DEADLOCK", Severity.ERROR,
                            _loc(info, lineno),
                            f"non-reentrant lock {h} held across a call to "
                            f"self.{callee}() which re-acquires it",
                        )

    # thread lifecycle per class
    for cls_name, ci in info.classes.items():
        starts = []
        teardown = False
        for (c, _n), f in facts.items():
            if c != cls_name:
                continue
            starts += f.starts_threads
            teardown |= f.has_teardown
        if starts and not teardown:
            lineno = min(starts)
            if not _suppressed(info, lineno, "CONC-THREAD-LIFECYCLE"):
                report.add(
                    "CONC-THREAD-LIFECYCLE", Severity.WARNING,
                    _loc(info, lineno),
                    f"{cls_name} starts threads/executors but has no "
                    f"join/shutdown/cancel teardown path",
                )

    # unresolvable checked-style guards
    for ci in info.classes.values():
        for field, spec in ci.guards.items():
            if not _doc_only(spec.raw) and not _known_lock(
                    info, spec.canonical):
                if not _suppressed(info, spec.lineno, "CONC-GUARD-UNKNOWN"):
                    report.add(
                        "CONC-GUARD-UNKNOWN", Severity.WARNING,
                        _loc(info, spec.lineno),
                        f"guarded-by {spec.raw!r} on {ci.name}.{field} "
                        f"names no lock the lint can resolve",
                    )
    for name, spec in info.global_guards.items():
        if not _doc_only(spec.raw) and not _known_lock(info, spec.canonical):
            if not _suppressed(info, spec.lineno, "CONC-GUARD-UNKNOWN"):
                report.add(
                    "CONC-GUARD-UNKNOWN", Severity.WARNING,
                    _loc(info, spec.lineno),
                    f"guarded-by {spec.raw!r} on module global {name} "
                    f"names no lock the lint can resolve",
                )


def _find_cycles(graph: dict) -> list:
    """Simple cycles in the lock graph (DFS; self-edges excluded — they are
    CONC-SELF-DEADLOCK's job, and reentrant self-edges are legal)."""
    cycles = []
    seen_cycles = set()

    def dfs(node, path, on_path):
        for nxt in graph.get(node, {}):
            if nxt == node:
                continue
            if nxt in on_path:
                cyc = path[path.index(nxt):] + [nxt]
                key = frozenset(cyc)
                if key not in seen_cycles:
                    seen_cycles.add(key)
                    cycles.append(cyc)
                continue
            dfs(nxt, path + [nxt], on_path | {nxt})

    for start in sorted(graph):
        dfs(start, [start], {start})
    return cycles


DEFAULT_SCOPE = (
    "src/repro/serving",
    "src/repro/runtime",
    "src/repro/kernels/autotune.py",
)


def iter_python_files(paths: Iterable[str], root: str = ".") -> list:
    out = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full):
            out.append((full, p))
        else:
            for dirpath, _dirs, files in os.walk(full):
                for f in sorted(files):
                    if f.endswith(".py"):
                        fp = os.path.join(dirpath, f)
                        out.append((fp, os.path.relpath(fp, root)))
    return sorted(out, key=lambda t: t[1])


def run(paths: Iterable[str] | None = None, root: str = ".") -> Report:
    """Lint every file in ``paths`` (default: the threaded layers)."""
    report = Report()
    lock_graph: dict = {}
    lock_kinds: dict = {}
    files = iter_python_files(paths or DEFAULT_SCOPE, root)
    for full, rel in files:
        with open(full, "r", encoding="utf-8") as fh:
            source = fh.read()
        modname = os.path.splitext(os.path.basename(rel))[0]
        info = _ModuleScanner(rel, modname, source).scan()
        lint_module(info, report, lock_graph, lock_kinds)
    for cyc in _find_cycles(lock_graph):
        edges = " -> ".join(cyc)
        locs = [lock_graph[a].get(b, "?")
                for a, b in zip(cyc, cyc[1:])]
        report.add(
            "CONC-ORDER", Severity.ERROR, locs[0] if locs else "?",
            f"lock-acquisition-order cycle: {edges} "
            f"(edges at {', '.join(locs)})",
        )
    report.stats["concurrency_files"] = len(files)
    report.stats["lock_graph_edges"] = sum(
        len(v) for v in lock_graph.values())
    report.stats["locks_discovered"] = len(lock_kinds)
    return report
