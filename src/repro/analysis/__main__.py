"""CLI entry point: ``python -m repro.analysis [--strict] [--format json]``.

Runs both analyzer families and exits non-zero when the report fails:

- errors always fail;
- warnings fail only under ``--strict`` (the CI gate runs strict);
- info findings never fail and are hidden from text output unless
  ``--show-info`` is given (they are always present in JSON).
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis.findings import Report


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jit-contract and concurrency static analysis for the "
        "coded serving stack",
    )
    parser.add_argument(
        "--strict", action="store_true",
        help="fail on warnings as well as errors (CI gate mode)",
    )
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="report format on stdout",
    )
    parser.add_argument(
        "--json-out", metavar="PATH", default=None,
        help="also write the JSON report to PATH",
    )
    parser.add_argument(
        "--only", choices=("contracts", "concurrency"), default=None,
        help="run a single analyzer family",
    )
    parser.add_argument(
        "--arch", action="append", default=None,
        help="restrict contract analysis to these CNN archs (repeatable)",
    )
    parser.add_argument(
        "--backend", action="append", default=None,
        choices=("lax", "pallas"),
        help="restrict contract analysis to these backends (repeatable)",
    )
    parser.add_argument(
        "--show-info", action="store_true",
        help="include info-severity findings in text output",
    )
    args = parser.parse_args(argv)

    report = Report()
    if args.only in (None, "concurrency"):
        from repro.analysis import concurrency

        report.extend(concurrency.run())
    if args.only in (None, "contracts"):
        from repro.analysis import contracts

        report.extend(
            contracts.run(
                archs=args.arch,
                backends=tuple(args.backend) if args.backend else ("lax", "pallas"),
            )
        )

    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as fh:
            fh.write(report.to_json())
            fh.write("\n")
    if args.format == "json":
        print(report.to_json())
    else:
        print(report.render_text(show_info=args.show_info))
    return 1 if report.failed(strict=args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
