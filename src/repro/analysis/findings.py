"""Finding/report model shared by all analyzers.

A finding is one rule violation at one location. Severities:

- ``error``: a contract violation that would break correctness (baked
  decode constant, guarded field mutated outside its lock, lock cycle).
- ``warning``: a likely bug or missing hygiene (wait without predicate
  loop, thread without join path). ``--strict`` fails on these too.
- ``info``: advisory context (e.g. donation present but no aliasing
  possible on this platform). Never fails a run.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field


class Severity:
    ERROR = "error"
    WARNING = "warning"
    INFO = "info"

    _ORDER = {"error": 0, "warning": 1, "info": 2}

    @classmethod
    def rank(cls, sev: str) -> int:
        return cls._ORDER.get(sev, 99)


@dataclass(frozen=True)
class Finding:
    rule: str  # e.g. "JIT-BAKED-CONST", "CONC-GUARD"
    severity: str  # Severity.*
    location: str  # "path/to/file.py:123" or a program-cell id
    message: str

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "location": self.location,
            "message": self.message,
        }

    def render(self) -> str:
        return f"[{self.severity}] {self.rule} {self.location}: {self.message}"


@dataclass
class Report:
    findings: list[Finding] = field(default_factory=list)
    # Free-form analyzer stats (program counts, trace bounds, files linted)
    # carried into the JSON output for tooling.
    stats: dict = field(default_factory=dict)

    def add(self, rule: str, severity: str, location: str, message: str) -> None:
        self.findings.append(Finding(rule, severity, location, message))

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)
        self.stats.update(other.stats)

    def sorted_findings(self) -> list[Finding]:
        return sorted(
            self.findings,
            key=lambda f: (Severity.rank(f.severity), f.location, f.rule),
        )

    def count(self, severity: str) -> int:
        return sum(1 for f in self.findings if f.severity == severity)

    def failed(self, strict: bool = True) -> bool:
        """Whether this report should fail the run.

        Errors always fail; warnings fail only in strict mode; info never
        fails.
        """
        if self.count(Severity.ERROR):
            return True
        return strict and self.count(Severity.WARNING) > 0

    def render_text(self, show_info: bool = False) -> str:
        lines = [
            f.render()
            for f in self.sorted_findings()
            if show_info or f.severity != Severity.INFO
        ]
        lines.append(
            "analysis: %d error(s), %d warning(s), %d info"
            % (
                self.count(Severity.ERROR),
                self.count(Severity.WARNING),
                self.count(Severity.INFO),
            )
        )
        for key in sorted(self.stats):
            lines.append(f"  {key}: {self.stats[key]}")
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps(
            {
                "findings": [f.to_dict() for f in self.sorted_findings()],
                "stats": self.stats,
                "counts": {
                    "error": self.count(Severity.ERROR),
                    "warning": self.count(Severity.WARNING),
                    "info": self.count(Severity.INFO),
                },
            },
            indent=2,
            default=str,
        )
