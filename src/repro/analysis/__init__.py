"""Static analysis for the coded serving stack.

Two analyzer families, both runnable without executing any pipeline data:

- ``contracts``: enumerates the shape space of every program the pipeline
  family can build (CNN archs x buckets x backends x transition fusing) and
  checks jit contracts on the traced jaxprs / lowered HLO — no baked
  decode/encode constants, donation wired through, no f64 / weak types /
  host callbacks, and a static proof of the bounded-trace contract.
- ``concurrency``: an AST lint over the threaded layers (``serving/``,
  ``runtime/``, ``kernels/autotune.py``) — ``# guarded-by:`` enforcement,
  lock-acquisition-order cycles, ``Condition.wait`` predicate loops, and
  thread/executor lifecycle.

CLI: ``python -m repro.analysis --strict`` (see ``__main__``).
"""

from repro.analysis.findings import Finding, Report, Severity

__all__ = ["Finding", "Report", "Severity"]
