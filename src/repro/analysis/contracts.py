"""Program-contract analyzer: jit contracts proven on the shape space.

For every program the pipeline family can build — CNN archs x batch
buckets x {lax, pallas} backends x {fused, unfused} transitions, in both
the single-process (``direct``) and threaded-runtime (``cluster``)
execution modes, plus the coded LM decoder's decode-step program space
({coded, uncoded} plans x backends, worker GEMM rounds and master-side
glue alike) — this module traces the program on ``ShapeDtypeStruct``
arguments (``CodedPipeline.program_space`` /
``CodedDecoderPipeline.program_space``; no data runs) and checks:

- ``JIT-BAKED-CONST`` (error): decode-inverse / encode-column matrices
  must enter traced programs as *runtime arguments*, never baked
  constants — a baked survivor-subset matrix would mean a fresh trace per
  subset, breaking the no-retrace contract.  Any floating-point constant
  of >= ``CONST_SIZE_LIMIT`` elements is flagged unless the cell
  explicitly allows its shape (the cluster encoder legitimately bakes the
  full-n A-code matrix: it is subset-independent).
- ``JIT-F64`` (error): no float64/complex128 aval anywhere in a traced
  program — the stack is float32-resident; silent x64 promotion doubles
  memory and halves throughput.
- ``JIT-WEAK-TYPE`` (warning): program outputs must not be weakly typed —
  a weak output means a Python-scalar promotion leaked through and the
  next program's trace signature becomes input-history-dependent.
- ``JIT-HOST-CALLBACK`` (error): no ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` primitives inside jitted programs — host round trips
  serialize the async dispatch chain (``device_get``-style syncs cannot
  even be expressed in a jaxpr; the callback primitives are the residue
  this rule can see).
- ``JIT-DONATION`` (error/info): transition programs built with donation
  must actually mark argument 0 donated in the lowered module
  (``args_info``); when an output aval matches the donated input, the
  compiled HLO must carry the ``tf.aliasing_output`` attribute (when no
  output matches, aliasing is impossible and an info note records it).
- ``TRACE-BOUND`` (error): a static proof of the bounded-trace contract —
  for each execution mode, the number of *distinct trace signatures* the
  full shape space induces must not exceed
  ``(num_geometries + num_transitions) x len(buckets)``
  (``CodedPipeline.program_trace_bound``).  Together with
  ``JIT-BAKED-CONST`` (subsets enter as runtime args, so they cannot
  create signatures) this bounds compilations for the pipeline's
  lifetime.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Iterable, Sequence

import numpy as np

from repro.analysis import jaxpr_tools
from repro.analysis.findings import Report, Severity

# Floating constants smaller than this are tolerated everywhere (eps
# scalars, small index-free masks); coding matrices are always bigger.
CONST_SIZE_LIMIT = 16

# Host-callback primitive names across jax versions.
HOST_CALLBACK_PRIMITIVES = {
    "pure_callback",
    "io_callback",
    "debug_callback",
    "outside_call",
    "host_callback_call",
}

F64_DTYPES = {"float64", "complex128"}


@dataclasses.dataclass(frozen=True)
class ContractConfig:
    """One pipeline family member to analyze."""

    arch: str
    backend: str  # "lax" | "pallas"
    fused: bool
    n: int = 4
    kab: tuple = (2, 2)
    buckets: tuple = (1, 2)

    @property
    def label(self) -> str:
        fused = "fused" if self.fused else "unfused"
        return f"{self.arch}/{self.backend}/{fused}"


def iter_configs(
    archs: Sequence[str] | None = None,
    backends: Sequence[str] = ("lax", "pallas"),
) -> list[ContractConfig]:
    """The default analysis matrix: every arch x backend x transition mode."""
    if archs is None:
        from repro.models.cnn import CNN_SPECS

        archs = sorted(CNN_SPECS)
    return [
        ContractConfig(arch, backend, fused)
        for arch in archs
        for backend in backends
        for fused in (False, True)
    ]


def build_pipeline(cfg: ContractConfig):
    """Construct the config's pipeline with zero weights (shapes are all
    that matter; filter encoding of zeros is cheap) at smoke resolution.

    Donation is forced on so the donation contract is checked even on CPU
    hosts where the pipeline's own default keeps it off.
    """
    from repro.core.pipeline import build_cnn_pipeline
    from repro.models.cnn import CNN_SPECS, input_hw

    _, layers = CNN_SPECS[cfg.arch]
    params = {
        l.name: np.zeros((l.out_ch, l.in_ch, l.kernel, l.kernel), np.float32)
        for l in layers
    }
    return build_cnn_pipeline(
        cfg.arch,
        params,
        n=cfg.n,
        default_kab=cfg.kab,
        input_hw=input_hw(cfg.arch, smoke=True),
        backend=cfg.backend,
        interpret=True,
        bucket_sizes=cfg.buckets,
        fuse_transitions=cfg.fused,
        donate_transitions=True,
    )


# -- per-cell checks (unit-testable on any cell-shaped object) --------------

def check_jaxpr_contracts(cell, jaxpr=None) -> list:
    """JIT-BAKED-CONST / JIT-F64 / JIT-WEAK-TYPE / JIT-HOST-CALLBACK on one
    traced cell.  ``cell`` needs ``fn``, ``args``, ``cell_id`` and
    ``allowed_const_shapes``; ``jaxpr`` may be pre-traced."""
    import jax

    report = Report()
    if jaxpr is None:
        jaxpr = jax.make_jaxpr(cell.fn)(*cell.args)
    loc = cell.cell_id
    allowed = {tuple(s) for s in getattr(cell, "allowed_const_shapes", ())}

    for arr in jaxpr_tools.const_arrays(jaxpr):
        if not np.issubdtype(arr.dtype, np.floating) and not np.issubdtype(
            arr.dtype, np.complexfloating
        ):
            continue
        if arr.size < CONST_SIZE_LIMIT:
            continue
        if tuple(arr.shape) in allowed:
            continue
        report.add(
            "JIT-BAKED-CONST",
            Severity.ERROR,
            loc,
            f"traced program bakes a float constant of shape {arr.shape} "
            f"({arr.dtype}); coding matrices must be runtime arguments so "
            f"survivor subsets never retrace",
        )

    bad_dtypes = sorted(
        {
            str(aval.dtype)
            for aval in jaxpr_tools.iter_avals(jaxpr)
            if hasattr(aval, "dtype") and str(aval.dtype) in F64_DTYPES
        }
    )
    if bad_dtypes:
        report.add(
            "JIT-F64",
            Severity.ERROR,
            loc,
            f"traced program contains {'/'.join(bad_dtypes)} avals; the "
            f"stack is float32-resident",
        )

    weak = [
        i
        for i, aval in enumerate(jaxpr.out_avals)
        if getattr(aval, "weak_type", False)
    ]
    if weak:
        report.add(
            "JIT-WEAK-TYPE",
            Severity.WARNING,
            loc,
            f"program outputs {weak} are weakly typed; a Python-scalar "
            f"promotion leaked into the traced program",
        )

    callbacks = sorted(
        jaxpr_tools.primitive_names(jaxpr) & HOST_CALLBACK_PRIMITIVES
    )
    if callbacks:
        report.add(
            "JIT-HOST-CALLBACK",
            Severity.ERROR,
            loc,
            f"host callback primitive(s) {callbacks} inside a jitted "
            f"program; host round trips serialize async dispatch",
        )
    return report.findings


def check_donation(cell) -> list:
    """JIT-DONATION on one cell that declares ``donate_argnums``."""
    report = Report()
    donate = tuple(getattr(cell, "donate_argnums", ()) or ())
    if not donate:
        return report.findings
    loc = cell.cell_id
    with warnings.catch_warnings():
        # CPU backends warn that donated buffers are unusable — the
        # platform copies; the *contract* (donation requested and wired
        # through) is what we verify, via args_info.
        warnings.filterwarnings(
            "ignore", message=".*donated.*", category=UserWarning
        )
        lowered = cell.fn.lower(*cell.args)
    # args_info is ((per-positional-arg pytrees...), kwargs-dict)
    positional = lowered.args_info[0]
    for argnum in donate:
        if argnum >= len(positional):
            report.add(
                "JIT-DONATION",
                Severity.ERROR,
                loc,
                f"donate_argnums includes {argnum} but the program has "
                f"{len(positional)} arguments",
            )
            continue
        leaves = _tree_leaves(positional[argnum])
        if not all(getattr(leaf, "donated", False) for leaf in leaves):
            report.add(
                "JIT-DONATION",
                Severity.ERROR,
                loc,
                f"argument {argnum} is declared donated but the lowered "
                f"module does not mark it donated",
            )
            continue
        # aliasing is only possible when some output matches the donated
        # input's aval; otherwise the platform must copy regardless
        donated_avals = {
            (tuple(leaf.shape), str(leaf.dtype)) for leaf in leaves
        }
        out_avals = {
            (tuple(a.shape), str(a.dtype)) for a in _out_avals(lowered)
        }
        if donated_avals & out_avals:
            if "tf.aliasing_output" not in lowered.as_text():
                report.add(
                    "JIT-DONATION",
                    Severity.ERROR,
                    loc,
                    f"argument {argnum} is donated and an output shares its "
                    f"aval, but the lowered module carries no "
                    f"tf.aliasing_output attribute — donation is not "
                    f"aliasing the buffer",
                )
        else:
            report.add(
                "JIT-DONATION",
                Severity.INFO,
                loc,
                f"argument {argnum} donated; no output matches its aval, so "
                f"buffer aliasing is impossible for this geometry (platform "
                f"will copy)",
            )
    return report.findings


def _tree_leaves(arg_info):
    import jax

    return jax.tree_util.tree_leaves(
        arg_info, is_leaf=lambda x: hasattr(x, "donated")
    )


def _out_avals(lowered):
    out = lowered.out_info
    import jax

    return [
        leaf
        for leaf in jax.tree_util.tree_leaves(
            out, is_leaf=lambda x: hasattr(x, "shape")
        )
        if hasattr(leaf, "shape")
    ]


def check_trace_bound(pipe, cells: Iterable, label: str) -> Report:
    """TRACE-BOUND: distinct trace signatures per execution mode must fit
    ``pipe.program_trace_bound``.  Static proof by exhaustive enumeration:
    ``program_space`` covers every (layer, bucket, mode) the pipeline can
    launch, and JIT-BAKED-CONST separately proves survivor subsets cannot
    mint new signatures."""
    report = Report()
    per_mode: dict[str, set] = {}
    for cell in cells:
        if cell.kind in ("worker", "transition"):
            per_mode.setdefault(cell.mode, set()).add(cell.trace_signature)
    bound = pipe.program_trace_bound
    for mode, sigs in sorted(per_mode.items()):
        report.stats[f"{label}/{mode}/traces"] = len(sigs)
        if len(sigs) > bound:
            report.add(
                "TRACE-BOUND",
                Severity.ERROR,
                f"{label}:{mode}",
                f"shape space induces {len(sigs)} worker+transition trace "
                f"signatures in {mode} mode, exceeding the bounded-trace "
                f"contract of {bound} "
                f"((geometries={pipe.num_geometries} + "
                f"transitions={pipe.num_transitions}) x "
                f"buckets={len(pipe.bucket_sizes or (1,))})",
            )
    report.stats[f"{label}/bound"] = bound
    return report


# -- LM decoder program space ------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecoderContractConfig:
    """One coded-LM-decoder family member: the decoder program space gets
    the same jit contracts as the ConvL pipelines — coding matrices as
    runtime args, no f64, no host callbacks, decode-step worker traces
    bounded by (GEMM geometry x bucket)."""

    plan_kind: str  # "coded" | "uncoded"
    backend: str  # "lax" | "pallas"
    n: int = 4
    k_b: int = 4
    buckets: tuple = (1, 2)

    @property
    def label(self) -> str:
        return f"lm-decoder/{self.backend}/{self.plan_kind}"


def iter_decoder_configs(
    backends: Sequence[str] = ("lax", "pallas"),
) -> list[DecoderContractConfig]:
    return [
        DecoderContractConfig(kind, backend)
        for backend in backends
        for kind in ("coded", "uncoded")
    ]


def build_decoder_pipeline(cfg: DecoderContractConfig):
    """The smoke LM config with zero weights (shape space only)."""
    import jax

    from repro.configs import smollm_135m
    from repro.core.decoder_pipeline import (UncodedPlan,
                                             build_lm_decoder_pipeline)

    bundle = smollm_135m.smoke()
    params = jax.tree.map(
        lambda s: np.zeros(s.shape, np.float32),
        bundle.param_shapes(np.float32),
    )
    plan = UncodedPlan(cfg.n) if cfg.plan_kind == "uncoded" else None
    return build_lm_decoder_pipeline(
        bundle.cfg, params, cfg.n,
        k_b=None if plan else cfg.k_b, plan=plan,
        backend=cfg.backend, interpret=True,
        bucket_sizes=cfg.buckets, max_len=32,
    )


# -- driver -----------------------------------------------------------------

def _analyze(pipe, label: str) -> Report:
    """Trace and check every program cell of one pipeline's shape space."""
    import jax

    report = Report()
    cells = list(pipe.program_space())
    report.extend(check_trace_bound(pipe, cells, label))
    seen: set = set()
    checked = 0
    for cell in cells:
        # decoder/encoder cells can repeat identical (fn, args) across
        # modes — checking one representative per program is enough
        key = (id(cell.fn), tuple(
            (a.shape, str(a.dtype)) for a in cell.args))
        if key in seen:
            continue
        seen.add(key)
        jaxpr = jax.make_jaxpr(cell.fn)(*cell.args)
        for f in check_jaxpr_contracts(cell, jaxpr):
            report.findings.append(
                dataclasses.replace(f, location=f"{label}/{f.location}")
            )
        if cell.donate_argnums:
            for f in check_donation(cell):
                report.findings.append(
                    dataclasses.replace(
                        f, location=f"{label}/{f.location}")
                )
        checked += 1
    report.stats[f"{label}/programs_checked"] = checked
    return report


def analyze_config(cfg: ContractConfig) -> Report:
    """Trace and check every program cell of one CNN pipeline config."""
    return _analyze(build_pipeline(cfg), cfg.label)


def analyze_decoder_config(cfg: DecoderContractConfig) -> Report:
    """Trace and check every program cell of one LM decoder config."""
    return _analyze(build_decoder_pipeline(cfg), cfg.label)


def run(
    archs: Sequence[str] | None = None,
    backends: Sequence[str] = ("lax", "pallas"),
) -> Report:
    """Run the contract analyzer over the full pipeline family: every CNN
    config plus the coded-LM-decoder program space."""
    report = Report()
    configs = iter_configs(archs, backends)
    for cfg in configs:
        report.extend(analyze_config(cfg))
    decoder_configs = iter_decoder_configs(backends)
    for dcfg in decoder_configs:
        report.extend(analyze_decoder_config(dcfg))
    report.stats["contract_configs"] = len(configs) + len(decoder_configs)
    return report
