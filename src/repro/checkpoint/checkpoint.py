"""Checkpointing: atomic, async-capable, mesh-agnostic.

Format: one ``.npz`` per checkpoint holding flattened leaves keyed by their
pytree path, plus a small JSON manifest (step, leaf dtypes).  Arrays are
pulled to host before writing, so a checkpoint written under one mesh can
be restored under any other (elastic re-shard on load: the restore path
device_puts each leaf with the *current* sharding).

Writes go to ``<dir>/tmp-<step>`` then ``os.replace`` -> crash-safe.
``AsyncCheckpointer`` overlaps serialization with training via a single
background thread (at most one in-flight save; the paper-level analogue of
overlap-compute-with-IO).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

SEP = "/"


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(directory: str, step: int, tree, extra: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp-{step}")
    final = os.path.join(directory, f"step-{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_paths(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in leaves.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    manifest = {"step": step, "keys": sorted(host), "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("-")[1])
        for d in os.listdir(directory)
        if d.startswith("step-")
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; if ``shardings`` (same
    structure) is given, each leaf is device_put with it — this is the
    elastic re-shard path for restarting under a different mesh."""
    path = os.path.join(directory, f"step-{step:08d}", "arrays.npz")
    data = np.load(path)
    keys = list(_flatten_with_paths(like_tree))
    flat_like, tdef = jax.tree_util.tree_flatten(like_tree)
    assert len(keys) == len(flat_like)
    arrays = [data[k] for k in keys]
    if shardings is not None:
        flat_sh = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "device_set") or hasattr(x, "_to_xla_hlo_sharding")
        )
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_sh)]
    return jax.tree_util.tree_unflatten(tdef, arrays)


class AsyncCheckpointer:
    """One-slot async writer: ``submit`` returns immediately; a previous
    in-flight save is joined first (bounded memory)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None

    def submit(self, step: int, tree, extra=None):
        self.wait()
        host = jax.device_get(tree)  # snapshot before training mutates buffers

        def work():
            save(self.directory, step, host, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(d.split("-")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step-")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:08d}"), ignore_errors=True)
