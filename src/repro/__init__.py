"""repro: FCDCC coded distributed convolution + the serving/training substrate.

Importing the package installs the jax version-compat shims (``repro.compat``)
so code written against the modern mesh API runs on jax 0.4.x too.
"""
from . import compat as _compat

_compat.install()
