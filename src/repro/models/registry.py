"""Uniform ModelBundle interface over all architecture families.

A bundle exposes everything the launcher / dry-run / tests need:
schema, loss (train), prefill, decode step, cache construction, and the
logical sharding axes of batch + cache leaves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.sharding import BATCH

from . import hymba as hymba_mod
from . import rwkv6 as rwkv_mod
from . import transformer as lm
from . import whisper as whisper_mod
from .common import schema_init, schema_shapes


@dataclasses.dataclass
class ModelBundle:
    name: str
    family: str  # "lm" | "vlm" | "encdec" | "ssm" | "hybrid"
    cfg: Any
    schema: dict
    sub_quadratic: bool
    has_decoder: bool
    loss_fn: Callable  # (params, batch) -> scalar
    prefill_fn: Callable  # (params, batch) -> logits
    decode_fn: Callable  # (params, cache, batch) -> (logits, cache)
    make_cache: Callable  # (batch, max_len) -> cache pytree
    cache_axes: Callable  # (cache_leaf_path_free) -> same-tree of axes tuples
    batch_axes: Callable  # (batch dict) -> same-tree of axes tuples
    # (params, cache, batch) -> (logits (B,P,V), filled cache): one jitted
    # cache-filling prompt pass; None for families without one (the serve
    # loop falls back to stepping the decoder over the prompt).
    prefill_cache_fn: Optional[Callable] = None

    def init(self, key, dtype=jnp.bfloat16):
        return schema_init(self.schema, key, dtype)

    def param_shapes(self, dtype=jnp.bfloat16):
        return schema_shapes(self.schema, dtype)


def _token_batch_axes(batch):
    """tokens/labels: batch over (pod,data); seq replicated (or data for B=1)."""
    out = {}
    for k, v in batch.items():
        if v.ndim == 0:
            out[k] = ()
        elif v.ndim >= 2 and v.shape[0] == 1:
            out[k] = (None, "data") + (None,) * (v.ndim - 2)
        else:
            out[k] = (BATCH,) + (None,) * (v.ndim - 1)
    return out


def _kv_cache_axes(tree):
    """(L, B, S, H, hd)-style leaves: B->batch axes, seq->model.

    §Perf: sequence-axis sharding + ring-writes make decode cache updates
    collective-free.  REPRO_BASELINE=1 restores the naive head/hd-axis
    sharding whose dynamic-update-slice forces a full cache all-gather.
    """
    import os

    baseline = os.environ.get("REPRO_BASELINE") == "1"

    def one(x):
        if x.ndim == 5:  # (L,B,S,H,hd)
            kv_divides = x.shape[3] % 16 == 0  # production model degree
            if baseline or kv_divides:
                # head-sharded cache + DUS (cheapest when kv heads shard)
                return (None, BATCH if x.shape[1] > 1 else None,
                        "data" if x.shape[1] == 1 else None, "model", "model")
            return (None, BATCH if x.shape[1] > 1 else None,
                    ("data", "model") if x.shape[1] == 1 else "model",
                    None, None)
        if x.ndim == 4:  # (L,B,S,dim) e.g. MLA latent
            if baseline:
                return (None, BATCH if x.shape[1] > 1 else None,
                        "data" if x.shape[1] == 1 else None, "model")
            return (None, BATCH if x.shape[1] > 1 else None,
                    ("data", "model") if x.shape[1] == 1 else "model", None)
        if x.ndim == 3:  # (L,B,d)
            return (None, BATCH if x.shape[1] > 1 else None, "model")
        return (None,) * x.ndim

    return jax.tree.map(one, tree)


def make_lm_bundle(cfg: lm.LMConfig, family="lm", prefix: tuple[int, int] | None = None):
    """prefix: (length, dim) of stub frontend embeddings (PaliGemma)."""

    def loss_fn(params, batch):
        return lm.lm_loss(
            params, cfg, batch["tokens"], batch["labels"], batch.get("prefix")
        )

    def prefill_fn(params, batch):
        return lm.forward(params, cfg, batch["tokens"], batch.get("prefix"))

    def decode_fn(params, cache, batch):
        return lm.decode_step(params, cfg, cache, batch["tokens"], batch["pos"])

    def prefill_cache_fn(params, cache, batch):
        return lm.prefill(params, cfg, cache, batch["tokens"])

    return ModelBundle(
        name=cfg.name,
        family=family,
        cfg=cfg,
        schema=lm.lm_schema(cfg),
        sub_quadratic=cfg.sub_quadratic,
        has_decoder=True,
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        make_cache=lambda b, s, dtype=jnp.bfloat16: lm.init_cache(cfg, b, s, dtype),
        cache_axes=_kv_cache_axes,
        batch_axes=_token_batch_axes,
        prefill_cache_fn=prefill_cache_fn,
    )


def make_rwkv_bundle(cfg: rwkv_mod.RwkvConfig):
    return ModelBundle(
        name=cfg.name,
        family="ssm",
        cfg=cfg,
        schema=rwkv_mod.rwkv_schema(cfg),
        sub_quadratic=True,
        has_decoder=True,
        loss_fn=lambda p, b: rwkv_mod.lm_loss(p, cfg, b["tokens"], b["labels"]),
        prefill_fn=lambda p, b: rwkv_mod.forward(p, cfg, b["tokens"]),
        decode_fn=lambda p, c, b: rwkv_mod.decode_step(p, cfg, c, b["tokens"], b["pos"]),
        make_cache=lambda b, s, dtype=jnp.bfloat16: rwkv_mod.init_state(cfg, b, dtype),
        cache_axes=lambda tree: jax.tree.map(
            lambda x: (None, BATCH if x.shape[1] > 1 else None, "model")
            + (None,) * (x.ndim - 3),
            tree,
        ),
        batch_axes=_token_batch_axes,
    )


def make_hymba_bundle(cfg: hymba_mod.HymbaConfig):
    def cache_axes(tree):
        def one(x):
            if x.ndim == 5 and x.shape[-1] == cfg.head_dim and x.shape[-2] != cfg.ssm_state:
                return (None, BATCH if x.shape[1] > 1 else None, None, "model", "model")
            if x.ndim == 5:  # ssm state (L,B,Hm,ns,hd)
                return (None, BATCH if x.shape[1] > 1 else None, "model", None, "model")
            return (None,) * x.ndim

        return jax.tree.map(one, tree)

    return ModelBundle(
        name=cfg.name,
        family="hybrid",
        cfg=cfg,
        schema=hymba_mod.hymba_schema(cfg),
        sub_quadratic=True,
        has_decoder=True,
        loss_fn=lambda p, b: hymba_mod.lm_loss(p, cfg, b["tokens"], b["labels"]),
        prefill_fn=lambda p, b: hymba_mod.forward(p, cfg, b["tokens"]),
        decode_fn=lambda p, c, b: hymba_mod.decode_step(p, cfg, c, b["tokens"], b["pos"]),
        make_cache=lambda b, s, dtype=jnp.bfloat16: hymba_mod.init_state(cfg, b, s, dtype),
        cache_axes=cache_axes,
        batch_axes=_token_batch_axes,
    )


def make_whisper_bundle(cfg: whisper_mod.WhisperConfig):
    def loss_fn(params, batch):
        return whisper_mod.lm_loss(
            params, cfg, batch["frames"], batch["tokens"], batch["labels"]
        )

    def prefill_fn(params, batch):
        return whisper_mod.forward(params, cfg, batch["frames"], batch["tokens"])

    def decode_fn(params, cache, batch):
        return whisper_mod.decode_step(params, cfg, cache, batch["tokens"], batch["pos"])

    return ModelBundle(
        name=cfg.name,
        family="encdec",
        cfg=cfg,
        schema=whisper_mod.whisper_schema(cfg),
        sub_quadratic=False,
        has_decoder=True,
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        make_cache=lambda b, s, dtype=jnp.bfloat16: whisper_mod.init_cache(cfg, b, s, dtype),
        cache_axes=lambda tree: jax.tree.map(
            lambda x: (None, BATCH if x.shape[1] > 1 else None, "model", None, None),
            tree,
        ),
        batch_axes=_token_batch_axes,
    )
