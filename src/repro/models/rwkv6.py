"""RWKV-6 "Finch": attention-free LM with data-dependent per-channel decay.

Faithful pieces: token-shift mixing, LoRA-produced data-dependent decay
``w_t = exp(-exp(w0 + tanh(x_w A_w) B_w))``, bonus ``u`` on the current
token, per-head normalization, gated output, squared-ReLU channel mix.
Simplification (DESIGN.md): static token-shift mix coefficients
(RWKV-5-style) instead of the data-dependent ddlerp.

Prefill/train use the chunked linear scan; decode is a true O(1)-state
recurrent step — which is why this arch runs the ``long_500k`` shape.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.sharding import BATCH, shard_hint

from .common import ParamSpec, rms_norm
from .linear_scan import chunked_linear_attention, linear_step


@dataclasses.dataclass(frozen=True)
class RwkvConfig:
    name: str
    layers: int
    d_model: int
    d_ff: int
    vocab: int
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 64

    @property
    def n_heads(self):
        return self.d_model // self.head_dim


def _layer_schema(cfg: RwkvConfig) -> dict:
    d, f, lora = cfg.d_model, cfg.d_ff, cfg.decay_lora
    h, hd = cfg.n_heads, cfg.head_dim
    mix = lambda: ParamSpec((d,), ("embed",), scale=0.02)
    return {
        "ln_att": ParamSpec((d,), ("embed",), scale=0.0),
        "mix_r": mix(), "mix_k": mix(), "mix_v": mix(),
        "mix_w": mix(), "mix_g": mix(),
        "w0": ParamSpec((d,), ("embed",), scale=0.02),
        "w_lora_a": ParamSpec((d, lora), ("embed", None)),
        "w_lora_b": ParamSpec((lora, d), (None, "embed"), scale=0.02),
        "wr": ParamSpec((d, d), ("embed", "heads")),
        "wk": ParamSpec((d, d), ("embed", "heads")),
        "wv": ParamSpec((d, d), ("embed", "heads")),
        "wg": ParamSpec((d, d), ("embed", "heads")),
        "wo": ParamSpec((d, d), ("heads", "embed")),
        "u": ParamSpec((h, hd), ("heads", None), scale=0.02),
        "ln_head": ParamSpec((h, hd), ("heads", None), scale=0.0),
        "ln_ffn": ParamSpec((d,), ("embed",), scale=0.0),
        "mix_fk": mix(), "mix_fr": mix(),
        "wk_ffn": ParamSpec((d, f), ("embed", "ff")),
        "wv_ffn": ParamSpec((f, d), ("ff", "embed")),
        "wr_ffn": ParamSpec((d, d), ("embed", "heads")),
    }


def rwkv_schema(cfg: RwkvConfig) -> dict:
    layer = _layer_schema(cfg)
    stacked = jax.tree.map(
        lambda p: ParamSpec((cfg.layers,) + p.shape, (None,) + p.axes, p.scale),
        layer,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), scale=0.0),
        "layers": stacked,
    }


def _shift(x, x_prev):
    """Token shift: x_{t-1} stream.  x: (B,T,d); x_prev: (B,d) carry."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _time_mix(w, x, cfg: RwkvConfig, x_prev, state, decode: bool):
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    xs = x_prev[:, None] if decode else _shift(x, x_prev)
    if decode:
        xs = xs[:, 0:1]
    r = _mix(x, xs, w["mix_r"]) @ w["wr"]
    k = _mix(x, xs, w["mix_k"]) @ w["wk"]
    v = _mix(x, xs, w["mix_v"]) @ w["wv"]
    g = _mix(x, xs, w["mix_g"]) @ w["wg"]
    xw = _mix(x, xs, w["mix_w"])
    dd = jnp.tanh(xw @ w["w_lora_a"]) @ w["w_lora_b"]
    log_w = -jnp.exp(
        jnp.clip(w["w0"].astype(jnp.float32) + dd.astype(jnp.float32), -8.0, 4.0)
    )  # (B,T,d) <= 0

    t = x.shape[1]
    rh = r.reshape(b, t, h, hd)
    kh = k.reshape(b, t, h, hd)
    vh = v.reshape(b, t, h, hd)
    lw = log_w.reshape(b, t, h, hd)
    u = w["u"].astype(jnp.float32)
    if decode:
        y, state = linear_step(
            rh[:, 0], kh[:, 0], vh[:, 0], lw[:, 0], state, bonus_u=u
        )
        y = y[:, None]
    else:
        y, state = chunked_linear_attention(
            rh, kh, vh, lw, bonus_u=u, chunk=cfg.chunk, state=state
        )
    y = rms_norm(y, w["ln_head"])  # per-head group norm
    y = y.reshape(b, t, h * hd) * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    return y @ w["wo"], x[:, -1], state


def _channel_mix(w, x, x_prev, decode: bool):
    xs = x_prev[:, None] if decode else _shift(x, x_prev)
    k = _mix(x, xs, w["mix_fk"]) @ w["wk_ffn"]
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid((_mix(x, xs, w["mix_fr"]) @ w["wr_ffn"]).astype(jnp.float32))
    return (k @ w["wv_ffn"]) * r.astype(x.dtype), x[:, -1]


def _layer(w, x, cfg, st, decode):
    h_in = rms_norm(x, w["ln_att"])
    att, xp_a, s = _time_mix(w, h_in, cfg, st["xa"], st["s"], decode)
    x = x + att
    h2 = rms_norm(x, w["ln_ffn"])
    ffn, xp_f = _channel_mix(w, h2, st["xf"], decode)
    return x + ffn, {"xa": xp_a, "xf": xp_f, "s": s}


def init_state(cfg: RwkvConfig, batch: int, dtype=jnp.bfloat16):
    """Recurrent state (the 'cache' of an attention-free model): O(1) in T."""
    return {
        "xa": jnp.zeros((cfg.layers, batch, cfg.d_model), dtype),
        "xf": jnp.zeros((cfg.layers, batch, cfg.d_model), dtype),
        "s": jnp.zeros(
            (cfg.layers, batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
            jnp.float32,
        ),
    }


def _run(params, cfg: RwkvConfig, tokens, state, decode: bool):
    x = params["embed"][tokens]
    x = shard_hint(x, BATCH, "data" if x.shape[0] == 1 else None, None)

    def body(x, xs):
        w, st = xs
        return _layer(w, x, cfg, st, decode)

    if not decode:
        body = jax.checkpoint(body)  # per-layer remat
    x, new_state = jax.lax.scan(body, x, (params["layers"], state))
    x = rms_norm(x, params["ln_f"])
    logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, new_state


def forward(params, cfg: RwkvConfig, tokens):
    state = init_state(cfg, tokens.shape[0])
    logits, _ = _run(params, cfg, tokens, state, decode=False)
    return logits


def decode_step(params, cfg: RwkvConfig, state, tokens, pos):
    del pos  # recurrent state is position-free
    return _run(params, cfg, tokens, state, decode=True)


def lm_loss(params, cfg: RwkvConfig, tokens, targets):
    logits = forward(params, cfg, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
