"""Chunked linear-attention / selective-SSM scan.

Canonical recurrence (per head, state S in R^{dk x dv}):

    S_t = S_{t-1} * w_t   + k_t (x) v_t          (w_t: per-channel decay)
    y_t = r_t . S_t                              (inclusive, Mamba2-style)
or, in RWKV mode:
    y_t = r_t . (S_{t-1} + (u * k_t) (x) v_t)    (bonus u on current token)
    S_t = S_{t-1} * w_t + k_t (x) v_t

Chunked evaluation: within a chunk of length C the contributions factor
through cumulative log-decays ``lp`` — intra-chunk pairs use
``exp(lp_t - lp_tau) <= 1`` (safe; decay <= 1) and the carried state uses
``exp(lp_last - lp_tau)``.  Cross-chunk state is carried by ``lax.scan``,
so activation memory is O(T * C) instead of O(T^2) and the HLO stays
compact.  This is the TPU-native adaptation of recurrent-layer papers:
MXU-sized GEMMs inside the chunk, a tiny sequential carry across chunks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["chunked_linear_attention", "linear_step"]


def chunked_linear_attention(
    r, k, v, log_decay, *, bonus_u=None, chunk: int = 64, state=None
):
    """r/k: (B,T,H,dk); v: (B,T,H,dv); log_decay: (B,T,H,dk) (log w_t <= 0).

    ``bonus_u``: (H, dk) enables RWKV mode.  ``state``: (B,H,dk,dv) carry.
    Returns (y, final_state): y (B,T,H,dv).
    """
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    c = min(chunk, t)
    t0 = t
    if t % c:  # pad tail: k=0 -> no state update; log_decay=0 -> no decay
        pad = c - t % c
        r, k, v = (jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * 2) for a in (r, k, v))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t = t + pad
    n = t // c
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    def reshape_c(x):
        return x.reshape(b, n, c, *x.shape[2:]).swapaxes(0, 1)  # (n,B,c,...)

    rc, kc, vc, lpc = map(reshape_c, (r, k, v, log_decay))

    tri_inc = jnp.tril(jnp.ones((c, c), bool))  # tau <= t
    tri_exc = jnp.tril(jnp.ones((c, c), bool), k=-1)  # tau < t
    mask = tri_exc if bonus_u is not None else tri_inc

    @jax.checkpoint
    def chunk_step(s, xs):
        rb, kb, vb, lpb = xs  # (B,c,H,dk)x3, (B,c,H,dv) for vb
        lp = jnp.cumsum(lpb.astype(jnp.float32), axis=1)  # (B,c,H,dk)
        # inter-chunk: query sees carried state through decay exp(lp) -- in
        # RWKV mode the query at t sees S_{t-1}: decay up to t-1 => shift.
        lp_q = lp
        if bonus_u is not None:
            lp_q = jnp.pad(lp, ((0, 0), (1, 0), (0, 0), (0, 0)))[:, :-1]
        q_dec = rb.astype(jnp.float32) * jnp.exp(lp_q)
        y_inter = jnp.einsum("bthk,bhkv->bthv", q_dec, s)
        # intra-chunk: pairwise decays exp(lp_q[t] - lp[tau]) <= 1
        diff = lp_q[:, :, None] - lp[:, None, :]  # (B,c,c,H,dk)
        a = jnp.einsum(
            "bthk,bshk,btshk->bths",
            rb.astype(jnp.float32),
            kb.astype(jnp.float32),
            jnp.exp(jnp.where(mask[None, :, :, None, None], diff, -jnp.inf)),
        )
        y_intra = jnp.einsum("bths,bshv->bthv", a, vb.astype(jnp.float32))
        if bonus_u is not None:
            diag = jnp.einsum(
                "bthk,hk,bthk->bth", rb.astype(jnp.float32), bonus_u, kb.astype(jnp.float32)
            )
            y_intra = y_intra + diag[..., None] * vb.astype(jnp.float32)
        # state update: S' = S * P_last + sum_tau exp(lp_last - lp_tau) k v
        p_last = lp[:, -1][:, None]  # (B,1,H,dk)
        k_dec = kb.astype(jnp.float32) * jnp.exp(p_last - lp)
        s_new = s * jnp.exp(lp[:, -1])[..., None] + jnp.einsum(
            "bthk,bthv->bhkv", k_dec, vb.astype(jnp.float32)
        )
        return s_new, (y_inter + y_intra).astype(r.dtype)

    state, ys = jax.lax.scan(chunk_step, state, (rc, kc, vc, lpc))
    y = ys.swapaxes(0, 1).reshape(b, t, h, dv)[:, :t0]
    return y, state


def linear_step(r, k, v, log_decay, state, *, bonus_u=None):
    """Single decode step.  r/k: (B,H,dk); v: (B,H,dv); state (B,H,dk,dv)."""
    w = jnp.exp(log_decay.astype(jnp.float32))[..., None]  # (B,H,dk,1)
    kv = jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32))
    if bonus_u is not None:
        att = state + bonus_u[None, :, :, None] * kv
        y = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32), att)
        state = state * w + kv
    else:
        state = state * w + kv
        y = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32), state)
    return y.astype(r.dtype), state
