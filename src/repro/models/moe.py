"""Mixture-of-Experts layer with sort-based capacity dispatch.

Dispatch strategy (TPU-native, compiles to gather/scatter + grouped GEMMs;
no (T, E, C) one-hot monsters):
  1. router top-k -> (token, expert, weight) triples,
  2. sort triples by expert id,
  3. position-in-expert via segment arithmetic; drop beyond capacity C,
  4. scatter tokens into an (E, C, d) buffer, run batched expert GEMMs,
  5. weighted scatter-add back to (T, d).

Experts shard over the mesh "model" axis (expert parallelism): the (E, C, d)
buffer and the expert weights both carry the ``experts`` logical axis, so
GSPMD turns the scatter/gather into an all-to-all-style exchange.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.sharding import BATCH, MODEL, shard_hint

from .common import ParamSpec


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_model: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.0
    # dispatch groups: capacity bookkeeping is done per contiguous token
    # group; set this to the data-parallel degree so groups align with
    # batch shards (each data shard dispatches its own tokens).
    dispatch_groups: int = 1


def moe_schema(cfg: MoEConfig) -> dict:
    e, d, f = cfg.n_routed, cfg.d_model, cfg.d_ff_expert
    schema = {
        "router": ParamSpec((d, e), ("embed", "experts")),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "w_down": ParamSpec((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.n_shared:
        fs = f * cfg.n_shared
        schema["shared"] = {
            "w_gate": ParamSpec((d, fs), ("embed", "ff")),
            "w_up": ParamSpec((d, fs), ("embed", "ff")),
            "w_down": ParamSpec((fs, d), ("ff", "embed")),
        }
    return schema


def _expert_ffn(w, xb):
    """xb: (E, C, d) -> (E, C, d); SwiGLU experts as batched GEMMs."""
    g = jnp.einsum("ecd,edf->ecf", xb, w["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xb, w["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, w["w_down"])


def moe_ffn(w, x, cfg: MoEConfig):
    """x: (T, d) -> (T, d).  Dispatch is per group (see MoEConfig)."""
    t, d = x.shape
    g = cfg.dispatch_groups if t % cfg.dispatch_groups == 0 else 1
    xg = x.reshape(g, t // g, d)
    if g > 1:
        # groups align with batch shards: all group-local ops below carry an
        # explicit leading G axis so the sharding constraint survives
        # (a vmap here hides the constraint and GSPMD replicates the
        # expert buffers across the data axis -- a 16x compute blowup).
        xg = shard_hint(xg, BATCH, None, None)
    yg = _moe_ffn_grouped(w, xg, cfg)
    return yg.reshape(t, d)


def _moe_ffn_grouped(w, xg, cfg: MoEConfig):
    """Gather-based grouped dispatch (§Perf): no float scatters.

    Float scatters into expert-sharded buffers force GSPMD to replicate and
    all-reduce the whole (E, C, d) buffer (TBs per step at DeepSeek scale).
    Instead we scatter only a tiny int32 slot->token index map, then GATHER
    activations into the buffer and gather expert outputs back per (token,
    k) entry.  All tensors keep the explicit (G, ...) group axis sharded
    over the data mesh axes; expert tensors shard over model.
    """
    g, t, d = xg.shape
    e, k = cfg.n_routed, cfg.top_k
    cap = max(int(cfg.capacity_factor * k * t / e), 8)
    cap = -(-cap // 8) * 8  # MXU-friendly

    logits = jnp.einsum("gtd,de->gte", xg, w["router"].astype(xg.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, k)  # (G, T, K)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    flat_e = gate_e.reshape(g, t * k)  # token-major entries per group
    order = jnp.argsort(flat_e, axis=-1)
    se = jnp.take_along_axis(flat_e, order, axis=-1)

    # position within expert segment: pos = idx - first-index-of-expert
    starts = jax.vmap(lambda s: jnp.searchsorted(s, jnp.arange(e)))(se)  # (G,E)
    pos = jnp.arange(t * k)[None] - jnp.take_along_axis(starts, se, axis=-1)
    keep = pos < cap
    dest_e = jnp.where(keep, se, e - 1)
    dest_c = jnp.where(keep, pos, cap)  # cap column = drop bin
    src_token = order // k  # (G, T*K)

    # int32-only scatter: slot -> token+1 (0 = empty).  ~G*E*C*4 bytes.
    slot_src = jnp.zeros((g, e, cap + 1), jnp.int32)
    gi = jnp.broadcast_to(jnp.arange(g)[:, None], dest_e.shape)
    slot_src = slot_src.at[gi, dest_e, dest_c].set(
        src_token.astype(jnp.int32) + 1, mode="drop"
    )[:, :, :cap]
    valid = slot_src > 0

    if os.environ.get("REPRO_BASELINE") == "1":
        return _moe_baseline_scatter(
            w, xg, cfg, cap, dest_e, dest_c, keep, src_token, order, gate_w
        )

    flat_idx = jnp.maximum(slot_src - 1, 0).reshape(g, e * cap)
    buf = jnp.take_along_axis(xg, flat_idx[..., None], axis=1)  # (G,E*cap,d)
    buf = buf.reshape(g, e, cap, d) * valid[..., None].astype(xg.dtype)
    buf = shard_hint(buf, BATCH, MODEL, None, None)
    out_buf = _expert_ffn_grouped(w, buf)  # (G,E,cap,d)
    out_buf = shard_hint(out_buf, BATCH, MODEL, None, None)

    # combine: each (token, k) entry gathers its expert-output row
    inv = jnp.argsort(order, axis=-1)  # entry -> sorted position
    entry_pos = jnp.take_along_axis(pos, inv, axis=-1)
    entry_keep = jnp.take_along_axis(keep, inv, axis=-1)
    entry_slot = flat_e * cap + jnp.minimum(entry_pos, cap - 1)  # (G, T*K)
    vals = jnp.take_along_axis(
        out_buf.reshape(g, e * cap, d), entry_slot[..., None], axis=1
    )
    vals = jnp.where(entry_keep[..., None], vals, 0)
    y = jnp.sum(
        vals.reshape(g, t, k, d) * gate_w[..., None].astype(xg.dtype), axis=2
    )

    if cfg.n_shared:
        y = y + _shared_ffn(w, xg)
    return y


def _expert_ffn_grouped(w, xb):
    """xb: (G, E, C, d) -> (G, E, C, d); SwiGLU experts as batched GEMMs."""
    gg = jnp.einsum("gecd,edf->gecf", xb, w["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xb, w["w_up"])
    h = jax.nn.silu(gg.astype(jnp.float32)).astype(u.dtype) * u
    return jnp.einsum("gecf,efd->gecd", h, w["w_down"])


def _shared_ffn(w, xg):
    s = w["shared"]
    gg = jnp.einsum("gtd,df->gtf", xg, s["w_gate"])
    u = jnp.einsum("gtd,df->gtf", xg, s["w_up"])
    h = jax.nn.silu(gg.astype(jnp.float32)).astype(u.dtype) * u
    return jnp.einsum("gtf,fd->gtd", h, s["w_down"])


def _moe_baseline_scatter(w, xg, cfg, cap, dest_e, dest_c, keep, src_token,
                          order, gate_w):
    """Paper-faithful baseline (§Perf A/B): float scatter/scatter-add
    dispatch, which GSPMD lowers with full-buffer replication+all-reduce."""
    g, t, d = xg.shape
    e, k = cfg.n_routed, cfg.top_k
    gi = jnp.broadcast_to(jnp.arange(g)[:, None], dest_e.shape)
    x_entries = jnp.take_along_axis(xg, src_token[..., None], axis=1)
    buf0 = jnp.zeros((g, e, cap + 1, d), xg.dtype)
    buf0 = buf0.at[gi, dest_e, dest_c].set(x_entries, mode="drop")
    out_buf0 = _expert_ffn_grouped(w, buf0[:, :, :cap])
    out_buf0 = jnp.pad(out_buf0, ((0, 0), (0, 0), (0, 1), (0, 0)))
    sw = jnp.take_along_axis(gate_w.reshape(g, t * k), order, axis=-1)
    contrib = out_buf0[gi, dest_e, dest_c] * sw[..., None].astype(xg.dtype)
    contrib = jnp.where(keep[..., None], contrib, 0.0)
    y = jnp.zeros((g, t, d), xg.dtype)
    y = y.at[gi, src_token].add(contrib, mode="drop")
    if cfg.n_shared:
        y = y + _shared_ffn(w, xg)
    return y
