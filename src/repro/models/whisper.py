"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, d) straight into the encoder.
Encoder: bidirectional self-attention.  Decoder: causal self-attention +
cross-attention to the encoder output, with a self-KV cache and
precomputed cross-KV for decode.  Norms are RMS (simplification noted in
DESIGN.md); activations GELU as in the paper.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.sharding import BATCH, shard_hint

from .common import ParamSpec, attention, make_attn_mask, rms_norm
from .transformer import _flash_attention, _ring_write


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str
    enc_layers: int
    dec_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    enc_len: int = 1500
    max_dec_len: int = 32768
    flash_chunk: int = 1024

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


def _attn_schema(d, axes=("embed", "heads")):
    return {
        "wq": ParamSpec((d, d), axes),
        "wk": ParamSpec((d, d), axes),
        "wv": ParamSpec((d, d), axes),
        "wo": ParamSpec((d, d), (axes[1], axes[0])),
    }


def _enc_layer_schema(cfg):
    d = cfg.d_model
    return {
        "ln1": ParamSpec((d,), ("embed",), scale=0.0),
        "self": _attn_schema(d),
        "ln2": ParamSpec((d,), ("embed",), scale=0.0),
        "w_up": ParamSpec((d, cfg.d_ff), ("embed", "ff")),
        "w_down": ParamSpec((cfg.d_ff, d), ("ff", "embed")),
    }


def _dec_layer_schema(cfg):
    s = _enc_layer_schema(cfg)
    s["ln_cross"] = ParamSpec((cfg.d_model,), ("embed",), scale=0.0)
    s["cross"] = _attn_schema(cfg.d_model)
    return s


def _stack(schema, n):
    return jax.tree.map(
        lambda p: ParamSpec((n,) + p.shape, (None,) + p.axes, p.scale),
        schema,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def whisper_schema(cfg: WhisperConfig) -> dict:
    d = cfg.d_model
    return {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), scale=0.02),
        "pos_dec": ParamSpec((cfg.max_dec_len, d), (None, "embed"), scale=0.01),
        "pos_enc": ParamSpec((cfg.enc_len, d), (None, "embed"), scale=0.01),
        "enc_layers": _stack(_enc_layer_schema(cfg), cfg.enc_layers),
        "dec_layers": _stack(_dec_layer_schema(cfg), cfg.dec_layers),
        "ln_enc": ParamSpec((d,), ("embed",), scale=0.0),
        "ln_dec": ParamSpec((d,), ("embed",), scale=0.0),
    }


def _mha(w, xq, xkv, mask, cfg, q_pos=None, k_pos=None, causal=False):
    b, sq, d = xq.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (xq @ w["wq"]).reshape(b, sq, h, hd)
    k = (xkv @ w["wk"]).reshape(b, -1, h, hd)
    v = (xkv @ w["wv"]).reshape(b, -1, h, hd)
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    if (
        causal
        and sq > cfg.flash_chunk
        and sq % cfg.flash_chunk == 0
        and sk % cfg.flash_chunk == 0
    ):
        out = _flash_attention(
            q, k, v, q_pos, k_pos, scale=scale, window=None,
            attn_softcap=None, chunk=cfg.flash_chunk,
        )
    else:
        out = attention(q, k, v, mask, scale=scale)
    return out.reshape(b, sq, d) @ w["wo"]


def _ffn(w, x):
    h = jax.nn.gelu((x @ w["w_up"]).astype(jnp.float32)).astype(x.dtype)
    return h @ w["w_down"]


def encode(params, cfg: WhisperConfig, frames):
    """frames: (B, enc_len, d) stub embeddings -> encoder states."""
    x = frames + params["pos_enc"][None].astype(frames.dtype)
    x = shard_hint(x, BATCH, None, None)
    b, s, _ = x.shape
    zero_mask = jnp.zeros((b, 1, s, s), jnp.float32)

    @jax.checkpoint
    def body(x, w):
        h = rms_norm(x, w["ln1"])
        x = x + _mha(w["self"], h, h, zero_mask, cfg)
        h = rms_norm(x, w["ln2"])
        return x + _ffn(w, h), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["ln_enc"])


def decode(params, cfg: WhisperConfig, tokens, enc_out):
    """Teacher-forced decoder pass. tokens: (B, S_dec)."""
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos_dec"][:s][None].astype(jnp.bfloat16)
    x = shard_hint(x, BATCH, None, None)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    causal = make_attn_mask(pos, pos)
    cross_mask = jnp.zeros((b, 1, s, enc_out.shape[1]), jnp.float32)

    @jax.checkpoint
    def body(x, w):
        h = rms_norm(x, w["ln1"])
        x = x + _mha(w["self"], h, h, causal, cfg, pos, pos, causal=True)
        h = rms_norm(x, w["ln_cross"])
        x = x + _mha(w["cross"], h, enc_out, cross_mask, cfg)
        h = rms_norm(x, w["ln2"])
        return x + _ffn(w, h), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["ln_dec"])
    return (x @ params["embed"].T).astype(jnp.float32)


def forward(params, cfg: WhisperConfig, frames, tokens):
    return decode(params, cfg, tokens, encode(params, cfg, frames))


def init_cache(cfg: WhisperConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    h, hd = cfg.n_heads, cfg.head_dim
    return {
        "k": jnp.zeros((cfg.dec_layers, batch, max_len, h, hd), dtype),
        "v": jnp.zeros((cfg.dec_layers, batch, max_len, h, hd), dtype),
        # cross K/V precomputed once per request at prefill
        "ck": jnp.zeros((cfg.dec_layers, batch, cfg.enc_len, h, hd), dtype),
        "cv": jnp.zeros((cfg.dec_layers, batch, cfg.enc_len, h, hd), dtype),
    }


def precompute_cross_kv(params, cfg: WhisperConfig, enc_out, cache):
    h, hd = cfg.n_heads, cfg.head_dim
    b = enc_out.shape[0]
    dec = params["dec_layers"]["cross"]
    ck = jnp.einsum("bsd,ldh->lbsh", enc_out, dec["wk"]).reshape(
        cfg.dec_layers, b, cfg.enc_len, h, hd
    )
    cv = jnp.einsum("bsd,ldh->lbsh", enc_out, dec["wv"]).reshape(
        cfg.dec_layers, b, cfg.enc_len, h, hd
    )
    return {**cache, "ck": ck.astype(cache["ck"].dtype), "cv": cv.astype(cache["cv"].dtype)}


def decode_step(params, cfg: WhisperConfig, cache, tokens, pos):
    """One decoder token with self-KV cache + precomputed cross-KV."""
    b = tokens.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    max_len = cache["k"].shape[2]
    x = params["embed"][tokens] + jax.lax.dynamic_slice_in_dim(
        params["pos_dec"], pos, 1, axis=0
    )[None].astype(jnp.bfloat16)
    q_pos = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1))
    k_pos = jnp.broadcast_to(jnp.arange(max_len, dtype=jnp.int32), (b, max_len))
    self_mask = make_attn_mask(q_pos, k_pos)
    cross_mask = jnp.zeros((b, 1, 1, cfg.enc_len), jnp.float32)

    def body(x, xs):
        w, kc, vc, ckc, cvc = xs
        hn = rms_norm(x, w["ln1"])
        q = (hn @ w["self"]["wq"]).reshape(b, 1, h, hd)
        k = (hn @ w["self"]["wk"]).reshape(b, 1, h, hd)
        v = (hn @ w["self"]["wv"]).reshape(b, 1, h, hd)
        kc = _ring_write(kc, k, pos)
        vc = _ring_write(vc, v, pos)
        out = attention(q, kc, vc, self_mask, scale=1.0 / math.sqrt(hd))
        x = x + out.reshape(b, 1, -1) @ w["self"]["wo"]
        hn = rms_norm(x, w["ln_cross"])
        qc = (hn @ w["cross"]["wq"]).reshape(b, 1, h, hd)
        outc = attention(qc, ckc, cvc, cross_mask, scale=1.0 / math.sqrt(hd))
        x = x + outc.reshape(b, 1, -1) @ w["cross"]["wo"]
        hn = rms_norm(x, w["ln2"])
        x = x + _ffn(w, hn)
        return x, (kc, vc)

    x, (kcs, vcs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["ck"], cache["cv"])
    )
    x = rms_norm(x, params["ln_dec"])
    logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, {**cache, "k": kcs, "v": vcs}


def lm_loss(params, cfg: WhisperConfig, frames, tokens, targets):
    logits = forward(params, cfg, frames, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
