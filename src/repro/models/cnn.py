"""The paper's CNN workloads: LeNet-5, AlexNet, VGG-16 ConvL stacks.

Each network is a list of conv-layer geometries (the paper's experiments
time only the ConvLs).  ``run_convls`` executes the stack either
single-node ("naive") or — when given a plan — as a thin wrapper over the
``repro.core.pipeline.CodedPipeline`` engine (every ConvL coded, filters
encoded once, batched inputs) — this drives benchmarks/exp1..exp5 and the
coded-inference example.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.fcdcc import FcdccPlan
from repro.core.partition import ConvGeometry
from repro.core.pipeline import CodedPipeline, plan_layers, relu_pool


@dataclasses.dataclass(frozen=True)
class ConvL:
    name: str
    in_ch: int
    out_ch: int
    kernel: int
    stride: int = 1
    padding: int = 0
    pool: int = 1  # max-pool factor applied after relu


# (input spatial size, conv layer list) — classic configs
LENET5 = (
    32,
    [
        ConvL("conv1", 1, 6, 5),
        ConvL("conv2", 6, 16, 5, pool=2),
    ],
)

ALEXNET = (
    227,
    [
        ConvL("conv1", 3, 96, 11, stride=4, pool=2),
        ConvL("conv2", 96, 256, 5, padding=2, pool=2),
        ConvL("conv3", 256, 384, 3, padding=1),
        ConvL("conv4", 384, 384, 3, padding=1),
        ConvL("conv5", 384, 256, 3, padding=1, pool=2),
    ],
)

VGG16 = (
    224,
    [
        ConvL("conv1_1", 3, 64, 3, padding=1),
        ConvL("conv1_2", 64, 64, 3, padding=1, pool=2),
        ConvL("conv2_1", 64, 128, 3, padding=1),
        ConvL("conv2_2", 128, 128, 3, padding=1, pool=2),
        ConvL("conv3_1", 128, 256, 3, padding=1),
        ConvL("conv3_2", 256, 256, 3, padding=1),
        ConvL("conv3_3", 256, 256, 3, padding=1, pool=2),
        ConvL("conv4_1", 256, 512, 3, padding=1),
        ConvL("conv4_2", 512, 512, 3, padding=1),
        ConvL("conv4_3", 512, 512, 3, padding=1, pool=2),
        ConvL("conv5_1", 512, 512, 3, padding=1),
        ConvL("conv5_2", 512, 512, 3, padding=1),
        ConvL("conv5_3", 512, 512, 3, padding=1, pool=2),
    ],
)

CNN_SPECS = {"lenet5": LENET5, "alexnet": ALEXNET, "vgg16": VGG16}

# reduced spatial sizes for CPU smoke runs (serve CLI, exp6, examples)
SMOKE_HW = {"lenet5": 32, "alexnet": 113, "vgg16": 56}


def input_hw(name: str, smoke: bool = False) -> int:
    """Canonical input resolution of a named CNN (``smoke`` shrinks it)."""
    return SMOKE_HW[name] if smoke else CNN_SPECS[name][0]


def layer_geometry(layer: ConvL, hw: int, k_a: int = 1, k_b: int = 1) -> ConvGeometry:
    return ConvGeometry(
        in_channels=layer.in_ch,
        out_channels=layer.out_ch,
        height=hw,
        width=hw,
        kernel_h=layer.kernel,
        kernel_w=layer.kernel,
        stride=layer.stride,
        padding=layer.padding,
        k_a=k_a,
        k_b=k_b,
    )


def init_cnn(name: str, key, dtype=jnp.float32):
    _, layers = CNN_SPECS[name]
    keys = jax.random.split(key, len(layers))
    return {
        l.name: jax.random.normal(k, (l.out_ch, l.in_ch, l.kernel, l.kernel), dtype)
        * (1.0 / (l.in_ch * l.kernel**2) ** 0.5)
        for k, l in zip(keys, layers)
    }


def run_convls(name: str, params, x, *, plan: FcdccPlan | None = None,
               per_layer_kab: dict | None = None, worker_ids=None, backend="lax"):
    """Run the ConvL stack on one image (C,H,W) or a batch (B,C,H,W).

    ``plan=None`` -> single-node naive execution; otherwise the stack is
    compiled into a ``CodedPipeline`` (filters encoded once, one jitted
    worker program per distinct geometry) with (k_a, k_b) from
    ``per_layer_kab`` (falls back to the plan's defaults).  ``worker_ids``
    are the available workers; each layer decodes from the first delta.
    """
    _, layers = CNN_SPECS[name]
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
    if plan is None:
        for layer in layers:
            y = jax.lax.conv_general_dilated(
                x, params[layer.name],
                window_strides=(layer.stride, layer.stride),
                padding=((layer.padding, layer.padding),) * 2,
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
            )
            x = relu_pool(y, layer.pool)
    else:
        specs = plan_layers(
            layers, x.shape[-1], plan.n,
            default_kab=(plan.k_a, plan.k_b), per_layer_kab=per_layer_kab,
        )
        pipe = CodedPipeline(specs, params, backend=backend)
        x = pipe.run(x, worker_ids)
    return x[0] if squeeze else x
