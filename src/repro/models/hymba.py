"""Hymba-style hybrid: parallel attention + SSM heads inside every layer.

Each layer splits the (normed) input into an attention branch (GQA, RoPE,
sliding-window) and a Mamba-style selective-SSM branch (depthwise causal
conv, data-dependent dt/B/C, per-head scalar decay — the Mamba-2
simplification, noted in DESIGN.md), then fuses the two normed branch
outputs by averaging (the paper's mean fusion).  Meta-tokens are omitted.

Sub-quadratic: attention is windowed, SSM is O(T) — this arch runs the
``long_500k`` shape with an O(window + state) cache.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.sharding import BATCH, shard_hint

from .common import ParamSpec, apply_rope, attention, make_attn_mask, rms_norm, rope_inv_freq
from .linear_scan import chunked_linear_attention, linear_step
from .transformer import _flash_attention, _ring_write


@dataclasses.dataclass(frozen=True)
class HymbaConfig:
    name: str
    layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    ssm_state: int = 16
    ssm_expand: int = 2
    conv_width: int = 4
    window: int = 1024
    rope_base: float = 10000.0
    chunk: int = 64
    flash_chunk: int = 1024

    @property
    def d_inner(self):
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self):
        return self.d_inner // self.head_dim


def _layer_schema(cfg: HymbaConfig) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    di, ns, hm = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return {
        "ln": ParamSpec((d,), ("embed",), scale=0.0),
        # attention branch
        "wq": ParamSpec((d, h * hd), ("embed", "heads")),
        "wk": ParamSpec((d, hkv * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, hkv * hd), ("embed", "kv_heads")),
        "wo_attn": ParamSpec((h * hd, d), ("heads", "embed")),
        "ln_attn_out": ParamSpec((d,), ("embed",), scale=0.0),
        # ssm branch
        "w_in": ParamSpec((d, 2 * di), ("embed", "ff")),  # u and gate z
        "conv": ParamSpec((cfg.conv_width, di), (None, "ff"), scale=0.02),
        "w_bc": ParamSpec((di, 2 * ns), ("ff", None)),
        "w_dt": ParamSpec((di, hm), ("ff", "heads")),
        "a_log": ParamSpec((hm,), ("heads",), scale=0.02),
        "d_skip": ParamSpec((hm,), ("heads",), scale=0.02),
        "wo_ssm": ParamSpec((di, d), ("ff", "embed")),
        "ln_ssm_out": ParamSpec((d,), ("embed",), scale=0.0),
        # ffn
        "ln_ffn": ParamSpec((d,), ("embed",), scale=0.0),
        "w_gate": ParamSpec((d, cfg.d_ff), ("embed", "ff")),
        "w_up": ParamSpec((d, cfg.d_ff), ("embed", "ff")),
        "w_down": ParamSpec((cfg.d_ff, d), ("ff", "embed")),
    }


def hymba_schema(cfg: HymbaConfig) -> dict:
    stacked = jax.tree.map(
        lambda p: ParamSpec((cfg.layers,) + p.shape, (None,) + p.axes, p.scale),
        _layer_schema(cfg),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), scale=0.0),
        "layers": stacked,
    }


def _attn_branch(w, x, cfg, rope, q_pos, k_pos, cache):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = apply_rope((x @ w["wq"]).reshape(b, s, h, hd), rope, q_pos)
    k = apply_rope((x @ w["wk"]).reshape(b, s, hkv, hd), rope, q_pos)
    v = (x @ w["wv"]).reshape(b, s, hkv, hd)
    if cache is not None:
        pos = q_pos[0, 0]
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
        cache = {"k": k, "v": v}
    scale = 1.0 / math.sqrt(hd)
    if s > cfg.flash_chunk and s % cfg.flash_chunk == 0:
        out = _flash_attention(
            q, k, v, q_pos, k_pos, scale=scale, window=cfg.window,
            attn_softcap=None, chunk=cfg.flash_chunk,
        )
    else:
        mask = make_attn_mask(q_pos, k_pos, cfg.window)
        out = attention(q, k, v, mask, scale=scale)
    return out.reshape(b, s, h * hd) @ w["wo_attn"], cache


def _causal_conv(u, kernel, tail):
    """Depthwise causal conv. u: (B,T,di); kernel: (W,di); tail: (B,W-1,di)."""
    w = kernel.shape[0]
    up = jnp.concatenate([tail.astype(u.dtype), u], axis=1)
    out = sum(up[:, i : i + u.shape[1]] * kernel[i] for i in range(w))
    return out, up[:, -(w - 1) :]


def _ssm_branch(w, x, cfg: HymbaConfig, state, decode: bool):
    b, t, _ = x.shape
    di, ns, hm, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.head_dim
    uz = x @ w["w_in"]
    u, z = jnp.split(uz, 2, axis=-1)
    u, conv_tail = _causal_conv(u, w["conv"], state["conv"])
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    bc = u @ w["w_bc"]
    b_in, c_out = jnp.split(bc, 2, axis=-1)  # (B,T,ns) each
    dt = jax.nn.softplus((u @ w["w_dt"]).astype(jnp.float32))  # (B,T,hm)
    a = -jnp.exp(w["a_log"].astype(jnp.float32))  # (hm,) < 0
    log_decay = dt * a  # (B,T,hm)

    # map to linear attention: k = B (dk=ns), v = dt*u per head (dv=hd), r = C
    kh = jnp.broadcast_to(b_in[:, :, None, :], (b, t, hm, ns))
    rh = jnp.broadcast_to(c_out[:, :, None, :], (b, t, hm, ns))
    vh = (u * dt.repeat(hd, axis=-1).astype(u.dtype)).reshape(b, t, hm, hd)
    lw = jnp.broadcast_to(log_decay[..., None], (b, t, hm, ns))
    if decode:
        y, s = linear_step(rh[:, 0], kh[:, 0], vh[:, 0], lw[:, 0], state["s"])
        y = y[:, None]
    else:
        y, s = chunked_linear_attention(rh, kh, vh, lw, chunk=cfg.chunk, state=state["s"])
    y = y.reshape(b, t, di) + u * w["d_skip"].repeat(hd).astype(u.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return y @ w["wo_ssm"], {"conv": conv_tail, "s": s}


def _layer(w, x, cfg, rope, q_pos, k_pos, st, decode):
    h_in = rms_norm(x, w["ln"])
    attn_out, kv = _attn_branch(
        w, h_in, cfg, rope, q_pos, k_pos, st["kv"] if decode else None
    )
    ssm_out, ssm_st = _ssm_branch(w, h_in, cfg, st, decode)
    fused = 0.5 * (
        rms_norm(attn_out, w["ln_attn_out"]) + rms_norm(ssm_out, w["ln_ssm_out"])
    )
    x = x + fused
    h2 = rms_norm(x, w["ln_ffn"])
    g = h2 @ w["w_gate"]
    up = h2 @ w["w_up"]
    ffn = (jax.nn.silu(g.astype(jnp.float32)).astype(up.dtype) * up) @ w["w_down"]
    new_st = {"conv": ssm_st["conv"], "s": ssm_st["s"], "kv": kv}
    return x + ffn, new_st


def init_state(cfg: HymbaConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode cache: windowed KV + O(1) SSM state.  For long-context decode
    the KV cache only needs ``window`` slots, but we allocate ``max_len``
    capped at window for generality."""
    kv_len = min(max_len, cfg.window)
    return {
        "kv": {
            "k": jnp.zeros((cfg.layers, batch, kv_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((cfg.layers, batch, kv_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        },
        "conv": jnp.zeros((cfg.layers, batch, cfg.conv_width - 1, cfg.d_inner), dtype),
        "s": jnp.zeros(
            (cfg.layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.head_dim),
            jnp.float32,
        ),
    }


def _prefill_state(cfg: HymbaConfig, batch: int):
    return {
        "conv": jnp.zeros((cfg.layers, batch, cfg.conv_width - 1, cfg.d_model * cfg.ssm_expand), jnp.bfloat16),
        "s": jnp.zeros(
            (cfg.layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.head_dim), jnp.float32
        ),
        "kv": None,
    }


def forward(params, cfg: HymbaConfig, tokens):
    b, s = tokens.shape
    x = params["embed"][tokens]
    x = shard_hint(x, BATCH, "data" if b == 1 else None, None)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    rope = rope_inv_freq(cfg.head_dim, cfg.rope_base)
    st = _prefill_state(cfg, b)

    def body(x, xs):
        w, stl = xs
        x, _ = _layer(w, x, cfg, rope, pos, pos, stl, decode=False)
        return x, None

    x, _ = jax.lax.scan(body, x, (params["layers"], st))
    x = rms_norm(x, params["ln_f"])
    return (x @ params["embed"].T).astype(jnp.float32)


def decode_step(params, cfg: HymbaConfig, state, tokens, pos):
    """pos: absolute position; KV cache slot = pos % window (ring buffer)."""
    b = tokens.shape[0]
    x = params["embed"][tokens]
    kv_len = state["kv"]["k"].shape[2]
    slot = jnp.mod(pos.astype(jnp.int32), kv_len)
    q_pos = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1))
    # ring buffer: key positions reconstructed relative to current pos
    idx = jnp.arange(kv_len, dtype=jnp.int32)
    k_pos = jnp.where(
        idx <= slot, pos - (slot - idx), pos - (slot + kv_len - idx)
    )
    k_pos = jnp.broadcast_to(k_pos[None], (b, kv_len))
    rope = rope_inv_freq(cfg.head_dim, cfg.rope_base)

    def body(x, xs):
        w, stl = xs
        stq = {"kv": stl["kv"], "conv": stl["conv"], "s": stl["s"]}
        # write into ring slot
        stq = dict(stq)
        x, new_st = _layer_decode_ring(w, x, cfg, rope, q_pos, k_pos, stq, slot)
        return x, new_st

    x, new_state = jax.lax.scan(body, x, (params["layers"], state))
    x = rms_norm(x, params["ln_f"])
    return (x @ params["embed"].T).astype(jnp.float32), new_state


def _layer_decode_ring(w, x, cfg, rope, q_pos, k_pos, st, slot):
    h_in = rms_norm(x, w["ln"])
    b, s, _ = h_in.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = apply_rope((h_in @ w["wq"]).reshape(b, s, h, hd), rope, q_pos)
    k = apply_rope((h_in @ w["wk"]).reshape(b, s, hkv, hd), rope, q_pos)
    v = (h_in @ w["wv"]).reshape(b, s, hkv, hd)
    ck = _ring_write(st["kv"]["k"], k, slot)
    cv = _ring_write(st["kv"]["v"], v, slot)
    mask = make_attn_mask(q_pos, k_pos, cfg.window)
    attn_out = attention(q, ck, cv, mask, scale=1.0 / math.sqrt(hd))
    attn_out = attn_out.reshape(b, s, h * hd) @ w["wo_attn"]
    ssm_out, ssm_st = _ssm_branch(w, h_in, cfg, st, decode=True)
    fused = 0.5 * (
        rms_norm(attn_out, w["ln_attn_out"]) + rms_norm(ssm_out, w["ln_ssm_out"])
    )
    x = x + fused
    h2 = rms_norm(x, w["ln_ffn"])
    g = h2 @ w["w_gate"]
    up = h2 @ w["w_up"]
    ffn = (jax.nn.silu(g.astype(jnp.float32)).astype(up.dtype) * up) @ w["w_down"]
    return x + ffn, {"kv": {"k": ck, "v": cv}, "conv": ssm_st["conv"], "s": ssm_st["s"]}


def lm_loss(params, cfg: HymbaConfig, tokens, targets):
    logits = forward(params, cfg, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
