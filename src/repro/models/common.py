"""Shared model building blocks: param schema with logical sharding axes,
norms, RoPE, attention (GQA / MLA, sliding-window, softcap, qk-norm).

Params are plain nested dicts of arrays.  Each model defines a *schema*
(same tree of ``ParamSpec``), from which we derive both ``init`` (random
arrays) and ``shardings`` (PartitionSpecs under a mesh, with
divisibility-aware fallback to replication).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# Logical-axis -> mesh-axis rules.  "data_axes" covers batch/sequence
# activations; params only ever shard over the model axis.
MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis names, len == len(shape)
    scale: float | None = None  # init stddev; default fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# logical axes that shard over the model/tensor axis of the mesh
_MODEL_SHARDED = {"vocab", "heads", "kv_heads", "ff", "experts", "out_ch"}


def spec_to_pspec(spec: ParamSpec, mesh, fsdp: bool = False) -> P:
    """Translate logical axes to a PartitionSpec, replicating any dim that
    does not divide the mesh axis (e.g. smollm's 9 heads on model=16).

    ``fsdp=True`` (training): additionally shard the largest remaining dim
    over the data(+pod) axes — fully-sharded params/grads/optimizer state
    (ZeRO-3-style); GSPMD inserts the per-layer weight all-gathers and
    gradient reduce-scatters.
    """
    model_size = mesh.shape[MODEL_AXIS]
    out: list = []
    used_model = False
    for dim, ax in zip(spec.shape, spec.axes):
        if ax in _MODEL_SHARDED and not used_model and dim % model_size == 0:
            out.append(MODEL_AXIS)
            used_model = True
        else:
            out.append(None)
    # FSDP only for stacked (>=3-D) layer weights: sharding a 2-D embedding
    # over data conflicts with the batch sharding of the logits matmul and
    # makes GSPMD replicate the whole table (measured: paligemma train_4k
    # regressed 3.7x in flops / 8x in temp — see EXPERIMENTS.md §Perf).
    if fsdp and len(spec.shape) >= 3:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        dsize = 1
        for a in data_axes:
            dsize *= mesh.shape[a]
        # pick the largest not-yet-sharded dim divisible by the data degree
        cands = [
            (dim, i) for i, (dim, sp) in enumerate(zip(spec.shape, out))
            if sp is None and dim % dsize == 0 and dim >= dsize
        ]
        if cands:
            _, i = max(cands)
            out[i] = data_axes if len(data_axes) > 1 else data_axes[0]
    return P(*out)


def schema_init(schema, key, dtype=jnp.bfloat16):
    """Random init of a schema tree (fan-in scaled normal)."""
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    arrs = []
    for k, spec in zip(keys, leaves):
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
        if spec.scale == 0.0:
            arrs.append(jnp.zeros(spec.shape, dtype))
        else:
            arrs.append(jax.random.normal(k, spec.shape, dtype) * scale)
    return jax.tree.unflatten(treedef, arrs)


def schema_shapes(schema, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree (for eval_shape / dry-run)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        schema,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def schema_pspecs(schema, mesh, fsdp: bool = False):
    return jax.tree.map(
        lambda s: spec_to_pspec(s, mesh, fsdp),
        schema,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def schema_shardings(schema, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, mesh)),
        schema,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def count_params(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(np.prod(s.shape) for s in leaves))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x, gamma, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)


def rope_inv_freq(head_dim: int, base: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, inv_freq, positions):
    """x: (B, S, H, D); positions: (B, S) int32.  Angles computed on the
    fly (no O(max_pos) tables — matters at 500k context)."""
    ang = positions.astype(jnp.float32)[:, :, None] * inv_freq  # (B,S,D/2)
    c = jnp.cos(ang)[:, :, None, :]
    s = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


def make_attn_mask(q_pos, k_pos, window: int | None = None):
    """Causal (+ optional sliding window) additive mask.

    q_pos: (B, Sq), k_pos: (B, Sk) -> (B, 1, Sq, Sk) float32 {0, -inf}.
    """
    ok = k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        ok &= k_pos[:, None, :] > q_pos[:, :, None] - window
    return jnp.where(ok, 0.0, -1e30)[:, None, :, :]


def attention(q, k, v, mask, *, scale=None, attn_softcap=None):
    """q: (B,Sq,H,D), k/v: (B,Sk,Hkv,D[v]); GQA by head repetition.

    Softmax in fp32 (production numerics); returns (B,Sq,H,Dv).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    rep = h // hkv
    qg = q.reshape(b, sq, hkv, rep, d)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32) * scale
    if attn_softcap is not None:
        logits = softcap(logits, attn_softcap)
    logits = logits + mask[:, :, None, :, :]  # mask (B,1,Sq,Sk) -> (B,1,1,Sq,Sk)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])
