"""Generic decoder-only LM covering the dense/GQA/MLA/MoE design space:

  * GQA attention (n_kv_heads <= n_heads), optional per-head qk-norm (Qwen3)
  * MLA latent attention with compressed KV cache (DeepSeek-V2/V3)
  * MoE FFN (shared + routed, top-k, sort-based dispatch) with dense-first
    layers (DeepSeek), or plain SwiGLU/GeGLU FFN
  * attention/logit softcaps + sandwich norms + embedding scaling (Gemma2)
  * sliding-window attention, optionally alternating local/global layers
  * optional prefix embeddings (PaliGemma image patches, Whisper-style stubs)

Layers run under ``jax.lax.scan`` with stacked params (compact HLO, fast
compile — the production pattern).  Long sequences use a flash-style
two-level scan attention (online softmax over KV chunks) so activation
memory stays O(S * chunk) instead of O(S^2).
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import BATCH, shard_hint

from .common import (
    ParamSpec,
    apply_rope,
    attention,
    make_attn_mask,
    rms_norm,
    rope_inv_freq,
    softcap,
)
from .moe import MoEConfig, moe_ffn, moe_schema


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int  # 0 => direct q projection
    kv_lora: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_dim: int


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"  # "silu" | "gelu"
    attn: str = "gqa"  # "gqa" | "mla"
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    n_dense_layers: int = 0  # leading dense layers before MoE stack
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    window: Optional[int] = None
    window_pattern: str = "none"  # "none" | "all" | "alternate"
    rope_base: float = 10000.0
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma: x *= sqrt(d_model)
    sandwich_norms: bool = False  # gemma2 post-attn/post-ffn norms
    max_seq: int = 4096
    flash_chunk: int = 1024
    # §Perf hillclimb: iterate only the lower-triangle (q,kv) block pairs —
    # skips the fully-masked upper half, halving attention FLOPs and HBM
    # traffic for causal prefill/train.  False = paper-faithful baseline
    # (full rectangle, mask applied).
    flash_block_skip: bool = dataclasses.field(
        default_factory=lambda: os.environ.get("REPRO_BASELINE") != "1"
    )
    sub_quadratic: bool = False  # True only for SSM/hybrid families

    @property
    def q_dim(self):
        if self.attn == "mla":
            return self.mla.qk_nope_dim + self.mla.qk_rope_dim
        return self.head_dim


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------


def _layer_schema(cfg: LMConfig, moe_layer: bool) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s: dict = {"ln_attn": ParamSpec((d,), ("embed",), scale=0.0)}
    if cfg.attn == "mla":
        m = cfg.mla
        qh = m.qk_nope_dim + m.qk_rope_dim
        if m.q_lora:
            s["wq_a"] = ParamSpec((d, m.q_lora), ("embed", None))
            s["q_ln"] = ParamSpec((m.q_lora,), (None,), scale=0.0)
            s["wq_b"] = ParamSpec((m.q_lora, h * qh), (None, "heads"))
        else:
            s["wq"] = ParamSpec((d, h * qh), ("embed", "heads"))
        s["wkv_a"] = ParamSpec((d, m.kv_lora + m.qk_rope_dim), ("embed", None))
        s["kv_ln"] = ParamSpec((m.kv_lora,), (None,), scale=0.0)
        s["wkv_b"] = ParamSpec(
            (m.kv_lora, h * (m.qk_nope_dim + m.v_dim)), (None, "heads")
        )
        s["wo"] = ParamSpec((h * m.v_dim, d), ("heads", "embed"))
    else:
        s["wq"] = ParamSpec((d, h * hd), ("embed", "heads"))
        s["wk"] = ParamSpec((d, hkv * hd), ("embed", "kv_heads"))
        s["wv"] = ParamSpec((d, hkv * hd), ("embed", "kv_heads"))
        s["wo"] = ParamSpec((h * hd, d), ("heads", "embed"))
        if cfg.qk_norm:
            s["q_ln"] = ParamSpec((hd,), (None,), scale=0.0)
            s["k_ln"] = ParamSpec((hd,), (None,), scale=0.0)
    s["ln_ffn"] = ParamSpec((d,), ("embed",), scale=0.0)
    if cfg.sandwich_norms:
        s["ln_attn_post"] = ParamSpec((d,), ("embed",), scale=0.0)
        s["ln_ffn_post"] = ParamSpec((d,), ("embed",), scale=0.0)
    if moe_layer:
        s["moe"] = moe_schema(cfg.moe)
    else:
        s["w_gate"] = ParamSpec((d, cfg.d_ff), ("embed", "ff"))
        s["w_up"] = ParamSpec((d, cfg.d_ff), ("embed", "ff"))
        s["w_down"] = ParamSpec((cfg.d_ff, d), ("ff", "embed"))
    return s


def _stack(schema: dict, n: int) -> dict:
    """Prepend a layer axis of size n to every leaf."""
    return jax.tree.map(
        lambda p: ParamSpec((n,) + p.shape, (None,) + p.axes, p.scale),
        schema,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def lm_schema(cfg: LMConfig) -> dict:
    n_moe = (cfg.layers - cfg.n_dense_layers) if cfg.moe else 0
    n_dense = cfg.layers - n_moe
    s: dict = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), scale=0.0),
    }
    if n_dense:
        s["dense_layers"] = _stack(_layer_schema(cfg, moe_layer=False), n_dense)
    if n_moe:
        s["moe_layers"] = _stack(_layer_schema(cfg, moe_layer=True), n_moe)
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec(
            (cfg.d_model, cfg.vocab), ("embed", "vocab"), scale=0.02
        )
    return s


# ---------------------------------------------------------------------------
# attention variants
# ---------------------------------------------------------------------------


def _flash_attention(q, k, v, q_pos, k_pos, *, scale, window, attn_softcap, chunk):
    """Two-level scan flash attention with online softmax.

    q: (B,Sq,H,D), k/v: (B,Sk,Hkv,D).  Memory O(Sq*chunk) per block.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    rep = h // hkv
    qc = min(chunk, sq)
    kc = min(chunk, sk)
    assert sq % qc == 0 and sk % kc == 0, (sq, sk, chunk)

    qg = q.reshape(b, sq // qc, qc, hkv, rep, d)
    kg = k.reshape(b, sk // kc, kc, hkv, d)
    vg = v.reshape(b, sk // kc, kc, hkv, dv)
    qp = q_pos.reshape(b, sq // qc, qc)
    kp = k_pos.reshape(b, sk // kc, kc)

    @jax.checkpoint
    def q_block(carry, qi):
        qb, qpb = qi  # (B,qc,hkv,rep,d), (B,qc)

        @jax.checkpoint
        def kv_block(st, ki):
            m, l, acc = st
            kb, vb, kpb = ki
            logits = (
                jnp.einsum("bqhrd,bkhd->bhrqk", qb, kb).astype(jnp.float32) * scale
            )
            if attn_softcap is not None:
                logits = softcap(logits, attn_softcap)
            ok = kpb[:, None, :] <= qpb[:, :, None]
            if window is not None:
                ok &= kpb[:, None, :] > qpb[:, :, None] - window
            logits = logits + jnp.where(ok, 0.0, -1e30)[:, None, None, :, :]
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhrqk,bkhd->bhrqd", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, rep, qc), -jnp.inf, jnp.float32),
            jnp.zeros((b, hkv, rep, qc), jnp.float32),
            jnp.zeros((b, hkv, rep, qc, dv), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init, (kg.swapaxes(0, 1), vg.swapaxes(0, 1), kp.swapaxes(0, 1))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.astype(q.dtype)

    _, blocks = jax.lax.scan(
        q_block, None, (qg.swapaxes(0, 1), qp.swapaxes(0, 1))
    )  # (nq, B, hkv, rep, qc, dv)
    out = jnp.transpose(blocks, (1, 0, 4, 2, 3, 5)).reshape(b, sq, h, dv)
    return out


def _flash_attention_triangle(
    q, k, v, q_pos, k_pos, *, scale, window, attn_softcap, chunk
):
    """Causal block-skip flash attention (§Perf optimization).

    Iterates a single scan over the STATIC list of lower-triangle
    (q_block, kv_block) pairs — nq*(nq+1)/2 steps instead of nq*nk — so the
    fully-masked upper half is never computed: ~2x fewer attention FLOPs
    and HBM bytes than the rectangle version at equal numerics (the inner
    online-softmax math is identical).
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    rep = h // hkv
    qc = min(chunk, sq)
    kc = min(chunk, sk)
    assert sq % qc == 0 and sk % kc == 0 and sq == sk, (sq, sk, chunk)
    nq = sq // qc

    qg = q.reshape(b, nq, qc, hkv, rep, d).swapaxes(0, 1)  # (nq,B,qc,hkv,rep,d)
    kg = k.reshape(b, nq, kc, hkv, d).swapaxes(0, 1)
    vg = v.reshape(b, nq, kc, hkv, dv).swapaxes(0, 1)
    qp = q_pos.reshape(b, nq, qc).swapaxes(0, 1)
    kp = k_pos.reshape(b, nq, kc).swapaxes(0, 1)

    pairs = [(qi, ki) for qi in range(nq) for ki in range(qi + 1)]
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

    @jax.checkpoint
    def step(carry, idx):
        m, l, acc = carry  # (nq,B,hkv,rep,qc[,dv])
        qi, ki = idx
        qb = jax.lax.dynamic_index_in_dim(qg, qi, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kg, ki, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vg, ki, 0, keepdims=False)
        qpb = jax.lax.dynamic_index_in_dim(qp, qi, 0, keepdims=False)
        kpb = jax.lax.dynamic_index_in_dim(kp, ki, 0, keepdims=False)
        logits = (
            jnp.einsum("bqhrd,bkhd->bhrqk", qb, kb).astype(jnp.float32) * scale
        )
        if attn_softcap is not None:
            logits = softcap(logits, attn_softcap)
        ok = kpb[:, None, :] <= qpb[:, :, None]
        if window is not None:
            ok &= kpb[:, None, :] > qpb[:, :, None] - window
        logits = logits + jnp.where(ok, 0.0, -1e30)[:, None, None, :, :]
        m_i = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_i, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=-1)
        a_new = a_i * corr[..., None] + jnp.einsum(
            "bhrqk,bkhd->bhrqd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    init = (
        jnp.full((nq, b, hkv, rep, qc), -jnp.inf, jnp.float32),
        jnp.zeros((nq, b, hkv, rep, qc), jnp.float32),
        jnp.zeros((nq, b, hkv, rep, qc, dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (qi_arr, ki_arr))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (nq,B,hkv,rep,qc,dv)
    out = jnp.transpose(out, (1, 0, 4, 2, 3, 5)).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def _attend(q, k, v, q_pos, k_pos, cfg: LMConfig, window, *, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    sq, sk = q.shape[1], k.shape[1]
    if sq > cfg.flash_chunk and sq % cfg.flash_chunk == 0 and sk % cfg.flash_chunk == 0:
        if cfg.flash_block_skip and sq == sk:
            return _flash_attention_triangle(
                q, k, v, q_pos, k_pos,
                scale=scale, window=window,
                attn_softcap=cfg.attn_softcap, chunk=cfg.flash_chunk,
            )
        return _flash_attention(
            q, k, v, q_pos, k_pos,
            scale=scale, window=window,
            attn_softcap=cfg.attn_softcap, chunk=cfg.flash_chunk,
        )
    mask = make_attn_mask(q_pos, k_pos, window)
    return attention(q, k, v, mask, scale=scale, attn_softcap=cfg.attn_softcap)


# model-axis degree of the production meshes (mesh.py); used to pick the
# cache layout that avoids collectives for each arch.
PRODUCTION_MODEL_DEGREE = 16


def _use_ring_cache(n_kv_heads: int) -> bool:
    """S-sharded ring caches when kv heads can't shard the model axis.

    Measured (EXPERIMENTS.md §Perf cell 2): head-sharded DUS caches
    all-gather the whole cache when kv %% 16 != 0 (qwen3: 37 GB/step); when
    kv DOES divide (codeqwen's 32), DUS is strictly cheaper than the ring
    rewrite (2.4x bytes) — so pick per arch."""
    if os.environ.get("REPRO_BASELINE") == "1":
        return False
    return n_kv_heads % PRODUCTION_MODEL_DEGREE != 0


def _ring_write(cache, new, pos, ring: bool = True):
    """Write ``new`` (B, S_new, ...) into ``cache`` (B, S, ...) starting at
    slot ``pos`` (the position of ``new``'s first row).

    For single-token decode writes (S_new == 1), ring=True selects against
    an iota — zero-collective under any sharding of S; ring=False is a
    dynamic-update-slice (cheaper HBM-wise; requires the cache NOT to be
    sharded along S).  Multi-token writes (batched prefill) always take the
    slice path: one contiguous store beats S_new selects."""
    if new.shape[1] > 1 or not ring or os.environ.get("REPRO_BASELINE") == "1":
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), pos, axis=1
        )
    idx = jnp.arange(cache.shape[1], dtype=jnp.int32)
    sel = (idx == pos).reshape((1, -1) + (1,) * (cache.ndim - 2))
    return jnp.where(sel, new.astype(cache.dtype), cache)


def _gqa_attn(w, x, cfg: LMConfig, rope, q_pos, k_pos, window, cache=None):
    """Returns (out, new_cache).  cache = dict(k=(B,S,hkv,hd), v=...) or None."""
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ w["wq"]).reshape(b, s, h, hd)
    k = (x @ w["wk"]).reshape(b, s, hkv, hd)
    v = (x @ w["wv"]).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, w["q_ln"])
        k = rms_norm(k, w["k_ln"])
    q = apply_rope(q, rope, q_pos)
    k = apply_rope(k, rope, q_pos)
    if cache is not None:
        pos = q_pos[0, 0]  # first query position (same across batch)
        # §Perf: where-based write instead of dynamic-update-slice — fully
        # shardable along the (model-sharded) sequence axis, so GSPMD never
        # all-gathers the cache (the DUS resharding pathology).
        ring = _use_ring_cache(cfg.n_kv_heads)
        ck = _ring_write(cache["k"], k, pos, ring)
        cv = _ring_write(cache["v"], v, pos, ring)
        out = _attend(q, ck, cv, q_pos, k_pos, cfg, window)
        new_cache = {"k": ck, "v": cv}
    else:
        out = _attend(q, k, v, q_pos, k_pos, cfg, window)
        new_cache = None
    return out.reshape(b, s, h * hd) @ w["wo"], new_cache


def _mla_attn(w, x, cfg: LMConfig, rope, q_pos, k_pos, window, cache=None):
    """MLA with compressed-latent KV cache: cache = dict(ckv=(B,S,kv_lora),
    krope=(B,S,rope_dim)).  Baseline decodes by expanding the latent."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    if m.q_lora:
        ql = rms_norm(x @ w["wq_a"], w["q_ln"])
        q = (ql @ w["wq_b"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    else:
        q = (x @ w["wq"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, rope, q_pos)

    kv = x @ w["wkv_a"]  # (B,S,kv_lora+rope)
    ckv, krope = jnp.split(kv, [m.kv_lora], axis=-1)
    ckv = rms_norm(ckv, w["kv_ln"])
    krope = apply_rope(krope[:, :, None, :], rope, q_pos)[:, :, 0, :]

    if cache is not None:
        pos = q_pos[0, 0]
        ckv = _ring_write(cache["ckv"], ckv, pos)
        krope = _ring_write(cache["krope"], krope, pos)
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        new_cache = None

    sk = ckv.shape[1]
    kvx = (ckv @ w["wkv_b"]).reshape(b, sk, h, m.qk_nope_dim + m.v_dim)
    k_nope, v = jnp.split(kvx, [m.qk_nope_dim], axis=-1)
    k_rope_b = jnp.broadcast_to(krope[:, :, None, :], (b, sk, h, m.qk_rope_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    out = _attend(q_full, k_full, v, q_pos, k_pos, cfg, window, scale=scale)
    return out.reshape(b, s, h * m.v_dim) @ w["wo"], new_cache


# ---------------------------------------------------------------------------
# layer / model forward
# ---------------------------------------------------------------------------


def _ffn(w, x, cfg: LMConfig):
    g = x @ w["w_gate"]
    u = x @ w["w_up"]
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(g.astype(jnp.float32)).astype(u.dtype) * u
    return h @ w["w_down"]


def _layer(w, x, cfg: LMConfig, rope, q_pos, k_pos, window, moe_layer, cache):
    if cache is None and x.shape[1] > 1 and os.environ.get("REPRO_SEQ_PARALLEL") == "1":
        # Sequence-parallel residual stream (Megatron-SP style).  Measured
        # on deepseek-v3 train_4k: -26% live activations but +3.2x
        # collective wire (per-layer x all-gathers) -> net loss on the
        # roofline; kept behind a flag.  See EXPERIMENTS.md §Perf
        # (refuted-hypothesis log); microbatching is the adopted fix.
        x = shard_hint(x, BATCH, "model", None)
    h_in = rms_norm(x, w["ln_attn"])
    attn_fn = _mla_attn if cfg.attn == "mla" else _gqa_attn
    attn_out, new_cache = attn_fn(w, h_in, cfg, rope, q_pos, k_pos, window, cache)
    if cfg.sandwich_norms:
        attn_out = rms_norm(attn_out, w["ln_attn_post"])
    x = x + attn_out
    h2 = rms_norm(x, w["ln_ffn"])
    if moe_layer:
        b, s, d = h2.shape
        ffn_out = moe_ffn(w["moe"], h2.reshape(b * s, d), cfg.moe).reshape(b, s, d)
    else:
        ffn_out = _ffn(w, h2, cfg)
    if cfg.sandwich_norms:
        ffn_out = rms_norm(ffn_out, w["ln_ffn_post"])
    return x + ffn_out, new_cache


def _layer_windows(cfg: LMConfig, n_layers: int, offset: int = 0):
    """Per-layer sliding-window size array (None encoded as 0)."""
    if cfg.window is None or cfg.window_pattern == "none":
        return [None] * n_layers
    if cfg.window_pattern == "all":
        return [cfg.window] * n_layers
    # alternate: even layers local, odd global (gemma2)
    return [cfg.window if (i + offset) % 2 == 0 else None for i in range(n_layers)]


def _run_stack(stack_w, x, cfg, rope, q_pos, k_pos, moe_layer, caches, windows):
    """scan over a homogeneous layer stack. windows: list -> traced per-layer
    int array (0 = global) consumed via two-mask select inside the body."""
    n_layers = jax.tree.leaves(stack_w)[0].shape[0]
    win_arr = jnp.asarray([0 if w is None else w for w in windows], jnp.int32)
    uniform = all(w == windows[0] for w in windows)

    def body(x, xs):
        w, win, cache = xs
        if uniform:
            window = windows[0]
        else:
            # alternate local/global: realized as window-size select; the
            # flash kernel takes a traced window bound.
            window = jnp.where(win > 0, win, jnp.iinfo(jnp.int32).max // 2)
        x, new_cache = _layer(
            w, x, cfg, rope, q_pos, k_pos, window, moe_layer, cache
        )
        return x, new_cache

    # per-layer remat: backward recomputes one layer at a time, so only the
    # (L, B, S, d) carries persist — not per-layer attention residuals.
    if caches is None:
        body = jax.checkpoint(body)
    xs = (stack_w, win_arr, caches)
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches


def _embed(params, cfg: LMConfig, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    # batch over (pod, data); for batch=1 long-context shapes the hint
    # falls back to sequence sharding over data.
    x = shard_hint(x, BATCH, "data" if x.shape[0] == 1 else None, None)
    return x


def _unembed(params, cfg: LMConfig, x):
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    if cfg.logit_softcap is not None:
        logits = softcap(logits, cfg.logit_softcap)
    return logits


def forward(params, cfg: LMConfig, tokens, prefix_embeds=None):
    """Full-sequence forward (train / prefill). tokens: (B, S) -> logits."""
    x = _embed(params, cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    rope_dim = cfg.mla.qk_rope_dim if cfg.attn == "mla" else cfg.head_dim
    rope = rope_inv_freq(rope_dim, cfg.rope_base)

    n_moe = (cfg.layers - cfg.n_dense_layers) if cfg.moe else 0
    n_dense = cfg.layers - n_moe
    if n_dense:
        wins = _layer_windows(cfg, n_dense)
        x, _ = _run_stack(
            params["dense_layers"], x, cfg, rope, pos, pos, False, None, wins
        )
    if n_moe:
        wins = _layer_windows(cfg, n_moe, offset=n_dense)
        x, _ = _run_stack(
            params["moe_layers"], x, cfg, rope, pos, pos, True, None, wins
        )
    return _unembed(params, cfg, x)


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked (L-leading) KV caches for decode."""
    n_moe = (cfg.layers - cfg.n_dense_layers) if cfg.moe else 0
    n_dense = cfg.layers - n_moe

    def one(n):
        if cfg.attn == "mla":
            m = cfg.mla
            return {
                "ckv": jnp.zeros((n, batch, max_len, m.kv_lora), dtype),
                "krope": jnp.zeros((n, batch, max_len, m.qk_rope_dim), dtype),
            }
        return {
            "k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        }

    out = {}
    if n_dense:
        out["dense"] = one(n_dense)
    if n_moe:
        out["moe"] = one(n_moe)
    return out


def cache_spec(cfg: LMConfig):
    """Logical axes for cache sharding: batch over data, heads over model."""
    if cfg.attn == "mla":
        return {"ckv": ("layers", "batch", "seq", None),
                "krope": ("layers", "batch", "seq", None)}
    return {"k": ("layers", "batch", "seq", "kv_heads", None),
            "v": ("layers", "batch", "seq", "kv_heads", None)}


def decode_step(params, cfg: LMConfig, cache, tokens, pos):
    """One decode step. tokens: (B, 1); pos: scalar int32 (next position).
    Returns (logits, new_cache)."""
    x = _embed(params, cfg, tokens)
    b = x.shape[0]
    max_len = jax.tree.leaves(cache)[0].shape[2]
    q_pos = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1))
    k_pos = jnp.broadcast_to(jnp.arange(max_len, dtype=jnp.int32), (b, max_len))
    # mask out not-yet-written cache slots via the causal test k_pos <= q_pos
    rope_dim = cfg.mla.qk_rope_dim if cfg.attn == "mla" else cfg.head_dim
    rope = rope_inv_freq(rope_dim, cfg.rope_base)

    n_moe = (cfg.layers - cfg.n_dense_layers) if cfg.moe else 0
    n_dense = cfg.layers - n_moe
    new_cache = {}
    if n_dense:
        wins = _layer_windows(cfg, n_dense)
        x, nc = _run_stack(
            params["dense_layers"], x, cfg, rope, q_pos, k_pos, False,
            cache["dense"], wins,
        )
        new_cache["dense"] = nc
    if n_moe:
        wins = _layer_windows(cfg, n_moe, offset=n_dense)
        x, nc = _run_stack(
            params["moe_layers"], x, cfg, rope, q_pos, k_pos, True,
            cache["moe"], wins,
        )
        new_cache["moe"] = nc
    return _unembed(params, cfg, x), new_cache


def prefill(params, cfg: LMConfig, cache, tokens):
    """Batched cache-filling prefill: one full-sequence pass that writes
    every prompt position's K/V into ``cache`` in a single jitted step.

    tokens: (B, P) -> (logits (B, P, V), filled cache).  Equivalent to P
    ``decode_step`` calls (same cache semantics: causal mask over the full
    ``max_len`` axis, positions 0..P-1 written) but one program — the step
    loop is only needed for generation."""
    x = _embed(params, cfg, tokens)
    b, s, _ = x.shape
    max_len = jax.tree.leaves(cache)[0].shape[2]
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    k_pos = jnp.broadcast_to(jnp.arange(max_len, dtype=jnp.int32), (b, max_len))
    rope_dim = cfg.mla.qk_rope_dim if cfg.attn == "mla" else cfg.head_dim
    rope = rope_inv_freq(rope_dim, cfg.rope_base)

    n_moe = (cfg.layers - cfg.n_dense_layers) if cfg.moe else 0
    n_dense = cfg.layers - n_moe
    new_cache = {}
    if n_dense:
        wins = _layer_windows(cfg, n_dense)
        x, nc = _run_stack(
            params["dense_layers"], x, cfg, rope, q_pos, k_pos, False,
            cache["dense"], wins,
        )
        new_cache["dense"] = nc
    if n_moe:
        wins = _layer_windows(cfg, n_moe, offset=n_dense)
        x, nc = _run_stack(
            params["moe_layers"], x, cfg, rope, q_pos, k_pos, True,
            cache["moe"], wins,
        )
        new_cache["moe"] = nc
    return _unembed(params, cfg, x), new_cache


def lm_loss(params, cfg: LMConfig, tokens, targets, prefix_embeds=None):
    logits = forward(params, cfg, tokens, prefix_embeds)
    if prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1] :]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
